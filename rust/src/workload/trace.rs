//! Trace capture/replay: a plain text format (one arrival time in seconds
//! per line, `#` comments) so workload traces can be diffed, versioned and
//! exchanged with the python side.

use crate::util::units::Secs;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Serialise arrival times.
pub fn to_text(times: &[Secs]) -> String {
    let mut s = String::with_capacity(times.len() * 12);
    s.push_str("# elastic-gen workload trace v1 (seconds)\n");
    for t in times {
        s.push_str(&format!("{:.9}\n", t.value()));
    }
    s
}

/// Parse a trace document.
pub fn from_text(text: &str) -> Result<Vec<Secs>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v: f64 = line
            .parse()
            .map_err(|_| anyhow!("trace line {}: bad number '{line}'", i + 1))?;
        if v < 0.0 {
            return Err(anyhow!("trace line {}: negative time", i + 1));
        }
        out.push(Secs(v));
    }
    if out.windows(2).any(|w| w[1] < w[0]) {
        return Err(anyhow!("trace not sorted"));
    }
    Ok(out)
}

pub fn save(path: &Path, times: &[Secs]) -> Result<()> {
    std::fs::write(path, to_text(times))?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<Secs>> {
    from_text(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let times = vec![Secs(0.001), Secs(0.04), Secs(1.5)];
        let parsed = from_text(&to_text(&times)).unwrap();
        assert_eq!(parsed.len(), 3);
        assert!((parsed[2].value() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn rejects_unsorted_and_garbage() {
        assert!(from_text("2.0\n1.0\n").is_err());
        assert!(from_text("abc\n").is_err());
        assert!(from_text("-1\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        assert_eq!(from_text("# hi\n\n0.5\n").unwrap(), vec![Secs(0.5)]);
    }
}
