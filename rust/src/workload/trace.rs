//! Trace capture/replay: a plain text format (one arrival time in seconds
//! per line, `#` comments) so workload traces can be diffed, versioned and
//! exchanged with the python side.
//!
//! Parsing returns a typed [`TraceError`] (not a panic, not a stringly
//! anyhow error): the adaptive serving loop feeds recorded traces back
//! into the fitter, and a malformed or empty capture must be a recoverable
//! "keep the current deployment" signal, never a crash.

use crate::util::units::Secs;
use std::path::Path;

/// Why a trace document was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceError {
    /// The document contains no arrival times at all.
    Empty,
    /// A line is not a number.
    BadNumber { line: usize },
    /// An arrival time is negative.
    NegativeTime { line: usize },
    /// An arrival time is smaller than its predecessor.
    NonMonotone { line: usize },
    /// Filesystem failure while saving/loading.
    Io(String),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Empty => write!(f, "trace contains no arrival times"),
            TraceError::BadNumber { line } => write!(f, "trace line {line}: bad number"),
            TraceError::NegativeTime { line } => write!(f, "trace line {line}: negative time"),
            TraceError::NonMonotone { line } => {
                write!(f, "trace line {line}: arrival time decreases")
            }
            TraceError::Io(e) => write!(f, "trace io error: {e}"),
        }
    }
}

impl std::error::Error for TraceError {}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> TraceError {
        TraceError::Io(e.to_string())
    }
}

/// Serialise arrival times.
pub fn to_text(times: &[Secs]) -> String {
    let mut s = String::with_capacity(times.len() * 12);
    s.push_str("# elastic-gen workload trace v1 (seconds)\n");
    for t in times {
        s.push_str(&format!("{:.9}\n", t.value()));
    }
    s
}

/// Parse a trace document.  Empty traces (no data lines) are rejected:
/// every consumer — replay, fitting, drift scoring — needs at least one
/// arrival, and an empty capture is indistinguishable from a broken one.
pub fn from_text(text: &str) -> Result<Vec<Secs>, TraceError> {
    let mut out: Vec<Secs> = Vec::new();
    let mut prev: Option<f64> = None;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v: f64 = line
            .parse()
            .map_err(|_| TraceError::BadNumber { line: i + 1 })?;
        if !v.is_finite() {
            return Err(TraceError::BadNumber { line: i + 1 });
        }
        if v < 0.0 {
            return Err(TraceError::NegativeTime { line: i + 1 });
        }
        if let Some(p) = prev {
            if v < p {
                return Err(TraceError::NonMonotone { line: i + 1 });
            }
        }
        prev = Some(v);
        out.push(Secs(v));
    }
    if out.is_empty() {
        return Err(TraceError::Empty);
    }
    Ok(out)
}

pub fn save(path: &Path, times: &[Secs]) -> Result<(), TraceError> {
    std::fs::write(path, to_text(times))?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Vec<Secs>, TraceError> {
    from_text(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, vec_f64};

    #[test]
    fn roundtrip() {
        let times = vec![Secs(0.001), Secs(0.04), Secs(1.5)];
        let parsed = from_text(&to_text(&times)).unwrap();
        assert_eq!(parsed.len(), 3);
        assert!((parsed[2].value() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn rejects_unsorted_and_garbage_with_typed_errors() {
        assert_eq!(
            from_text("2.0\n1.0\n").unwrap_err(),
            TraceError::NonMonotone { line: 2 }
        );
        assert_eq!(from_text("abc\n").unwrap_err(), TraceError::BadNumber { line: 1 });
        assert_eq!(from_text("nan\n").unwrap_err(), TraceError::BadNumber { line: 1 });
        assert_eq!(
            from_text("-1\n").unwrap_err(),
            TraceError::NegativeTime { line: 1 }
        );
    }

    #[test]
    fn empty_trace_is_a_typed_error_not_a_panic() {
        assert_eq!(from_text("").unwrap_err(), TraceError::Empty);
        assert_eq!(from_text("# only comments\n\n").unwrap_err(), TraceError::Empty);
        // the error renders (drift reports embed it)
        assert!(TraceError::Empty.to_string().contains("no arrival times"));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        assert_eq!(from_text("# hi\n\n0.5\n").unwrap(), vec![Secs(0.5)]);
    }

    #[test]
    fn load_missing_file_is_io_error() {
        let e = load(Path::new("/definitely/missing/trace.txt")).unwrap_err();
        assert!(matches!(e, TraceError::Io(_)));
    }

    #[test]
    fn prop_roundtrip_preserves_sorted_traces() {
        // any non-empty sorted non-negative series round-trips within the
        // 1e-9 print precision
        check("trace roundtrip", 200, vec_f64(1, 64, 0.0..1e5), |v| {
            let mut sorted = v.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            let times: Vec<Secs> = sorted.iter().map(|&x| Secs(x)).collect();
            match from_text(&to_text(&times)) {
                Ok(parsed) => {
                    parsed.len() == times.len()
                        && parsed
                            .iter()
                            .zip(&times)
                            .all(|(a, b)| (a.value() - b.value()).abs() < 1e-8)
                }
                Err(_) => false,
            }
        });
    }

    #[test]
    fn prop_unsorted_traces_rejected() {
        // any series with a strict decrease must be rejected NonMonotone
        check("unsorted rejected", 200, vec_f64(2, 64, 0.0..1e5), |v| {
            let times: Vec<Secs> = v.iter().map(|&x| Secs(x)).collect();
            let decreases = v.windows(2).any(|w| w[1] < w[0]);
            match from_text(&to_text(&times)) {
                Ok(_) => !decreases,
                Err(TraceError::NonMonotone { .. }) => decreases,
                Err(_) => false,
            }
        });
    }
}
