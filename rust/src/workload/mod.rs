//! Workload models (RQ2): request-arrival processes with the
//! characteristics §2.1 names — regular sensor periods, irregular/bursty
//! event streams — plus trace capture/replay for reproducible comparisons.

pub mod fit;
pub mod trace;

use crate::util::rng::Rng;
use crate::util::units::Secs;

/// A request-arrival process.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Fixed sensor period (the regular case of [6]).
    Periodic { period: Secs },
    /// Poisson arrivals with mean inter-arrival `mean_gap` (irregular [7]).
    Poisson { mean_gap: Secs },
    /// Bursts of `burst_len` requests `intra_gap` apart, separated by
    /// `burst_gap` (the event-camera/alarm pattern of [7]).
    Bursty {
        burst_len: u32,
        intra_gap: Secs,
        burst_gap: Secs,
    },
    /// Alternating phases of two mean rates (regime switching), the
    /// hardest case for a fixed threshold.
    Phased {
        fast_gap: Secs,
        slow_gap: Secs,
        phase_len: u32,
    },
    /// Explicit absolute arrival times.
    Trace { times: Vec<Secs> },
}

impl Workload {
    /// Generate `n` absolute arrival times (sorted, starting after t=0).
    pub fn arrivals(&self, n: usize, rng: &mut Rng) -> Vec<Secs> {
        let mut out = Vec::with_capacity(n);
        match self {
            Workload::Periodic { period } => {
                for i in 1..=n {
                    out.push(Secs(period.value() * i as f64));
                }
            }
            Workload::Poisson { mean_gap } => {
                let lambda = 1.0 / mean_gap.value();
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(lambda);
                    out.push(Secs(t));
                }
            }
            Workload::Bursty {
                burst_len,
                intra_gap,
                burst_gap,
            } => {
                let mut t = 0.0;
                'outer: loop {
                    t += burst_gap.value();
                    for _ in 0..*burst_len {
                        out.push(Secs(t));
                        if out.len() == n {
                            break 'outer;
                        }
                        t += intra_gap.value();
                    }
                }
            }
            Workload::Phased {
                fast_gap,
                slow_gap,
                phase_len,
            } => {
                let mut t = 0.0;
                let mut fast = true;
                'outer2: loop {
                    let gap = if fast { fast_gap } else { slow_gap };
                    for _ in 0..*phase_len {
                        // jitter +-20% keeps the phases from being trivially
                        // learnable
                        t += gap.value() * rng.range(0.8, 1.2);
                        out.push(Secs(t));
                        if out.len() == n {
                            break 'outer2;
                        }
                    }
                    fast = !fast;
                }
            }
            Workload::Trace { times } => {
                out.extend(times.iter().take(n).cloned());
            }
        }
        out
    }

    /// Mean inter-arrival gap of the process (analytical, for the
    /// Generator's closed-form estimators).
    pub fn mean_gap(&self) -> Secs {
        match self {
            Workload::Periodic { period } => *period,
            Workload::Poisson { mean_gap } => *mean_gap,
            Workload::Bursty {
                burst_len,
                intra_gap,
                burst_gap,
            } => {
                let per_burst =
                    burst_gap.value() + intra_gap.value() * (*burst_len as f64 - 1.0);
                Secs(per_burst / *burst_len as f64)
            }
            Workload::Phased {
                fast_gap, slow_gap, ..
            } => Secs((fast_gap.value() + slow_gap.value()) / 2.0),
            Workload::Trace { times } => match (times.first(), times.last()) {
                (Some(first), Some(last)) if times.len() >= 2 => {
                    Secs((last.value() - first.value()) / (times.len() - 1) as f64)
                }
                _ => Secs(0.0),
            },
        }
    }

    pub fn describe(&self) -> String {
        match self {
            Workload::Periodic { period } => format!("periodic({:.1}ms)", period.ms()),
            Workload::Poisson { mean_gap } => format!("poisson(mean {:.1}ms)", mean_gap.ms()),
            Workload::Bursty {
                burst_len,
                intra_gap,
                burst_gap,
            } => format!(
                "bursty({}x{:.1}ms / {:.0}ms)",
                burst_len,
                intra_gap.ms(),
                burst_gap.ms()
            ),
            Workload::Phased {
                fast_gap,
                slow_gap,
                phase_len,
            } => format!(
                "phased({:.1}ms<->{:.1}ms x{})",
                fast_gap.ms(),
                slow_gap.ms(),
                phase_len
            ),
            Workload::Trace { times } => format!("trace({} events)", times.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_exact() {
        let w = Workload::Periodic { period: Secs::from_ms(10.0) };
        let a = w.arrivals(3, &mut Rng::new(1));
        assert_eq!(a, vec![Secs(0.01), Secs(0.02), Secs(0.03)]);
    }

    #[test]
    fn arrivals_sorted_and_positive() {
        let workloads = [
            Workload::Poisson { mean_gap: Secs::from_ms(5.0) },
            Workload::Bursty {
                burst_len: 4,
                intra_gap: Secs::from_ms(1.0),
                burst_gap: Secs::from_ms(50.0),
            },
            Workload::Phased {
                fast_gap: Secs::from_ms(2.0),
                slow_gap: Secs::from_ms(30.0),
                phase_len: 10,
            },
        ];
        for w in workloads {
            let a = w.arrivals(200, &mut Rng::new(3));
            assert_eq!(a.len(), 200);
            assert!(a[0].value() > 0.0);
            assert!(a.windows(2).all(|p| p[1] >= p[0]), "{}", w.describe());
        }
    }

    #[test]
    fn poisson_mean_gap_converges() {
        let w = Workload::Poisson { mean_gap: Secs::from_ms(10.0) };
        let a = w.arrivals(20_000, &mut Rng::new(5));
        let measured = a.last().unwrap().value() / 20_000.0;
        assert!((measured / 0.01 - 1.0).abs() < 0.05, "{measured}");
    }

    #[test]
    fn bursty_mean_gap_formula() {
        let w = Workload::Bursty {
            burst_len: 5,
            intra_gap: Secs::from_ms(1.0),
            burst_gap: Secs::from_ms(96.0),
        };
        // per burst: 96 + 4*1 = 100ms over 5 items = 20ms
        assert!((w.mean_gap().ms() - 20.0).abs() < 1e-9);
    }

    /// The drift report and switch-event log embed these strings; pin them
    /// so log-parsing tooling doesn't silently break.
    #[test]
    fn describe_strings_pinned() {
        assert_eq!(
            Workload::Periodic { period: Secs::from_ms(50.0) }.describe(),
            "periodic(50.0ms)"
        );
        assert_eq!(
            Workload::Poisson { mean_gap: Secs(0.8) }.describe(),
            "poisson(mean 800.0ms)"
        );
        assert_eq!(
            Workload::Bursty {
                burst_len: 8,
                intra_gap: Secs::from_ms(30.0),
                burst_gap: Secs(2.0),
            }
            .describe(),
            "bursty(8x30.0ms / 2000ms)"
        );
        assert_eq!(
            Workload::Phased {
                fast_gap: Secs::from_ms(2.0),
                slow_gap: Secs::from_ms(30.0),
                phase_len: 10,
            }
            .describe(),
            "phased(2.0ms<->30.0ms x10)"
        );
        assert_eq!(
            Workload::Trace { times: vec![Secs(0.1); 3] }.describe(),
            "trace(3 events)"
        );
    }

    #[test]
    fn trace_passthrough() {
        let times = vec![Secs(0.1), Secs(0.2), Secs(0.5)];
        let w = Workload::Trace { times: times.clone() };
        assert_eq!(w.arrivals(2, &mut Rng::new(1)), times[..2].to_vec());
    }
}
