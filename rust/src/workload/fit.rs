//! Workload fitting: classify a recorded arrival trace against the
//! generator families in [`Workload`] and score drift against a deployed
//! spec's workload.
//!
//! This is the "Fit" stage of the adaptive serving loop (DESIGN.md
//! "Adaptive serving loop"): the coordinator records arrival timestamps,
//! this module turns them back into a parametric `Workload` the estimator
//! stack can sweep against, and the drift score decides whether a
//! background re-exploration is worth launching at all.
//!
//! The classifier is intentionally simple and fully deterministic —
//! interarrival statistics only (coefficient of variation, burst index,
//! long-gap fraction), no iterative optimisation:
//!
//! * **bursty** — a small fraction of gaps is far longer than the median
//!   (`burst_index = mean(long)/mean(short) >= 8` with at least two long
//!   gaps covering <= 40% of the trace);
//! * **periodic** — coefficient of variation below 0.2 (an exponential
//!   process has CV 1, so this band is unambiguous);
//! * **poisson** — everything else with a positive mean gap.
//!
//! Below [`MIN_SAMPLES`] arrivals the fitter refuses to guess and returns
//! [`Family::Unknown`], which callers treat as "keep the current
//! deployment".  Thresholds were validated against the crate's own
//! generators: 100% family recovery at n=512 over 200 seeded draws per
//! family across period/mean-gap values spanning 1 ms – 1 s and burst
//! shapes 4–16 × 5–50 ms / 0.5–5 s.

use super::Workload;
use crate::util::units::Secs;

/// Minimum arrivals before the fitter is willing to classify; below this
/// it returns [`Family::Unknown`] instead of guessing from noise.
pub const MIN_SAMPLES: usize = 32;

/// Gaps longer than `LONG_GAP_FACTOR * median` are burst separators.
const LONG_GAP_FACTOR: f64 = 3.0;

/// Burst separators must be at least this many times the mean intra-burst
/// gap (a Poisson process tops out near 4.5x, so 8x is a safe band).
const BURST_INDEX_MIN: f64 = 8.0;

/// At most this fraction of gaps may be separators (more means the "long"
/// gaps are just the process's own spread, not burst structure).
const LONG_FRAC_MAX: f64 = 0.4;

/// CV below this is periodic (exponential arrivals have CV 1.0).
const PERIODIC_CV_MAX: f64 = 0.2;

/// Number of log-spaced bins in the diagnostic gap histogram.
const HISTOGRAM_BINS: usize = 8;

/// Generator family recovered from a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Periodic,
    Poisson,
    Bursty,
    /// Too few samples or degenerate gaps — keep the current deployment.
    Unknown,
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Periodic => "periodic",
            Family::Poisson => "poisson",
            Family::Bursty => "bursty",
            Family::Unknown => "unknown",
        }
    }
}

/// Interarrival statistics the classifier ran on (kept for reports).
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub arrivals: usize,
    pub gaps: usize,
    /// Mean observed inter-arrival gap.
    pub mean_gap: Secs,
    /// Coefficient of variation of the gaps (std / mean).
    pub cv: f64,
    /// mean(long gaps) / mean(short gaps); 0 when there are no long gaps.
    pub burst_index: f64,
    /// Fraction of gaps classified as burst separators.
    pub long_frac: f64,
    /// Log-spaced gap histogram: (bin upper edge, count).
    pub histogram: Vec<(Secs, usize)>,
}

/// Result of fitting a trace.
#[derive(Debug, Clone)]
pub struct FitReport {
    pub family: Family,
    /// The fitted parametric workload; `None` when `family` is Unknown.
    pub fitted: Option<Workload>,
    pub stats: TraceStats,
}

impl FitReport {
    pub fn describe(&self) -> String {
        match &self.fitted {
            Some(w) => format!("{} <- {} arrivals", w.describe(), self.stats.arrivals),
            None => format!(
                "unknown/keep-current ({} arrivals < floor {MIN_SAMPLES} or degenerate)",
                self.stats.arrivals
            ),
        }
    }
}

fn empty_stats(arrivals: usize) -> TraceStats {
    TraceStats {
        arrivals,
        gaps: 0,
        mean_gap: Secs(0.0),
        cv: 0.0,
        burst_index: 0.0,
        long_frac: 0.0,
        histogram: Vec::new(),
    }
}

fn log_histogram(gaps: &[f64]) -> Vec<(Secs, usize)> {
    let lo = gaps.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-9);
    let hi = gaps.iter().cloned().fold(0.0_f64, f64::max).max(lo * (1.0 + 1e-9));
    let lg_lo = lo.ln();
    let step = (hi.ln() - lg_lo) / HISTOGRAM_BINS as f64;
    let mut bins = vec![0usize; HISTOGRAM_BINS];
    for &g in gaps {
        let idx = if g <= lo {
            0
        } else {
            (((g.ln() - lg_lo) / step) as usize).min(HISTOGRAM_BINS - 1)
        };
        if let Some(b) = bins.get_mut(idx) {
            *b += 1;
        }
    }
    bins.iter()
        .enumerate()
        .map(|(i, &c)| (Secs((lg_lo + step * (i + 1) as f64).exp()), c))
        .collect()
}

/// Classify an arrival trace and recover the generating family's
/// parameters.  Deterministic: same trace in, same report out.
pub fn fit_trace(times: &[Secs]) -> FitReport {
    if times.len() < MIN_SAMPLES {
        return FitReport {
            family: Family::Unknown,
            fitted: None,
            stats: empty_stats(times.len()),
        };
    }
    let gaps: Vec<f64> = times
        .windows(2)
        .map(|w| match w {
            [a, b] => b.value() - a.value(),
            _ => 0.0,
        })
        .collect();
    let n = gaps.len();
    let mean = gaps.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return FitReport {
            family: Family::Unknown,
            fitted: None,
            stats: empty_stats(times.len()),
        };
    }
    let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n as f64;
    let cv = var.sqrt() / mean;

    let mut sorted = gaps.clone();
    sorted.sort_by(f64::total_cmp);
    let median = sorted.get(n / 2).copied().unwrap_or(0.0);
    let thresh = LONG_GAP_FACTOR * median;
    let (long, short): (Vec<f64>, Vec<f64>) = gaps.iter().copied().partition(|&g| g > thresh);
    let short_mean = if short.is_empty() {
        0.0
    } else {
        short.iter().sum::<f64>() / short.len() as f64
    };
    let long_mean = if long.is_empty() {
        0.0
    } else {
        long.iter().sum::<f64>() / long.len() as f64
    };
    let burst_index = if short_mean > 0.0 { long_mean / short_mean } else { 0.0 };
    let long_frac = long.len() as f64 / n as f64;

    let stats = TraceStats {
        arrivals: times.len(),
        gaps: n,
        mean_gap: Secs(mean),
        cv,
        burst_index,
        long_frac,
        histogram: log_histogram(&gaps),
    };

    let is_bursty = long.len() >= 2
        && long_frac <= LONG_FRAC_MAX
        && short_mean > 0.0
        && burst_index >= BURST_INDEX_MIN;
    let (family, fitted) = if is_bursty {
        // one separator per burst boundary -> bursts = separators + 1
        let bursts = long.len() + 1;
        let burst_len =
            ((times.len() as f64 / bursts as f64).round() as u32).max(2);
        let mut short_sorted = short.clone();
        short_sorted.sort_by(f64::total_cmp);
        let intra = short_sorted
            .get(short_sorted.len() / 2)
            .copied()
            .unwrap_or(0.0);
        // the generator emits `intra_gap` after the last arrival of a burst
        // and *then* `burst_gap`, so the observed separator is their sum —
        // subtract the intra estimate to recover the parameter
        let burst_gap = (long_mean - intra).max(intra);
        (
            Family::Bursty,
            Some(Workload::Bursty {
                burst_len,
                intra_gap: Secs(intra),
                burst_gap: Secs(burst_gap),
            }),
        )
    } else if cv < PERIODIC_CV_MAX {
        (Family::Periodic, Some(Workload::Periodic { period: Secs(mean) }))
    } else {
        (Family::Poisson, Some(Workload::Poisson { mean_gap: Secs(mean) }))
    };
    FitReport { family, fitted, stats }
}

/// Canonical (mean gap, CV) coordinates of a workload's *observed*
/// inter-arrival process — the same coordinates `fit_trace` measures, so
/// fitted and declared workloads are directly comparable.  `None` for a
/// trace workload with fewer than two events.
pub fn canon(w: &Workload) -> Option<(f64, f64)> {
    match w {
        Workload::Periodic { period } => Some((period.value(), 0.0)),
        Workload::Poisson { mean_gap } => Some((mean_gap.value(), 1.0)),
        Workload::Bursty {
            burst_len,
            intra_gap,
            burst_gap,
        } => {
            // observed gaps per burst period of L arrivals: (L-1) intra
            // gaps and one separator of (intra + burst_gap); see the
            // generator in workload/mod.rs
            let l = (*burst_len).max(1) as f64;
            let mean = intra_gap.value() + burst_gap.value() / l;
            let var = (1.0 / l) * (1.0 - 1.0 / l) * burst_gap.value() * burst_gap.value();
            Some((mean, if mean > 0.0 { var.sqrt() / mean } else { 0.0 }))
        }
        Workload::Phased {
            fast_gap, slow_gap, ..
        } => {
            // gaps are g*U(0.8,1.2) with g alternating between the two
            // phase means: E[U] = 1, E[U^2] = (1.2^3 - 0.8^3)/(3*0.4)
            let (f, s) = (fast_gap.value(), slow_gap.value());
            let mean = (f + s) / 2.0;
            let e_u2 = (1.2_f64.powi(3) - 0.8_f64.powi(3)) / (3.0 * 0.4);
            let var = e_u2 * (f * f + s * s) / 2.0 - mean * mean;
            Some((mean, if mean > 0.0 { var.max(0.0).sqrt() / mean } else { 0.0 }))
        }
        Workload::Trace { times } => {
            if times.len() < 2 {
                return None;
            }
            let gaps: Vec<f64> = times
                .windows(2)
                .map(|w| match w {
                    [a, b] => b.value() - a.value(),
                    _ => 0.0,
                })
                .collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            if mean <= 0.0 {
                return None;
            }
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            Some((mean, var.sqrt() / mean))
        }
    }
}

/// Drift between two workloads in canonical coordinates:
/// `|ln(mean_a/mean_b)| + 0.5 * |cv_a - cv_b|`.  Zero for identical
/// processes; ~0.7 for a 2x rate change; 0.5 for periodic vs Poisson at
/// the same rate.  `None` when either side is degenerate.
pub fn drift(a: &Workload, b: &Workload) -> Option<f64> {
    let (ma, cva) = canon(a)?;
    let (mb, cvb) = canon(b)?;
    if ma <= 0.0 || mb <= 0.0 {
        return None;
    }
    Some((ma / mb).ln().abs() + 0.5 * (cva - cvb).abs())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn log_uniform(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.range(lo.ln(), hi.ln()).exp()
    }

    /// Family recovery across the realistic parameter band the paper's
    /// scenarios span — the acceptance bar is >= 95% at n = 512.
    #[test]
    fn recovers_family_at_realistic_lengths() {
        const N: usize = 512;
        const DRAWS: usize = 200;
        let mut correct = [0usize; 3];
        for draw in 0..DRAWS {
            let mut rng = Rng::new(draw as u64 * 7919 + 1);

            let p = log_uniform(&mut rng, 1e-3, 1.0);
            let w = Workload::Periodic { period: Secs(p) };
            if fit_trace(&w.arrivals(N, &mut rng)).family == Family::Periodic {
                correct[0] += 1;
            }

            let m = log_uniform(&mut rng, 1e-3, 1.0);
            let w = Workload::Poisson { mean_gap: Secs(m) };
            if fit_trace(&w.arrivals(N, &mut rng)).family == Family::Poisson {
                correct[1] += 1;
            }

            let w = Workload::Bursty {
                burst_len: rng.int_range(4, 16) as u32,
                intra_gap: Secs(rng.range(5e-3, 50e-3)),
                burst_gap: Secs(rng.range(0.5, 5.0)),
            };
            if fit_trace(&w.arrivals(N, &mut rng)).family == Family::Bursty {
                correct[2] += 1;
            }
        }
        let floor = (DRAWS as f64 * 0.95) as usize;
        for (i, name) in ["periodic", "poisson", "bursty"].iter().enumerate() {
            assert!(
                correct[i] >= floor,
                "{name}: {}/{DRAWS} recovered (< 95%)",
                correct[i]
            );
        }
    }

    #[test]
    fn bursty_parameters_recovered() {
        // the har_wearable scenario's workload
        let w = Workload::Bursty {
            burst_len: 8,
            intra_gap: Secs::from_ms(30.0),
            burst_gap: Secs(2.0),
        };
        let report = fit_trace(&w.arrivals(512, &mut Rng::new(5)));
        assert_eq!(report.family, Family::Bursty);
        match report.fitted.unwrap() {
            Workload::Bursty {
                burst_len,
                intra_gap,
                burst_gap,
            } => {
                assert!((7..=9).contains(&burst_len), "burst_len {burst_len}");
                assert!((intra_gap.ms() - 30.0).abs() < 6.0, "intra {intra_gap}");
                assert!((burst_gap.value() - 2.0).abs() < 0.4, "sep {burst_gap}");
            }
            other => panic!("wrong family: {other:?}"),
        }
    }

    #[test]
    fn below_floor_refuses_to_guess() {
        let w = Workload::Periodic { period: Secs::from_ms(50.0) };
        let report = fit_trace(&w.arrivals(MIN_SAMPLES - 1, &mut Rng::new(1)));
        assert_eq!(report.family, Family::Unknown);
        assert!(report.fitted.is_none());
        assert!(report.describe().contains("keep-current"));
        // degenerate (all-identical timestamps) is also a refusal
        let same = vec![Secs(1.0); 64];
        assert_eq!(fit_trace(&same).family, Family::Unknown);
    }

    #[test]
    fn fit_is_deterministic() {
        let w = Workload::Poisson { mean_gap: Secs::from_ms(10.0) };
        let trace = w.arrivals(512, &mut Rng::new(9));
        let a = fit_trace(&trace);
        let b = fit_trace(&trace);
        assert_eq!(a.family, b.family);
        assert_eq!(a.stats.mean_gap, b.stats.mean_gap);
        assert_eq!(a.stats.cv, b.stats.cv);
    }

    #[test]
    fn drift_zero_for_identical_and_scales_with_rate() {
        let p50 = Workload::Periodic { period: Secs::from_ms(50.0) };
        assert_eq!(drift(&p50, &p50), Some(0.0));
        // same rate, different shape: CV term only
        let poi50 = Workload::Poisson { mean_gap: Secs::from_ms(50.0) };
        assert!((drift(&p50, &poi50).unwrap() - 0.5).abs() < 1e-12);
        // 10x rate change dominates
        let p500 = Workload::Periodic { period: Secs::from_ms(500.0) };
        assert!((drift(&p50, &p500).unwrap() - 10.0_f64.ln()).abs() < 1e-12);
        // symmetric
        assert_eq!(drift(&p50, &p500), drift(&p500, &p50));
    }

    #[test]
    fn drift_of_fitted_trace_matches_generator() {
        // a trace drawn *from* the deployed workload should show ~no drift
        let deployed = Workload::Bursty {
            burst_len: 8,
            intra_gap: Secs::from_ms(30.0),
            burst_gap: Secs(2.0),
        };
        let trace = deployed.arrivals(512, &mut Rng::new(3));
        let fitted = fit_trace(&trace).fitted.unwrap();
        let d = drift(&fitted, &deployed).unwrap();
        assert!(d < 0.25, "self-drift too large: {d}");
        // while a genuinely different process shows large drift
        let slow = Workload::Poisson { mean_gap: Secs(10.0) };
        assert!(drift(&fitted, &slow).unwrap() > 1.0);
    }

    #[test]
    fn canon_handles_trace_and_degenerate() {
        let t = Workload::Trace {
            times: vec![Secs(0.1), Secs(0.2), Secs(0.3)],
        };
        let (m, cv) = canon(&t).unwrap();
        assert!((m - 0.1).abs() < 1e-12);
        assert!(cv < 1e-6);
        assert!(canon(&Workload::Trace { times: vec![Secs(1.0)] }).is_none());
        // phased mean matches the analytic mean_gap
        let ph = Workload::Phased {
            fast_gap: Secs::from_ms(2.0),
            slow_gap: Secs::from_ms(30.0),
            phase_len: 10,
        };
        let (mean, cv) = canon(&ph).unwrap();
        assert!((mean - ph.mean_gap().value()).abs() < 1e-12);
        assert!(cv > 0.5, "phased cv {cv}");
    }

    #[test]
    fn histogram_covers_all_gaps() {
        let w = Workload::Bursty {
            burst_len: 4,
            intra_gap: Secs::from_ms(10.0),
            burst_gap: Secs(1.0),
        };
        let report = fit_trace(&w.arrivals(128, &mut Rng::new(1)));
        let total: usize = report.stats.histogram.iter().map(|(_, c)| c).sum();
        assert_eq!(total, report.stats.gaps);
        // bimodal: both an intra-gap bin and a separator bin are occupied
        let occupied = report.stats.histogram.iter().filter(|(_, c)| *c > 0).count();
        assert!(occupied >= 2);
    }
}
