//! Power and energy models.
//!
//! The dynamic model is the standard CMOS first-order form
//! `P_dyn ∝ C_switched · V² · f`, folded into a per-device calibration
//! constant (`dyn_mw_per_mhz_per_klut`, fitted so the Spartan-7 LSTM
//! accelerator lands in the published power envelope of [2]).  DSP and
//! BRAM blocks carry fixed per-MHz surcharges.
//!
//! Energy efficiency is reported as the paper does: GOPS/s/W over one
//! inference, with 1 MAC = 2 ops.

use crate::fpga::device::FpgaDevice;
use crate::rtl::composition::Accelerator;
use crate::util::units::{Hertz, Joules, Secs, Watts};

/// Per-MHz dynamic surcharge of hard blocks (mW), 28 nm baseline.
const DSP_MW_PER_MHZ: f64 = 0.018;
const BRAM_MW_PER_MHZ: f64 = 0.012;

/// Power breakdown of an accelerator on a device at a clock.
#[derive(Debug, Clone, Copy)]
pub struct PowerEstimate {
    pub static_w: Watts,
    pub dynamic_w: Watts,
}

impl PowerEstimate {
    pub fn total(&self) -> Watts {
        self.static_w + self.dynamic_w
    }
}

/// Dynamic + static power of `acc` running continuously on `device` at
/// `clock`.
pub fn power(acc: &Accelerator, device: &FpgaDevice, clock: Hertz) -> PowerEstimate {
    let r = acc.resources();
    let mhz = clock.mhz();
    // Node scaling applies to the *shared* hard-block surcharges only:
    // DSP_MW_PER_MHZ / BRAM_MW_PER_MHZ are one 28 nm-baseline constant for
    // the whole catalog, so older nodes scale them up.  The LUT term's
    // `dyn_mw_per_mhz_per_klut` is fitted per device and already carries
    // the process burn (lx9's 0.140 exceeds 0.085 * 45/28) — scaling it
    // again would double-count the node factor and skew cross-device
    // (xc7s vs ice40/lx9) comparisons.  Pinned by
    // `cross_node_dynamic_power_monotone` below.
    let hard_block_node_factor = device.node_nm as f64 / 28.0;
    let lut_mw = device.dyn_mw_per_mhz_per_klut * (r.luts as f64 / 1000.0) * mhz;
    let dsp_mw = DSP_MW_PER_MHZ * r.dsps as f64 * mhz * hard_block_node_factor;
    let bram_mw = BRAM_MW_PER_MHZ * r.bram18 as f64 * mhz * hard_block_node_factor;
    // weight active time by how busy each component keeps its logic
    let activity: f64 = if acc.components.is_empty() {
        1.0
    } else {
        acc.components
            .iter()
            .map(|c| c.active_fraction * c.cycles as f64)
            .sum::<f64>()
            / acc.cycles().max(1) as f64
    };
    PowerEstimate {
        static_w: device.static_power,
        dynamic_w: Watts::from_mw((lut_mw + dsp_mw + bram_mw) * activity),
    }
}

/// Energy of one inference (latency x total power).
pub fn energy_per_inference(acc: &Accelerator, device: &FpgaDevice, clock: Hertz) -> Joules {
    let p = power(acc, device, clock).total();
    p * acc.latency(clock)
}

/// The paper's headline metric: GOPS/s/W.
pub fn gops_per_watt(acc: &Accelerator, device: &FpgaDevice, clock: Hertz) -> f64 {
    let t: Secs = acc.latency(clock);
    let p = power(acc, device, clock).total();
    let gops = acc.ops() as f64 / t.value() / 1e9;
    gops / p.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;

    fn setup() -> (Accelerator, &'static FpgaDevice, Hertz) {
        (
            build(Topology::LstmHar, &BuildOpts::optimised(Q16_8)),
            device("xc7s15").unwrap(),
            Hertz::from_mhz(100.0),
        )
    }

    #[test]
    fn power_in_plausible_envelope() {
        let (acc, d, f) = setup();
        let p = power(&acc, d, f).total();
        // small Spartan-7 accelerator: tens of mW, far below 1 W
        assert!(p.value() > 0.01 && p.value() < 0.5, "total {p}");
    }

    #[test]
    fn dynamic_scales_with_clock() {
        let (acc, d, _) = setup();
        let p50 = power(&acc, d, Hertz::from_mhz(50.0)).dynamic_w;
        let p100 = power(&acc, d, Hertz::from_mhz(100.0)).dynamic_w;
        assert!((p100.value() / p50.value() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn energy_eff_in_paper_regime() {
        // the paper reports 5.57 (baseline) .. 12.98 (optimised) GOPS/s/W
        // for the LSTM accelerator; the model must land within an order of
        // magnitude and preserve the ordering
        let d = device("xc7s15").unwrap();
        let f = Hertz::from_mhz(100.0);
        let base = gops_per_watt(&build(Topology::LstmHar, &BuildOpts::baseline(Q16_8)), d, f);
        let opt = gops_per_watt(&build(Topology::LstmHar, &BuildOpts::optimised(Q16_8)), d, f);
        assert!(opt > base, "opt {opt} <= base {base}");
        assert!(base > 0.3 && base < 60.0, "baseline {base}");
        assert!(opt / base > 1.4 && opt / base < 3.5, "ratio {}", opt / base);
    }

    #[test]
    fn cross_node_dynamic_power_monotone() {
        // the same accelerator at the same clock must burn strictly more
        // dynamic power on the older node (lx9, 45 nm) than on Spartan-7
        // (28 nm): the per-device LUT coefficients are pre-scaled and the
        // shared hard-block surcharges carry the node factor, so both
        // terms move in the same direction and the comparison stays
        // consistent across devices
        let (acc, _, _) = setup();
        let s7 = device("xc7s15").unwrap();
        let s6 = device("lx9").unwrap();
        let f = Hertz::from_mhz(50.0);
        let p7 = power(&acc, s7, f).dynamic_w;
        let p6 = power(&acc, s6, f).dynamic_w;
        assert!(p6.value() > p7.value(), "lx9 {p6} !> xc7s15 {p7}");
        // the catalog invariant the LUT term relies on: the per-device
        // coefficient already includes at least the node burn, so it must
        // never be multiplied by the node factor again
        let node_ratio = s6.node_nm as f64 / s7.node_nm as f64;
        assert!(
            s6.dyn_mw_per_mhz_per_klut >= s7.dyn_mw_per_mhz_per_klut * node_ratio,
            "lx9 LUT coefficient is not pre-scaled"
        );
    }

    #[test]
    fn slower_clock_cuts_power_but_not_energy_much() {
        let (acc, d, _) = setup();
        let e100 = energy_per_inference(&acc, d, Hertz::from_mhz(100.0));
        let e25 = energy_per_inference(&acc, d, Hertz::from_mhz(25.0));
        // dynamic energy is frequency-independent to first order; the
        // static share grows as the run stretches
        assert!(e25.value() > e100.value());
    }
}
