//! Learnable-threshold strategy switching ([7]).
//!
//! The predefined break-even threshold is optimal only when the gap
//! prediction is; under irregular workloads the realised gaps scatter and
//! the fixed switch pays the wrong cost on both sides.  The learnable
//! variant runs multiplicative-weights ("Hedge") over a geometric grid of
//! candidate thresholds: after every gap, each expert is charged the
//! energy *it* would have spent on that gap, and the played threshold is
//! the weighted median of the ensemble.  This is a no-regret scheme — over
//! time the played threshold tracks the best fixed threshold in hindsight,
//! and under regime switches it re-adapts at the learning rate.

use super::{CostModel, PostAction, Strategy};
use crate::util::units::{Joules, Secs};

#[derive(Debug)]
pub struct LearnableThreshold {
    /// Candidate thresholds (geometric grid, seconds).
    grid: Vec<f64>,
    /// Hedge weights (log domain).
    log_w: Vec<f64>,
    /// Learning rate.
    eta: f64,
    /// A decision awaits its realised-gap feedback.
    pending: bool,
    /// Cost model captured at decision time (for the observe() update).
    last_cost: Option<CostModel>,
    /// Predicted gap at decision time.
    last_predicted: Secs,
}

impl LearnableThreshold {
    /// Grid spanning [lo, hi] with `n` geometric points.
    pub fn new(lo: Secs, hi: Secs, n: usize, eta: f64) -> LearnableThreshold {
        assert!(n >= 2 && hi.value() > lo.value() && lo.value() > 0.0);
        let ratio = (hi.value() / lo.value()).powf(1.0 / (n - 1) as f64);
        let grid: Vec<f64> = (0..n).map(|i| lo.value() * ratio.powi(i as i32)).collect();
        LearnableThreshold {
            log_w: vec![0.0; grid.len()],
            grid,
            eta,
            pending: false,
            last_cost: None,
            last_predicted: Secs(0.0),
        }
    }

    /// Default configuration: 24 thresholds from 1 ms to 30 s.
    pub fn default_grid() -> LearnableThreshold {
        LearnableThreshold::new(Secs::from_ms(1.0), Secs(30.0), 24, 0.25)
    }

    /// Current played threshold: weighted median of the grid.
    pub fn threshold(&self) -> Secs {
        let max = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let w: Vec<f64> = self.log_w.iter().map(|l| (l - max).exp()).collect();
        let total: f64 = w.iter().sum();
        let mut acc = 0.0;
        for (i, wi) in w.iter().enumerate() {
            acc += wi;
            if acc >= total / 2.0 {
                return Secs(self.grid[i]);
            }
        }
        Secs(*self.grid.last().unwrap())
    }

    /// Charge each expert the energy it would have spent on the *realised*
    /// gap had it applied its threshold to the *predicted* gap — i.e.
    /// experts are evaluated under the same imperfect predictor the node
    /// actually has, so the ensemble learns a threshold that compensates
    /// for prediction lag (the effect [7] exploits).  Losses are
    /// normalised by the worst expert so `eta` is scale-free.
    fn update(&mut self, cost: &CostModel, predicted: Secs, realized: Secs) {
        let losses: Vec<f64> = self
            .grid
            .iter()
            .map(|&th| {
                let action = if predicted.value() > th {
                    PostAction::PowerOff
                } else {
                    PostAction::StayIdle
                };
                cost.gap_energy(action, realized).value()
            })
            .collect();
        // regret against the round's best expert, on a *fixed* energy
        // scale (the cold-start cost) so high-stakes rounds move the
        // weights proportionally more than low-stakes ones — per-round
        // min-max normalisation would erase exactly the asymmetry the
        // learner needs to see.
        let min = losses.iter().cloned().fold(f64::MAX, f64::min);
        let scale = cost.cold_energy.value().max(1e-18);
        for (lw, loss) in self.log_w.iter_mut().zip(&losses) {
            let regret = ((loss - min) / scale).min(8.0);
            *lw -= self.eta * regret;
        }
        // keep the log-weights bounded
        let m = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for lw in &mut self.log_w {
            *lw -= m;
            *lw = lw.max(-50.0);
        }
    }

    /// Energy a fixed threshold would pay on a gap (used by tests/benches).
    pub fn fixed_threshold_energy(cost: &CostModel, th: Secs, gap: Secs) -> Joules {
        let action = if gap.value() > th.value() {
            PostAction::PowerOff
        } else {
            PostAction::StayIdle
        };
        cost.gap_energy(action, gap)
    }
}

impl Strategy for LearnableThreshold {
    fn name(&self) -> &'static str {
        "learnable-threshold"
    }

    fn decide(&mut self, cost: &CostModel, predicted_gap: Secs) -> PostAction {
        self.last_cost = Some(*cost);
        self.last_predicted = predicted_gap;
        self.pending = true;
        if predicted_gap.value() > self.threshold().value() {
            PostAction::PowerOff
        } else {
            PostAction::StayIdle
        }
    }

    fn observe(&mut self, realized_gap: Secs) {
        if let (true, Some(cost)) = (self.pending, self.last_cost) {
            self.update(&cost, self.last_predicted, realized_gap);
            self.pending = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Hertz, Watts};

    fn cost() -> CostModel {
        CostModel {
            cold_energy: Joules::from_mj(10.0),
            cold_time: Secs::from_ms(66.0),
            idle_power: Watts::from_mw(30.0),
            off_power: Watts::from_mw(0.9),
            busy_time: Secs::from_us(100.0),
            busy_power: Watts::from_mw(80.0),
            clock: Hertz::from_mhz(100.0),
            min_clock: Hertz::from_mhz(5.0),
        }
    }

    #[test]
    fn converges_to_idle_side_on_short_gaps() {
        let c = cost();
        let mut s = LearnableThreshold::default_grid();
        // constant 40ms gaps: best action is StayIdle -> threshold drifts up
        for _ in 0..500 {
            let _ = s.decide(&c, Secs::from_ms(40.0));
            s.observe(Secs::from_ms(40.0));
        }
        assert_eq!(s.decide(&c, Secs::from_ms(40.0)), PostAction::StayIdle);
        assert!(s.threshold().value() > 0.04, "th {}", s.threshold());
    }

    #[test]
    fn converges_to_off_side_on_long_gaps() {
        let c = cost();
        let mut s = LearnableThreshold::default_grid();
        for _ in 0..500 {
            let _ = s.decide(&c, Secs(5.0));
            s.observe(Secs(5.0));
        }
        assert_eq!(s.decide(&c, Secs(5.0)), PostAction::PowerOff);
        assert!(s.threshold().value() < 5.0);
    }

    #[test]
    fn readapts_after_regime_switch() {
        let c = cost();
        let mut s = LearnableThreshold::default_grid();
        for _ in 0..300 {
            let _ = s.decide(&c, Secs(5.0));
            s.observe(Secs(5.0));
        }
        let th_long = s.threshold().value();
        for _ in 0..300 {
            let _ = s.decide(&c, Secs::from_ms(20.0));
            s.observe(Secs::from_ms(20.0));
        }
        // after the switch to short gaps the threshold must move up past
        // the observed gap (choose idle)
        assert!(s.threshold().value() > 0.02, "before {} after {}", th_long, s.threshold());
    }

    #[test]
    fn grid_is_geometric_and_sorted() {
        let s = LearnableThreshold::new(Secs::from_ms(1.0), Secs(10.0), 16, 0.2);
        assert_eq!(s.grid.len(), 16);
        assert!(s.grid.windows(2).all(|w| w[1] > w[0]));
        assert!((s.grid[0] - 0.001).abs() < 1e-12);
        assert!((s.grid[15] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn observe_without_decide_is_noop() {
        let mut s = LearnableThreshold::default_grid();
        let before = s.threshold();
        s.observe(Secs(1.0));
        assert_eq!(before.value(), s.threshold().value());
    }
}
