//! Workload-aware strategies (RQ2, §3.2, [6,7]).
//!
//! After every served request the node must decide what to do with the
//! FPGA until the next one:
//!
//! * **On-Off** — power the rail down; pay `powerup + configuration`
//!   (time *and* energy, MCU + flash + FPGA) on the next request.
//! * **Idle-Waiting** — keep the fabric configured; pay idle power for the
//!   whole gap ([6]'s contribution: at short periods this wins by an order
//!   of magnitude).
//! * **Clock-Scaling** — stretch the inference across the expected gap at
//!   a reduced clock, trading peak power for the idle window.
//! * **Adaptive (predefined threshold)** — Off when the expected gap
//!   exceeds the break-even threshold `E_cold / P_idle`, Idle otherwise.
//! * **Adaptive (learnable threshold)** — the same switch driven by an
//!   online expert ensemble over candidate thresholds, updated with the
//!   realised energy regret of each expert ([7]).

pub mod learnable;

use crate::util::units::{Hertz, Joules, Secs, Watts};

/// What to do with the fabric after completing a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PostAction {
    PowerOff,
    StayIdle,
}

/// The cost constants a strategy trades against (device + accelerator +
/// board, all precomputed by the simulator).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Full cold-start energy: power-up ramp + configuration, including
    /// MCU/flash streaming overhead.
    pub cold_energy: Joules,
    /// Cold-start latency.
    pub cold_time: Secs,
    /// Power while configured and idle (FPGA static + MCU sleep).
    pub idle_power: Watts,
    /// Power while off (MCU sleep only).
    pub off_power: Watts,
    /// Inference latency at the nominal clock.
    pub busy_time: Secs,
    /// Power during inference at the nominal clock.
    pub busy_power: Watts,
    /// Nominal clock.
    pub clock: Hertz,
    /// Minimum clock the design can be scaled down to.
    pub min_clock: Hertz,
}

impl CostModel {
    /// The break-even gap: beyond this, powering off saves energy.
    /// `P_idle * g = E_cold + P_off * g  =>  g* = E_cold / (P_idle - P_off)`.
    pub fn breakeven_gap(&self) -> Secs {
        let dp = (self.idle_power.value() - self.off_power.value()).max(1e-12);
        Secs(self.cold_energy.value() / dp)
    }

    /// Energy consumed across a gap of length `g` for each action.
    pub fn gap_energy(&self, action: PostAction, g: Secs) -> Joules {
        match action {
            PostAction::StayIdle => self.idle_power * g,
            PostAction::PowerOff => self.cold_energy + self.off_power * g,
        }
    }

    /// A copy with multiplicative calibration corrections applied to the
    /// energy constants.  The estimator↔simulator calibration loop
    /// (`generator::calibrate`) fits one multiplier per energy term —
    /// busy power (the `dyn_mw_per_mhz_per_klut` + DSP/BRAM surcharge
    /// share), idle overhead, off overhead, cold-start energy — against
    /// DES ledgers and feeds them back through this hook.  Time constants
    /// are left untouched: the fit corrects joules, not latency.
    pub fn with_corrections(&self, busy: f64, idle: f64, off: f64, cold: f64) -> CostModel {
        CostModel {
            cold_energy: Joules(self.cold_energy.value() * cold),
            idle_power: Watts(self.idle_power.value() * idle),
            off_power: Watts(self.off_power.value() * off),
            busy_power: Watts(self.busy_power.value() * busy),
            ..*self
        }
    }
}

/// Strategy interface: consulted after each completed request.
pub trait Strategy: Send {
    fn name(&self) -> &'static str;

    /// Decision for the upcoming gap.  `predicted_gap` is the node's
    /// current estimate of the time until the next request.
    fn decide(&mut self, cost: &CostModel, predicted_gap: Secs) -> PostAction;

    /// Clock to run the *next* inference at (clock-scaling strategies
    /// deviate from nominal).
    fn clock(&self, cost: &CostModel, predicted_gap: Secs) -> Hertz {
        let _ = predicted_gap;
        cost.clock
    }

    /// Feedback: the realised gap that followed the last decision.
    fn observe(&mut self, realized_gap: Secs) {
        let _ = realized_gap;
    }
}

/// Always power off (the traditional duty-cycling baseline).
#[derive(Debug, Default)]
pub struct OnOff;

impl Strategy for OnOff {
    fn name(&self) -> &'static str {
        "on-off"
    }

    fn decide(&mut self, _cost: &CostModel, _gap: Secs) -> PostAction {
        PostAction::PowerOff
    }
}

/// Always stay configured ([6]).
#[derive(Debug, Default)]
pub struct IdleWait;

impl Strategy for IdleWait {
    fn name(&self) -> &'static str {
        "idle-wait"
    }

    fn decide(&mut self, _cost: &CostModel, _gap: Secs) -> PostAction {
        PostAction::StayIdle
    }
}

/// Stay configured and stretch the next inference across the predicted
/// gap by lowering the clock (dynamic power scales with f, so the busy
/// energy stays ~constant while the high-power window widens to swallow
/// the idle window).
#[derive(Debug, Default)]
pub struct ClockScale;

impl Strategy for ClockScale {
    fn name(&self) -> &'static str {
        "clock-scale"
    }

    fn decide(&mut self, _cost: &CostModel, _gap: Secs) -> PostAction {
        PostAction::StayIdle
    }

    fn clock(&self, cost: &CostModel, predicted_gap: Secs) -> Hertz {
        if predicted_gap.value() <= cost.busy_time.value() {
            return cost.clock;
        }
        // choose f so that busy_time * (f_nom / f) ~ 0.9 * gap
        let stretch = 0.9 * predicted_gap.value() / cost.busy_time.value();
        let f = cost.clock.value() / stretch;
        Hertz(f.clamp(cost.min_clock.value(), cost.clock.value()))
    }
}

/// Threshold switch with the analytically precomputed break-even point.
#[derive(Debug)]
pub struct PredefinedThreshold {
    threshold: Option<Secs>,
}

impl PredefinedThreshold {
    /// Use the cost model's break-even gap.
    pub fn breakeven() -> PredefinedThreshold {
        PredefinedThreshold { threshold: None }
    }

    /// Fix an explicit threshold.
    pub fn at(threshold: Secs) -> PredefinedThreshold {
        PredefinedThreshold {
            threshold: Some(threshold),
        }
    }
}

/// The threshold a designer would precompute from FPGA datasheet numbers
/// alone — configuration energy and static power, *without* the
/// board-level MCU/flash streaming overheads the deployed node actually
/// pays.  This is the realistic "predefined" baseline of [7]: the
/// learnable scheme's advantage is discovering the deployment's true
/// crossover (see benches/e4_adaptive.rs).
pub fn datasheet_breakeven(device: &'static crate::fpga::FpgaDevice) -> Secs {
    let cfg = crate::fpga::ConfigController::raw(device);
    Secs(cfg.cold_start_energy().value() / device.static_power.value().max(1e-12))
}

impl Strategy for PredefinedThreshold {
    fn name(&self) -> &'static str {
        "predefined-threshold"
    }

    fn decide(&mut self, cost: &CostModel, predicted_gap: Secs) -> PostAction {
        let th = self.threshold.unwrap_or_else(|| cost.breakeven_gap());
        if predicted_gap.value() > th.value() {
            PostAction::PowerOff
        } else {
            PostAction::StayIdle
        }
    }
}

/// Exponential-moving-average gap predictor shared by the adaptive
/// strategies and the simulator.
#[derive(Debug, Clone)]
pub struct GapPredictor {
    ema: Option<f64>,
    alpha: f64,
}

impl GapPredictor {
    pub fn new(alpha: f64) -> GapPredictor {
        assert!((0.0..=1.0).contains(&alpha));
        GapPredictor { ema: None, alpha }
    }

    pub fn observe(&mut self, gap: Secs) {
        self.ema = Some(match self.ema {
            None => gap.value(),
            Some(e) => e * (1.0 - self.alpha) + gap.value() * self.alpha,
        });
    }

    pub fn predict(&self) -> Option<Secs> {
        self.ema.map(Secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel {
            cold_energy: Joules::from_mj(10.0),
            cold_time: Secs::from_ms(66.0),
            idle_power: Watts::from_mw(30.0),
            off_power: Watts::from_mw(0.9),
            busy_time: Secs::from_us(100.0),
            busy_power: Watts::from_mw(80.0),
            clock: Hertz::from_mhz(100.0),
            min_clock: Hertz::from_mhz(5.0),
        }
    }

    #[test]
    fn breakeven_formula() {
        let c = cost();
        // 10 mJ / 29.1 mW ~ 0.344 s
        assert!((c.breakeven_gap().value() - 0.010 / 0.0291).abs() < 1e-6);
    }

    #[test]
    fn gap_energy_crossover() {
        let c = cost();
        let g_short = Secs::from_ms(40.0);
        let g_long = Secs(2.0);
        assert!(
            c.gap_energy(PostAction::StayIdle, g_short).value()
                < c.gap_energy(PostAction::PowerOff, g_short).value()
        );
        assert!(
            c.gap_energy(PostAction::PowerOff, g_long).value()
                < c.gap_energy(PostAction::StayIdle, g_long).value()
        );
    }

    #[test]
    fn predefined_switches_at_threshold() {
        let c = cost();
        let mut s = PredefinedThreshold::breakeven();
        assert_eq!(s.decide(&c, Secs::from_ms(40.0)), PostAction::StayIdle);
        assert_eq!(s.decide(&c, Secs(1.0)), PostAction::PowerOff);
    }

    #[test]
    fn clock_scaling_stretches() {
        let c = cost();
        let s = ClockScale;
        let f = s.clock(&c, Secs::from_ms(10.0));
        assert!(f.value() < c.clock.value());
        assert!(f.value() >= c.min_clock.value());
        // gap shorter than inference: no scaling
        assert_eq!(s.clock(&c, Secs::from_us(50.0)).value(), c.clock.value());
    }

    #[test]
    fn gap_predictor_ema() {
        let mut p = GapPredictor::new(0.5);
        assert!(p.predict().is_none());
        p.observe(Secs(1.0));
        p.observe(Secs(2.0));
        assert!((p.predict().unwrap().value() - 1.5).abs() < 1e-12);
    }
}
