//! Vendor-style report rendering (the "EDA Tool Analysis" output of §2.3):
//! utilisation, timing and power sections in the familiar Vivado
//! `report_utilization` shape, so downstream users can eyeball generated
//! designs the way they would a real run.

use super::synth::SynthResult;
use super::timing;
use crate::fpga::device::FpgaDevice;
use crate::power::PowerEstimate;
use crate::rtl::composition::Accelerator;
use crate::util::table::{num, Table};
use crate::util::units::Hertz;

/// Complete design report for one (accelerator, device, clock) triple.
#[derive(Debug, Clone)]
pub struct DesignReport {
    pub design: String,
    pub device: String,
    pub synth: SynthResult,
    pub fmax: Hertz,
    pub clock: Hertz,
    pub slack_ns: f64,
    pub power: PowerEstimate,
    pub cycles: u64,
    pub latency_us: f64,
    pub gops_per_watt: f64,
}

/// Build the full report.
pub fn report(
    acc: &Accelerator,
    device: &FpgaDevice,
    clock: Hertz,
) -> DesignReport {
    let synth = super::synth::synthesize(acc, device);
    let fmax = timing::fmax(&synth, device);
    let power = crate::power::power(acc, device, clock);
    DesignReport {
        design: acc.name.clone(),
        device: device.name.to_string(),
        slack_ns: timing::slack_ns(&synth, device, clock),
        fmax,
        clock,
        power,
        cycles: acc.cycles(),
        latency_us: acc.latency(clock).us(),
        gops_per_watt: crate::power::gops_per_watt(acc, device, clock),
        synth,
    }
}

impl DesignReport {
    pub fn timing_met(&self) -> bool {
        self.slack_ns >= 0.0
    }

    /// Render the three report sections as text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Design Report: {} on {} @ {:.1} MHz\n\n",
            self.design,
            self.device,
            self.clock.mhz()
        ));

        let mut util = Table::new(&["Resource", "Used", "Available", "Util%"])
            .with_title("1. Utilization");
        let rows = [
            ("LUT", self.synth.mapped.luts, self.synth.capacity.luts),
            ("FF", self.synth.mapped.ffs, self.synth.capacity.ffs),
            ("BRAM18", self.synth.mapped.bram18, self.synth.capacity.bram18),
            ("DSP", self.synth.mapped.dsps, self.synth.capacity.dsps),
        ];
        for (name, used, avail) in rows {
            let pct = if avail == 0 {
                "-".to_string()
            } else {
                num(100.0 * used as f64 / avail as f64, 1)
            };
            util.row(&[name.to_string(), used.to_string(), avail.to_string(), pct]);
        }
        out.push_str(&util.render());
        out.push('\n');

        let mut t = Table::new(&["Metric", "Value"]).with_title("2. Timing");
        t.row(&["Critical path (ns)".into(), num(self.synth.crit_path_ns, 2)]);
        t.row(&["Fmax (MHz)".into(), num(self.fmax.mhz(), 1)]);
        t.row(&["Requested (MHz)".into(), num(self.clock.mhz(), 1)]);
        t.row(&["WNS (ns)".into(), num(self.slack_ns, 2)]);
        t.row(&[
            "Timing".into(),
            if self.timing_met() { "MET" } else { "VIOLATED" }.into(),
        ]);
        out.push_str(&t.render());
        out.push('\n');

        let mut p = Table::new(&["Metric", "Value"]).with_title("3. Power / Performance");
        p.row(&["Static (mW)".into(), num(self.power.static_w.mw(), 2)]);
        p.row(&["Dynamic (mW)".into(), num(self.power.dynamic_w.mw(), 2)]);
        p.row(&["Total (mW)".into(), num(self.power.total().mw(), 2)]);
        p.row(&["Cycles/inf".into(), self.cycles.to_string()]);
        p.row(&["Latency (us)".into(), num(self.latency_us, 2)]);
        p.row(&["GOPS/s/W".into(), num(self.gops_per_watt, 2)]);
        out.push_str(&p.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn report_sections_render() {
        let acc = build(Topology::LstmHar, &BuildOpts::optimised(Q16_8));
        let r = report(&acc, device("xc7s15").unwrap(), Hertz::from_mhz(100.0));
        let text = r.render();
        assert!(text.contains("1. Utilization"));
        assert!(text.contains("2. Timing"));
        assert!(text.contains("3. Power / Performance"));
        assert!(text.contains("GOPS/s/W"));
    }

    #[test]
    fn report_values_consistent() {
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let r = report(&acc, device("xc7s15").unwrap(), Hertz::from_mhz(100.0));
        assert_eq!(r.cycles, acc.cycles());
        assert!(r.timing_met());
        assert!(r.gops_per_watt > 0.0);
    }
}
