//! EDA tool substitute (§2.3): analytical synthesis (technology mapping +
//! capacity), timing analysis (fmax with congestion) and vendor-style
//! report rendering.

pub mod report;
pub mod synth;
pub mod timing;

pub use report::{report, DesignReport};
pub use synth::{synthesize, SynthResult, TechFactors};
pub use timing::{fmax, meets_timing, slack_ns};
