//! Timing analysis: achievable clock from the mapped critical path plus a
//! routing-congestion penalty that grows with utilisation (the familiar
//! "timing collapses when the device fills up" effect every Vivado user
//! knows), capped by the family fabric ceiling.

use super::synth::SynthResult;
use crate::fpga::device::FpgaDevice;
use crate::util::units::Hertz;

/// Routing delay added on top of the logic path, as a function of
/// utilisation: negligible below ~50 %, steep past ~80 %.
pub fn routing_penalty_ns(logic_ns: f64, utilization: f64) -> f64 {
    let u = utilization.clamp(0.0, 1.2);
    // smooth convex penalty: 8% of logic delay at u=0.5, ~60% at u=0.9
    let frac = 0.04 + 0.75 * u.powi(4);
    logic_ns * frac
}

/// Achievable fmax for a mapped design.
pub fn fmax(synth: &SynthResult, device: &FpgaDevice) -> Hertz {
    let total_ns = synth.crit_path_ns + routing_penalty_ns(synth.crit_path_ns, synth.utilization);
    let f = 1e9 / total_ns;
    Hertz(f.min(device.fmax_ceiling.value()))
}

/// Timing closure check at a requested clock.
pub fn meets_timing(synth: &SynthResult, device: &FpgaDevice, clock: Hertz) -> bool {
    fmax(synth, device).value() >= clock.value()
}

/// Worst negative slack (ns) at the requested clock; positive = met.
pub fn slack_ns(synth: &SynthResult, device: &FpgaDevice, clock: Hertz) -> f64 {
    let period = 1e9 / clock.value();
    let path = 1e9 / fmax(synth, device).value();
    period - path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eda::synth::synthesize;
    use crate::fpga::device::device;
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn optimised_design_closes_100mhz_on_s15() {
        // the E8/[11] claim: the (pipelined, hard-activation) MLP runs at
        // 100 MHz on XC7S15
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let d = device("xc7s15").unwrap();
        let s = synthesize(&acc, d);
        assert!(meets_timing(&s, d, Hertz::from_mhz(100.0)), "fmax {}", fmax(&s, d));
    }

    #[test]
    fn lx9_slower_than_s15() {
        // [10] vs [11]: the Spartan-6 predecessor closed at 50 MHz only
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let f_lx9 = fmax(&synthesize(&acc, device("lx9").unwrap()), device("lx9").unwrap());
        let f_s15 = fmax(&synthesize(&acc, device("xc7s15").unwrap()), device("xc7s15").unwrap());
        assert!(f_lx9.value() < f_s15.value());
    }

    #[test]
    fn congestion_penalty_grows() {
        assert!(routing_penalty_ns(5.0, 0.9) > routing_penalty_ns(5.0, 0.3) * 3.0);
    }

    #[test]
    fn slack_sign_matches_closure() {
        let acc = build(Topology::LstmHar, &BuildOpts::baseline(Q16_8));
        let d = device("xc7s15").unwrap();
        let s = synthesize(&acc, d);
        let clk = Hertz::from_mhz(100.0);
        assert_eq!(meets_timing(&s, d, clk), slack_ns(&s, d, clk) >= 0.0);
    }

    #[test]
    fn fmax_capped_by_ceiling() {
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let d = device("ice40up5k").unwrap();
        let f = fmax(&synthesize(&acc, d), d);
        assert!(f.value() <= d.fmax_ceiling.value());
    }
}
