//! Analytical technology mapping ("synthesis").
//!
//! Template profiles are expressed in 7-series-equivalent units; mapping to
//! a concrete device applies family technology factors (4-input iCE40 LUTs
//! absorb less logic than 6-input 7-series LUTs, Spartan-6 sits between)
//! and checks capacity.  This is the stand-in for Vivado/Radiant described
//! in DESIGN.md §2 — the Generator consumes exactly the numbers a vendor
//! utilisation report would give it.

use crate::fpga::device::{Family, FpgaDevice, Resources};
use crate::rtl::composition::Accelerator;

/// Per-family technology factors relative to the 7-series baseline.
#[derive(Debug, Clone, Copy)]
pub struct TechFactors {
    /// LUT inflation (how many native LUTs per 6-input-equivalent LUT).
    pub lut: f64,
    /// FF inflation.
    pub ff: f64,
    /// Combinational delay scaling (fabric speed).
    pub delay: f64,
}

pub fn tech_factors(family: Family) -> TechFactors {
    match family {
        Family::Spartan7 => TechFactors { lut: 1.0, ff: 1.0, delay: 1.0 },
        Family::Spartan6 => TechFactors { lut: 1.15, ff: 1.0, delay: 1.45 },
        Family::Ice40 => TechFactors { lut: 1.6, ff: 1.0, delay: 1.9 },
    }
}

/// Result of mapping an accelerator onto a device.
#[derive(Debug, Clone)]
pub struct SynthResult {
    pub mapped: Resources,
    pub capacity: Resources,
    pub fits: bool,
    /// Worst-dimension utilisation (>= 1.0 when over capacity).
    pub utilization: f64,
    /// Post-mapping combinational delay in ns.
    pub crit_path_ns: f64,
}

/// Map `acc` onto `device`.
pub fn synthesize(acc: &Accelerator, device: &FpgaDevice) -> SynthResult {
    let tf = tech_factors(device.family);
    let raw = acc.resources();
    let mapped = Resources {
        luts: (raw.luts as f64 * tf.lut).ceil() as u32,
        ffs: (raw.ffs as f64 * tf.ff).ceil() as u32,
        bram18: raw.bram18,
        dsps: raw.dsps,
    };
    let utilization = mapped.utilization(&device.resources);
    SynthResult {
        mapped,
        capacity: device.resources,
        fits: mapped.fits_in(&device.resources),
        utilization,
        crit_path_ns: acc.crit_path_ns() * tf.delay,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn ice40_inflates_luts() {
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let s7 = synthesize(&acc, device("xc7s15").unwrap());
        let ice = synthesize(&acc, device("ice40up5k").unwrap());
        assert!(ice.mapped.luts > s7.mapped.luts);
        assert!(ice.crit_path_ns > s7.crit_path_ns);
    }

    #[test]
    fn capacity_check() {
        let acc = build(Topology::CnnEcg, &BuildOpts::optimised(Q16_8));
        let on_s25 = synthesize(&acc, device("xc7s25").unwrap());
        assert!(on_s25.fits, "util {}", on_s25.utilization);
    }

    #[test]
    fn utilization_consistent_with_fits() {
        for t in Topology::all() {
            let acc = build(*t, &BuildOpts::baseline(Q16_8));
            for d in crate::fpga::device::DEVICES {
                let s = synthesize(&acc, d);
                assert_eq!(s.fits, s.utilization <= 1.0, "{} on {}", t.name(), d.name);
            }
        }
    }
}
