//! Bit-true Q-format fixed-point arithmetic.
//!
//! Exact mirror of `python/compile/quant.py` — the cross-layer contract:
//! quantisation is `floor(x * 2^f + 0.5)` saturated to the signed
//! `total_bits` range; post-multiply rescaling is `sra_round`
//! (add `1 << (n-1)`, arithmetic shift right by `n`).  The behavioural
//! simulator (GHDL substitute) executes the same schedule as the compiled
//! HLO on these primitives, so the pure-integer activation variants agree
//! bit-for-bit across Rust / Pallas / PJRT.

/// Signed fixed-point format: `total_bits` wide, `frac_bits` fractional.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QFormat {
    pub total_bits: u32,
    pub frac_bits: u32,
}

/// 16-bit Q8.8 — the default datapath of the LSTM accelerator [2].
pub const Q16_8: QFormat = QFormat { total_bits: 16, frac_bits: 8 };
/// Reduced-precision exploration points.
pub const Q12_6: QFormat = QFormat { total_bits: 12, frac_bits: 6 };
pub const Q8_4: QFormat = QFormat { total_bits: 8, frac_bits: 4 };

impl QFormat {
    pub fn new(total_bits: u32, frac_bits: u32) -> QFormat {
        assert!((2..=26).contains(&total_bits), "total_bits {total_bits}");
        assert!(frac_bits > 0 && frac_bits < total_bits, "frac_bits {frac_bits}");
        QFormat { total_bits, frac_bits }
    }

    /// Parse "q16_8"-style names (the manifest encoding).
    pub fn parse(name: &str) -> Option<QFormat> {
        let rest = name.strip_prefix('q')?;
        let (t, f) = rest.split_once('_')?;
        Some(QFormat::new(t.parse().ok()?, f.parse().ok()?))
    }

    pub fn name(&self) -> String {
        format!("q{}_{}", self.total_bits, self.frac_bits)
    }

    #[inline]
    pub fn scale(&self) -> i64 {
        1 << self.frac_bits
    }

    #[inline]
    pub fn qmin(&self) -> i64 {
        -(1 << (self.total_bits - 1))
    }

    #[inline]
    pub fn qmax(&self) -> i64 {
        (1 << (self.total_bits - 1)) - 1
    }

    pub fn resolution(&self) -> f64 {
        1.0 / self.scale() as f64
    }

    /// f64 -> Q value: floor(x * 2^f + 0.5), saturated.
    #[inline]
    pub fn quantize(&self, x: f64) -> i64 {
        let q = (x * self.scale() as f64 + 0.5).floor();
        if q <= self.qmin() as f64 {
            self.qmin()
        } else if q >= self.qmax() as f64 {
            self.qmax()
        } else {
            q as i64
        }
    }

    #[inline]
    pub fn dequantize(&self, q: i64) -> f64 {
        q as f64 * self.resolution()
    }

    #[inline]
    pub fn saturate(&self, q: i64) -> i64 {
        q.clamp(self.qmin(), self.qmax())
    }

    /// Rescale a product of two Q(f) values (at 2f scale) back to Q(f).
    #[inline]
    pub fn requant_product(&self, p: i64) -> i64 {
        self.saturate(sra_round(p, self.frac_bits))
    }

    pub fn quantize_vec(&self, xs: &[f64]) -> Vec<i64> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    pub fn dequantize_vec(&self, qs: &[i64]) -> Vec<f64> {
        qs.iter().map(|&q| self.dequantize(q)).collect()
    }
}

/// Arithmetic shift right with round-half-up: `(p + (1 << (n-1))) >> n`.
/// `n == 0` is the identity (matches `quant.sra_round`).
#[inline]
pub fn sra_round(p: i64, n: u32) -> i64 {
    if n == 0 {
        p
    } else {
        (p + (1i64 << (n - 1))) >> n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_matches_python_semantics() {
        let f = Q16_8;
        // floor(x * 256 + 0.5)
        assert_eq!(f.quantize(1.0), 256);
        assert_eq!(f.quantize(0.001953125), 1); // exactly 0.5 LSB rounds up
        assert_eq!(f.quantize(-0.001953125), 0); // -0.5 LSB rounds up to 0
        assert_eq!(f.quantize(1000.0), f.qmax());
        assert_eq!(f.quantize(-1000.0), f.qmin());
    }

    #[test]
    fn sra_round_matches_python() {
        // same cases as python/tests/test_quant.py::TestSraRound
        assert_eq!(sra_round(3, 2), 1);
        assert_eq!(sra_round(-3, 2), -1);
        assert_eq!(sra_round(2, 2), 1);
        assert_eq!(sra_round(-2, 2), 0);
        assert_eq!(sra_round(-5, 0), -5);
    }

    #[test]
    fn grid_roundtrip() {
        let f = Q12_6;
        for q in f.qmin()..=f.qmax() {
            assert_eq!(f.quantize(f.dequantize(q)), q);
        }
    }

    #[test]
    fn product_requant() {
        let f = Q16_8;
        let one = f.scale();
        assert_eq!(f.requant_product(one * one), one);
        // 1.5 * 2.0 = 3.0
        let a = f.quantize(1.5);
        let b = f.quantize(2.0);
        assert_eq!(f.dequantize(f.requant_product(a * b)), 3.0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(QFormat::parse("q16_8"), Some(Q16_8));
        assert_eq!(QFormat::parse("q12_6"), Some(Q12_6));
        assert_eq!(QFormat::parse("garbage"), None);
        assert_eq!(Q8_4.name(), "q8_4");
    }

    #[test]
    #[should_panic]
    fn rejects_overflowing_format() {
        QFormat::new(32, 16);
    }
}
