//! Activation-function RTL template variants (RQ1).
//!
//! Functional semantics are the bit-true mirror of
//! `python/compile/kernels/activations.py`; hardware costs are the
//! per-variant synthesis profile the Generator's analytical models consume
//! (calibrated to the template library of [2,5]):
//!
//! | impl  | datapath                    | LUT | FF | BRAM | DSP | lat | II |
//! |-------|-----------------------------|-----|----|------|-----|-----|----|
//! | Exact | iterative polynomial/CORDIC | 520 | 380| 0    | 2   | 12  | 4  |
//! | Pla   | PLAN shift+add segments     | 96  | 60 | 0    | 0   | 2   | 1  |
//! | Lut   | 256-entry BRAM table        | 24  | 20 | 1    | 0   | 2   | 1  |
//! | Hard  | shift + clamp               | 18  | 16 | 0    | 0   | 1   | 1  |
//!
//! `lat` is result latency in cycles, `II` the initiation interval (results
//! per cycle once the pipeline is primed).

use super::fixed_point::{sra_round, QFormat};
use crate::fpga::device::Resources;

/// Which mathematical function.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    Sigmoid,
    Tanh,
    HardSigmoid,
    HardTanh,
}

/// Which RTL implementation style.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActImpl {
    Exact,
    Pla,
    Lut,
    Hard,
}

/// A concrete activation variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActVariant {
    pub kind: ActKind,
    pub imp: ActImpl,
}

/// LUT variant geometry (mirrors activations.py).
pub const LUT_LO: f64 = -8.0;
pub const LUT_HI: f64 = 8.0;
pub const LUT_SIZE: usize = 256;

impl ActVariant {
    pub fn new(kind: ActKind, imp: ActImpl) -> ActVariant {
        ActVariant { kind, imp }
    }

    /// Parse the manifest encoding, e.g. ("sigmoid", "pla").
    pub fn parse(kind: &str, imp: &str) -> Option<ActVariant> {
        let kind = match kind {
            "sigmoid" => ActKind::Sigmoid,
            "tanh" => ActKind::Tanh,
            "hardsigmoid" => ActKind::HardSigmoid,
            "hardtanh" => ActKind::HardTanh,
            _ => return None,
        };
        let imp = match imp {
            "exact" => ActImpl::Exact,
            "pla" => ActImpl::Pla,
            "lut" => ActImpl::Lut,
            "hard" => ActImpl::Hard,
            _ => return None,
        };
        Some(ActVariant { kind, imp })
    }

    // -- functional semantics (bit-true) ------------------------------------

    /// Apply the variant to one Q value.
    pub fn eval(&self, q: i64, fmt: QFormat) -> i64 {
        match (self.kind, self.imp) {
            (ActKind::Sigmoid, ActImpl::Exact) => {
                fmt.quantize(sigmoid_f64(fmt.dequantize(q)))
            }
            (ActKind::Sigmoid, ActImpl::Pla) => sigmoid_pla(q, fmt),
            (ActKind::Sigmoid, ActImpl::Lut) => lut_eval(q, fmt, ActKind::Sigmoid),
            (ActKind::Tanh, ActImpl::Exact) => fmt.quantize(fmt.dequantize(q).tanh()),
            (ActKind::Tanh, ActImpl::Pla) => tanh_pla(q, fmt),
            (ActKind::Tanh, ActImpl::Lut) => lut_eval(q, fmt, ActKind::Tanh),
            (ActKind::HardSigmoid, _) => hardsigmoid(q, fmt),
            (ActKind::HardTanh, _) => hardtanh(q, fmt),
            // manifest encoding: ("sigmoid", "hard") means the hard variant
            // substituted at the sigmoid position (and likewise for tanh)
            (ActKind::Sigmoid, ActImpl::Hard) => hardsigmoid(q, fmt),
            (ActKind::Tanh, ActImpl::Hard) => hardtanh(q, fmt),
        }
    }

    pub fn eval_vec(&self, qs: &[i64], fmt: QFormat) -> Vec<i64> {
        qs.iter().map(|&q| self.eval(q, fmt)).collect()
    }

    /// Worst-case absolute error vs the real-valued function, in LSBs of
    /// `fmt` (analytical precision model used as a DSE constraint).
    pub fn max_error_lsb(&self, fmt: QFormat) -> f64 {
        let lsb = fmt.resolution();
        match self.imp {
            ActImpl::Exact | ActImpl::Hard => 1.0,
            // PLAN: published max error 0.0189 for sigmoid; tanh doubles it
            ActImpl::Pla => {
                let base = match self.kind {
                    ActKind::Sigmoid => 0.0189,
                    ActKind::Tanh => 2.0 * 0.0189,
                    _ => 0.0,
                };
                base / lsb + 1.0
            }
            // LUT: half-cell * max slope + rounding
            ActImpl::Lut => {
                let cell = (LUT_HI - LUT_LO) / LUT_SIZE as f64;
                let slope = match self.kind {
                    ActKind::Sigmoid => 0.25,
                    ActKind::Tanh => 1.0,
                    _ => 0.0,
                };
                (cell / 2.0 * slope) / lsb + 1.0
            }
        }
    }

    // -- hardware profile ----------------------------------------------------

    pub fn resources(&self) -> Resources {
        match self.imp {
            ActImpl::Exact => Resources::new(520, 380, 0, 2),
            ActImpl::Pla => Resources::new(96, 60, 0, 0),
            ActImpl::Lut => Resources::new(24, 20, 1, 0),
            ActImpl::Hard => Resources::new(18, 16, 0, 0),
        }
    }

    /// Result latency in cycles.
    pub fn latency(&self) -> u64 {
        match self.imp {
            ActImpl::Exact => 12,
            ActImpl::Pla | ActImpl::Lut => 2,
            ActImpl::Hard => 1,
        }
    }

    /// Initiation interval (cycles between consecutive inputs).
    pub fn ii(&self) -> u64 {
        match self.imp {
            ActImpl::Exact => 4,
            _ => 1,
        }
    }

    /// Combinational path through the unit in ns (drives the fmax model).
    pub fn logic_delay_ns(&self) -> f64 {
        match self.imp {
            ActImpl::Exact => 7.5,
            ActImpl::Pla => 4.8,
            ActImpl::Lut => 4.2,
            ActImpl::Hard => 3.5,
        }
    }
}

// ---------------------------------------------------------------------------
// bit-true implementations (mirror activations.py exactly for the
// pure-integer paths; Exact routes through f64 and agrees within 1 LSB)
// ---------------------------------------------------------------------------

fn sigmoid_f64(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// PLAN sigmoid for q >= 0 (see activations.py::_plan_positive).
fn plan_positive(q: i64, fmt: QFormat) -> i64 {
    let one = fmt.scale();
    let b1 = one;
    let b2 = (19 * one) >> 3;
    let b3 = 5 * one;
    if q < b1 {
        sra_round(q, 2) + (one >> 1)
    } else if q < b2 {
        sra_round(q, 3) + ((5 * one) >> 3)
    } else if q < b3 {
        sra_round(q, 5) + ((27 * one) >> 5)
    } else {
        one
    }
}

pub fn sigmoid_pla(q: i64, fmt: QFormat) -> i64 {
    let one = fmt.scale();
    let pos = plan_positive(q.abs(), fmt);
    fmt.saturate(if q < 0 { one - pos } else { pos })
}

pub fn tanh_pla(q: i64, fmt: QFormat) -> i64 {
    let one = fmt.scale();
    let s = sigmoid_pla(2 * q, fmt);
    fmt.saturate(2 * s - one)
}

/// BRAM table contents (mirrors activations.py::lut_table for the
/// sigmoid/tanh kinds).  Hard variants get the sampled hard function — the
/// generator's DSE may enumerate (hard kind, LUT impl) points, and table
/// construction must not panic on them.  (The python kernels never emit
/// hard LUTs; `ActVariant::eval` keeps routing hard kinds through the
/// 1-cycle shift+clamp datapath.)
pub fn lut_table(kind: ActKind, fmt: QFormat) -> Vec<i64> {
    let step = (LUT_HI - LUT_LO) / LUT_SIZE as f64;
    (0..LUT_SIZE)
        .map(|i| {
            let mid = i as f64 * step + LUT_LO + step / 2.0;
            let f = match kind {
                ActKind::Sigmoid => sigmoid_f64(mid),
                ActKind::Tanh => mid.tanh(),
                ActKind::HardSigmoid => (mid / 4.0 + 0.5).clamp(0.0, 1.0),
                ActKind::HardTanh => mid.clamp(-1.0, 1.0),
            };
            (f * fmt.scale() as f64 + 0.5)
                .floor()
                .clamp(fmt.qmin() as f64, fmt.qmax() as f64) as i64
        })
        .collect()
}

fn lut_eval(q: i64, fmt: QFormat, kind: ActKind) -> i64 {
    assert!(fmt.frac_bits >= 4, "LUT variant requires frac_bits >= 4");
    let shift = fmt.frac_bits - 4;
    let lo_q = (LUT_LO * fmt.scale() as f64) as i64;
    let idx = ((q - lo_q) >> shift).clamp(0, LUT_SIZE as i64 - 1) as usize;
    lut_table(kind, fmt).get(idx).copied().unwrap_or(0)
}

pub fn hardsigmoid(q: i64, fmt: QFormat) -> i64 {
    let one = fmt.scale();
    (sra_round(q, 2) + (one >> 1)).clamp(0, one)
}

pub fn hardtanh(q: i64, fmt: QFormat) -> i64 {
    let one = fmt.scale();
    q.clamp(-one, one)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::fixed_point::Q16_8;

    const F: QFormat = Q16_8;

    #[test]
    fn pla_matches_known_points() {
        // sigma(0) = 0.5; sigma(1) = 0.75 under PLAN
        assert_eq!(sigmoid_pla(0, F), F.scale() / 2);
        assert_eq!(sigmoid_pla(F.scale(), F), (3 * F.scale()) / 4);
        assert_eq!(sigmoid_pla(8 * F.scale(), F), F.scale());
        assert_eq!(sigmoid_pla(-8 * F.scale(), F), 0);
    }

    #[test]
    fn pla_symmetry() {
        for q in (-2048..2048).step_by(7) {
            assert_eq!(sigmoid_pla(-q, F), F.scale() - sigmoid_pla(q, F));
        }
    }

    #[test]
    fn exact_sigmoid_error() {
        let v = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact);
        for q in (-2048..2048).step_by(13) {
            let y = v.eval(q, F);
            let want = sigmoid_f64(F.dequantize(q));
            assert!((F.dequantize(y) - want).abs() <= F.resolution());
        }
    }

    #[test]
    fn pla_error_within_model() {
        let v = ActVariant::new(ActKind::Sigmoid, ActImpl::Pla);
        let bound = v.max_error_lsb(F) * F.resolution();
        for q in -2048..2048 {
            let err = (F.dequantize(v.eval(q, F)) - sigmoid_f64(F.dequantize(q))).abs();
            assert!(err <= bound, "q={q} err={err}");
        }
    }

    #[test]
    fn lut_error_within_model() {
        for kind in [ActKind::Sigmoid, ActKind::Tanh] {
            let v = ActVariant::new(kind, ActImpl::Lut);
            let bound = v.max_error_lsb(F) * F.resolution();
            for q in (-2048..2048).step_by(3) {
                let want = match kind {
                    ActKind::Sigmoid => sigmoid_f64(F.dequantize(q)),
                    _ => F.dequantize(q).tanh(),
                };
                let err = (F.dequantize(v.eval(q, F)) - want).abs();
                assert!(err <= bound, "{kind:?} q={q} err={err}");
            }
        }
    }

    #[test]
    fn hard_variants_clamp() {
        let one = F.scale();
        assert_eq!(hardsigmoid(10 * one, F), one);
        assert_eq!(hardsigmoid(-10 * one, F), 0);
        assert_eq!(hardsigmoid(0, F), one / 2);
        assert_eq!(hardtanh(5 * one, F), one);
        assert_eq!(hardtanh(-5 * one, F), -one);
        assert_eq!(hardtanh(3, F), 3);
    }

    #[test]
    fn lut_saturated_ends() {
        let t = lut_table(ActKind::Sigmoid, F);
        assert_eq!(t[0], 0);
        assert_eq!(t[LUT_SIZE - 1], F.scale());
        assert!(t.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn hard_variant_lut_tables_defined() {
        // reachable from generator DSE: must be a real table, not a panic
        let hs = lut_table(ActKind::HardSigmoid, F);
        assert_eq!(hs[0], 0);
        assert_eq!(hs[LUT_SIZE - 1], F.scale());
        assert!(hs.windows(2).all(|w| w[1] >= w[0]));
        let ht = lut_table(ActKind::HardTanh, F);
        assert_eq!(ht[0], -F.scale());
        assert_eq!(ht[LUT_SIZE - 1], F.scale());
        assert!(ht.windows(2).all(|w| w[1] >= w[0]));
        // each cell is the hard function sampled at the cell midpoint
        let step = (LUT_HI - LUT_LO) / LUT_SIZE as f64;
        for (i, (&s, &t)) in hs.iter().zip(&ht).enumerate() {
            let mid = i as f64 * step + LUT_LO + step / 2.0;
            assert_eq!(s, F.quantize((mid / 4.0 + 0.5).clamp(0.0, 1.0)), "hs[{i}]");
            assert_eq!(t, F.quantize(mid.clamp(-1.0, 1.0)), "ht[{i}]");
        }
    }

    #[test]
    fn out_of_range_lut_index_clamps() {
        let v = ActVariant::new(ActKind::Sigmoid, ActImpl::Lut);
        assert_eq!(v.eval(F.qmin(), F), 0);
        assert_eq!(v.eval(F.qmax(), F), F.scale());
    }

    #[test]
    fn hardware_profile_ordering() {
        // cheaper variants use strictly fewer LUTs and lower latency
        let exact = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact);
        let pla = ActVariant::new(ActKind::Sigmoid, ActImpl::Pla);
        let hard = ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard);
        assert!(exact.resources().luts > pla.resources().luts);
        assert!(pla.resources().luts > hard.resources().luts);
        assert!(exact.latency() > hard.latency());
        assert!(exact.logic_delay_ns() > hard.logic_delay_ns());
    }

    #[test]
    fn parse_manifest_encoding() {
        let v = ActVariant::parse("sigmoid", "pla").unwrap();
        assert_eq!(v.kind, ActKind::Sigmoid);
        assert_eq!(v.imp, ActImpl::Pla);
        assert!(ActVariant::parse("sigmoid", "bogus").is_none());
    }
}
