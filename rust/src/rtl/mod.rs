//! RTL template library (RQ1): bit-true functional models + analytical
//! synthesis profiles for every DL component the paper's generator
//! composes — activations (4 functions x up to 3 implementations), FC,
//! LSTM, conv and attention templates, plus the fixed-point datapath
//! contract shared with the Python kernels.

pub mod activation;
pub mod attention;
pub mod component;
pub mod composition;
pub mod conv;
pub mod fc;
pub mod fixed_point;
pub mod lstm;

pub use activation::{ActImpl, ActKind, ActVariant};
pub use composition::{build, Accelerator, BuildOpts};
pub use fixed_point::{QFormat, Q12_6, Q16_8, Q8_4};
