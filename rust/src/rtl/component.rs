//! Common profile type produced by every RTL template's analytical model.

use crate::fpga::device::Resources;

/// Analytical synthesis/performance profile of one instantiated component.
///
/// Produced by the templates (`FcTemplate::profile()` etc.), consumed by the
/// composition model, the EDA estimator and the Generator.
#[derive(Debug, Clone)]
pub struct ComponentProfile {
    pub name: String,
    /// Fabric resources (before the device-specific technology factor the
    /// EDA model applies — these are 7-series-equivalent numbers).
    pub resources: Resources,
    /// Cycles to process one inference through this component.
    pub cycles: u64,
    /// Longest combinational path in ns (pre-routing).
    pub crit_path_ns: f64,
    /// Multiply-accumulate operations per inference (for GOPS accounting;
    /// 1 MAC = 2 ops by the usual convention).
    pub macs: u64,
    /// Fraction of the run during which this component's logic toggles
    /// (drives the dynamic-power estimate).
    pub active_fraction: f64,
}

impl ComponentProfile {
    /// Ops per inference (2 ops per MAC).
    pub fn ops(&self) -> u64 {
        self.macs * 2
    }
}

/// Pipeline register fill depth added by pipelined schedules.
pub const PIPELINE_FILL: u64 = 8;

/// Control/FSM overhead LUTs per template instance.
pub const CTRL_LUTS: u32 = 120;
pub const CTRL_FFS: u32 = 90;

/// DSP multiplier combinational delay (ns) and BRAM access time (ns) on the
/// 28 nm fabric — the baseline the per-family technology factors scale.
pub const DSP_DELAY_NS: f64 = 4.0;
pub const BRAM_DELAY_NS: f64 = 2.9;
/// Extra mux/control delay of non-pipelined (resource-shared) schedules.
pub const SEQ_MUX_DELAY_NS: f64 = 1.8;

/// BRAM18 blocks needed for `bits` of storage.
pub fn bram18_for_bits(bits: u64) -> u32 {
    const BRAM18_BITS: u64 = 18 * 1024;
    bits.div_ceil(BRAM18_BITS) as u32
}

/// DSP blocks per MAC lane for a given operand width (7-series DSP48: one
/// block up to 18x25 bit, two cascaded above).
pub fn dsps_per_mac(total_bits: u32) -> u32 {
    if total_bits <= 18 {
        1
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bram_rounding() {
        assert_eq!(bram18_for_bits(0), 0);
        assert_eq!(bram18_for_bits(1), 1);
        assert_eq!(bram18_for_bits(18 * 1024), 1);
        assert_eq!(bram18_for_bits(18 * 1024 + 1), 2);
    }

    #[test]
    fn dsp_width_split() {
        assert_eq!(dsps_per_mac(16), 1);
        assert_eq!(dsps_per_mac(18), 1);
        assert_eq!(dsps_per_mac(24), 2);
    }

    #[test]
    fn ops_convention() {
        let p = ComponentProfile {
            name: "x".into(),
            resources: Resources::default(),
            cycles: 10,
            crit_path_ns: 4.0,
            macs: 100,
            active_fraction: 1.0,
        };
        assert_eq!(p.ops(), 200);
    }
}
