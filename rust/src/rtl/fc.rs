//! Fully-connected layer RTL template ([4,10,11]).
//!
//! Design axes (the template's generics in the paper's library):
//!
//! * `alus`       — parallel MAC lanes (DSP blocks); the classic
//!                  throughput-vs-resources axis of §5.1.
//! * `pipelined`  — activation and accumulation overlapped with the MAC
//!                  stream (II=1) vs a resource-shared sequential schedule.
//! * `act`        — activation variant appended to the layer.
//! * `fmt`        — datapath width (DSP lane splitting above 18 bit).

use super::activation::ActVariant;
use super::component::{
    bram18_for_bits, dsps_per_mac, ComponentProfile, BRAM_DELAY_NS, CTRL_FFS, CTRL_LUTS,
    DSP_DELAY_NS, PIPELINE_FILL, SEQ_MUX_DELAY_NS,
};
use super::fixed_point::QFormat;
use crate::fpga::device::Resources;

#[derive(Debug, Clone)]
pub struct FcTemplate {
    pub name: String,
    pub n_in: u32,
    pub n_out: u32,
    pub alus: u32,
    pub pipelined: bool,
    pub act: Option<ActVariant>,
    pub fmt: QFormat,
}

impl FcTemplate {
    pub fn new(name: &str, n_in: u32, n_out: u32, fmt: QFormat) -> FcTemplate {
        FcTemplate {
            name: name.to_string(),
            n_in,
            n_out,
            alus: 1,
            pipelined: false,
            act: None,
            fmt,
        }
    }

    pub fn with_alus(mut self, alus: u32) -> FcTemplate {
        assert!(alus >= 1);
        self.alus = alus;
        self
    }

    pub fn pipelined(mut self, on: bool) -> FcTemplate {
        self.pipelined = on;
        self
    }

    pub fn with_act(mut self, act: ActVariant) -> FcTemplate {
        self.act = Some(act);
        self
    }

    pub fn macs(&self) -> u64 {
        self.n_in as u64 * self.n_out as u64
    }

    /// Cycles for one forward pass.
    pub fn cycles(&self) -> u64 {
        let mac_cycles = self.macs().div_ceil(self.alus as u64);
        let act_cycles = match (&self.act, self.pipelined) {
            (None, _) => 0,
            // pipelined: the act unit consumes results as they retire; only
            // its fill latency is exposed.
            (Some(a), true) => a.latency(),
            // sequential: each of the n_out results is pushed through the
            // shared act unit after the MACs finish.
            (Some(a), false) => self.n_out as u64 * a.ii() + a.latency(),
        };
        let fill = if self.pipelined { PIPELINE_FILL } else { 0 };
        // per-output accumulator drain in the sequential schedule
        let drain = if self.pipelined { 0 } else { self.n_out as u64 };
        mac_cycles + act_cycles + fill + drain
    }

    pub fn resources(&self) -> Resources {
        let dsps = self.alus * dsps_per_mac(self.fmt.total_bits);
        let weight_bits = self.macs() * self.fmt.total_bits as u64;
        let brams = bram18_for_bits(weight_bits);
        let mut r = Resources::new(
            CTRL_LUTS + 14 * self.alus,
            CTRL_FFS + 18 * self.alus + if self.pipelined { 64 } else { 0 },
            brams,
            dsps,
        );
        if let Some(a) = &self.act {
            r = r.add(&a.resources());
        }
        r
    }

    pub fn crit_path_ns(&self) -> f64 {
        let mut d: f64 = DSP_DELAY_NS.max(BRAM_DELAY_NS);
        if let Some(a) = &self.act {
            if !self.pipelined {
                // act output feeds the same cycle's writeback mux
                d = d.max(a.logic_delay_ns());
            } else {
                // registered boundary: act path stands alone
                d = d.max(a.logic_delay_ns() * 0.75);
            }
        }
        if !self.pipelined {
            d += SEQ_MUX_DELAY_NS;
        }
        d
    }

    pub fn profile(&self) -> ComponentProfile {
        ComponentProfile {
            name: self.name.clone(),
            resources: self.resources(),
            cycles: self.cycles(),
            crit_path_ns: self.crit_path_ns(),
            macs: self.macs(),
            active_fraction: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::activation::{ActImpl, ActKind};
    use crate::rtl::fixed_point::Q16_8;

    fn t() -> FcTemplate {
        FcTemplate::new("fc", 16, 8, Q16_8)
    }

    #[test]
    fn more_alus_fewer_cycles() {
        assert!(t().with_alus(8).cycles() < t().with_alus(1).cycles());
        // but more DSPs
        assert!(t().with_alus(8).resources().dsps > t().with_alus(1).resources().dsps);
    }

    #[test]
    fn pipelining_hides_activation() {
        let act = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact);
        let seq = t().with_act(act).cycles();
        let pipe = t().with_act(act).pipelined(true).cycles();
        assert!(pipe < seq, "pipe {pipe} >= seq {seq}");
    }

    #[test]
    fn exact_act_dominates_critical_path_when_sequential() {
        let act = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact);
        let with = t().with_act(act).crit_path_ns();
        let without = t().crit_path_ns();
        assert!(with > without);
    }

    #[test]
    fn weight_storage_scales() {
        let small = FcTemplate::new("s", 8, 8, Q16_8).resources().bram18;
        let big = FcTemplate::new("b", 64, 64, Q16_8).resources().bram18;
        assert!(big > small);
    }

    #[test]
    fn macs_count() {
        assert_eq!(t().macs(), 128);
        assert_eq!(t().profile().ops(), 256);
    }

    #[test]
    fn cycles_monotone_in_size() {
        let a = FcTemplate::new("a", 8, 8, Q16_8).cycles();
        let b = FcTemplate::new("b", 32, 8, Q16_8).cycles();
        assert!(b > a);
    }
}
