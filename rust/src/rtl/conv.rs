//! 1-D convolution RTL template (the on-device ECG CNN of [3]).
//!
//! The RTL design streams the input window through a shift register and
//! evaluates `c_out` MAC columns; the template's axes match fc.rs
//! (ALU parallelism, pipelined activation, variant, format).

use super::activation::ActVariant;
use super::component::{
    bram18_for_bits, dsps_per_mac, ComponentProfile, BRAM_DELAY_NS, CTRL_FFS, CTRL_LUTS,
    DSP_DELAY_NS, PIPELINE_FILL, SEQ_MUX_DELAY_NS,
};
use super::fixed_point::QFormat;
use crate::fpga::device::Resources;

#[derive(Debug, Clone)]
pub struct ConvTemplate {
    pub name: String,
    pub t_in: u32,
    pub c_in: u32,
    pub kw: u32,
    pub c_out: u32,
    pub stride: u32,
    pub alus: u32,
    pub pipelined: bool,
    pub act: Option<ActVariant>,
    pub fmt: QFormat,
}

impl ConvTemplate {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        t_in: u32,
        c_in: u32,
        kw: u32,
        c_out: u32,
        stride: u32,
        fmt: QFormat,
    ) -> ConvTemplate {
        assert!(stride >= 1 && kw <= t_in);
        ConvTemplate {
            name: name.to_string(),
            t_in,
            c_in,
            kw,
            c_out,
            stride,
            alus: 1,
            pipelined: false,
            act: None,
            fmt,
        }
    }

    pub fn with_alus(mut self, alus: u32) -> ConvTemplate {
        assert!(alus >= 1);
        self.alus = alus;
        self
    }

    pub fn pipelined(mut self, on: bool) -> ConvTemplate {
        self.pipelined = on;
        self
    }

    pub fn with_act(mut self, act: ActVariant) -> ConvTemplate {
        self.act = Some(act);
        self
    }

    pub fn t_out(&self) -> u32 {
        (self.t_in - self.kw) / self.stride + 1
    }

    pub fn macs(&self) -> u64 {
        self.t_out() as u64 * self.kw as u64 * self.c_in as u64 * self.c_out as u64
    }

    pub fn cycles(&self) -> u64 {
        let mac = self.macs().div_ceil(self.alus as u64);
        let outputs = self.t_out() as u64 * self.c_out as u64;
        let act = match (&self.act, self.pipelined) {
            (None, _) => 0,
            (Some(a), true) => a.latency(),
            (Some(a), false) => outputs * a.ii() + a.latency(),
        };
        let fill = if self.pipelined { PIPELINE_FILL } else { 0 };
        // the sequential schedule overlaps accumulator writeback with the
        // MAC stream except for the final output column
        let drain = if self.pipelined { 0 } else { self.c_out as u64 };
        mac + act + fill + drain
    }

    pub fn resources(&self) -> Resources {
        let dsps = self.alus * dsps_per_mac(self.fmt.total_bits);
        let weight_bits =
            self.kw as u64 * self.c_in as u64 * self.c_out as u64 * self.fmt.total_bits as u64;
        // line buffer for the sliding window
        let linebuf_bits = self.kw as u64 * self.c_in as u64 * self.fmt.total_bits as u64;
        let brams = bram18_for_bits(weight_bits + linebuf_bits);
        let mut r = Resources::new(
            CTRL_LUTS + 60 + 14 * self.alus,
            CTRL_FFS + 80 + 18 * self.alus + if self.pipelined { 96 } else { 0 },
            brams,
            dsps,
        );
        if let Some(a) = &self.act {
            r = r.add(&a.resources());
        }
        r
    }

    pub fn crit_path_ns(&self) -> f64 {
        let mut d: f64 = DSP_DELAY_NS.max(BRAM_DELAY_NS);
        if let Some(a) = &self.act {
            if self.pipelined {
                d = d.max(a.logic_delay_ns() * 0.75);
            } else {
                d = d.max(a.logic_delay_ns());
            }
        }
        if !self.pipelined {
            d += SEQ_MUX_DELAY_NS;
        }
        d
    }

    pub fn profile(&self) -> ComponentProfile {
        ComponentProfile {
            name: self.name.clone(),
            resources: self.resources(),
            cycles: self.cycles(),
            crit_path_ns: self.crit_path_ns(),
            macs: self.macs(),
            active_fraction: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::activation::{ActImpl, ActKind};
    use crate::rtl::fixed_point::Q16_8;

    fn t() -> ConvTemplate {
        ConvTemplate::new("conv", 128, 1, 7, 8, 2, Q16_8)
    }

    #[test]
    fn output_length() {
        assert_eq!(t().t_out(), 61);
        assert_eq!(
            ConvTemplate::new("c", 61, 8, 5, 16, 2, Q16_8).t_out(),
            29
        );
    }

    #[test]
    fn macs_formula() {
        assert_eq!(t().macs(), 61 * 7 * 8);
    }

    #[test]
    fn parallelism_reduces_cycles() {
        assert!(t().with_alus(8).cycles() * 6 < t().cycles());
    }

    #[test]
    fn pipelined_act_cheaper_than_sequential() {
        let act = ActVariant::new(ActKind::Tanh, ActImpl::Exact);
        assert!(t().with_act(act).pipelined(true).cycles() < t().with_act(act).cycles());
    }

    #[test]
    #[should_panic]
    fn kernel_wider_than_input_rejected() {
        ConvTemplate::new("bad", 4, 1, 7, 8, 1, Q16_8);
    }
}
