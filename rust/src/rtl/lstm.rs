//! LSTM cell RTL template — the paper's flagship accelerator ([2,20], E1).
//!
//! The template exposes the two optimisation axes §3.1 quantifies:
//!
//! * **Schedule** — `pipelined = false` reproduces the baseline of [2]:
//!   the gate MAC pass, the activation pass and the elementwise state
//!   update run back-to-back through shared units.  `pipelined = true` is
//!   the optimised design: activations and the elementwise update are
//!   overlapped with the MAC stream of the *next* gate block, exposing only
//!   fill latencies.
//! * **Activation variants** — the sigmoid/tanh implementation pair; exact
//!   units are high-latency (II=4) and long-path, Hard* are single-cycle.
//!
//! The E1 experiment instantiates this template at the paper's dimensions
//! and reports latency + energy efficiency for (sequential, exact) vs
//! (pipelined, hard); see benches/e1_lstm_opt.rs.

use super::activation::ActVariant;
use super::component::{
    bram18_for_bits, dsps_per_mac, ComponentProfile, BRAM_DELAY_NS, CTRL_FFS, CTRL_LUTS,
    DSP_DELAY_NS, PIPELINE_FILL, SEQ_MUX_DELAY_NS,
};
use super::fixed_point::QFormat;
use crate::fpga::device::Resources;

#[derive(Debug, Clone)]
pub struct LstmTemplate {
    pub name: String,
    pub n_in: u32,
    pub n_h: u32,
    /// Sequence length per inference.
    pub timesteps: u32,
    pub alus: u32,
    pub pipelined: bool,
    pub sigmoid: ActVariant,
    pub tanh: ActVariant,
    pub fmt: QFormat,
}

impl LstmTemplate {
    pub fn new(
        name: &str,
        n_in: u32,
        n_h: u32,
        timesteps: u32,
        sigmoid: ActVariant,
        tanh: ActVariant,
        fmt: QFormat,
    ) -> LstmTemplate {
        LstmTemplate {
            name: name.to_string(),
            n_in,
            n_h,
            timesteps,
            alus: 1,
            pipelined: false,
            sigmoid,
            tanh,
            fmt,
        }
    }

    pub fn with_alus(mut self, alus: u32) -> LstmTemplate {
        assert!(alus >= 1);
        self.alus = alus;
        self
    }

    pub fn pipelined(mut self, on: bool) -> LstmTemplate {
        self.pipelined = on;
        self
    }

    /// Gate MACs per timestep: (n_in + n_h) rows into 4*n_h columns.
    pub fn gate_macs_per_step(&self) -> u64 {
        (self.n_in as u64 + self.n_h as u64) * 4 * self.n_h as u64
    }

    /// Elementwise multiplies per timestep: f*c, i*g, o*tanh(c').
    pub fn ew_macs_per_step(&self) -> u64 {
        3 * self.n_h as u64
    }

    pub fn macs(&self) -> u64 {
        self.timesteps as u64 * (self.gate_macs_per_step() + self.ew_macs_per_step())
    }

    /// Cycles for one timestep.
    pub fn cycles_per_step(&self) -> u64 {
        let mac = self.gate_macs_per_step().div_ceil(self.alus as u64);
        let n_h = self.n_h as u64;
        if self.pipelined {
            // activations + elementwise update stream behind the MACs; only
            // fill latencies and the tanh(c') tail are exposed.
            let tail = self.tanh.latency() + self.sigmoid.latency().max(self.tanh.latency());
            mac + PIPELINE_FILL + tail + self.ew_macs_per_step().div_ceil(self.alus as u64)
        } else {
            // sequential: 3*n_h sigmoid + n_h tanh gate activations, then
            // the elementwise update, then n_h tanh(c') + n_h product.
            let gate_acts = 3 * n_h * self.sigmoid.ii()
                + n_h * self.tanh.ii()
                + self.sigmoid.latency().max(self.tanh.latency());
            let ew = self.ew_macs_per_step().div_ceil(self.alus as u64);
            let c_tanh = n_h * self.tanh.ii() + self.tanh.latency();
            mac + gate_acts + ew + c_tanh
        }
    }

    pub fn cycles(&self) -> u64 {
        self.timesteps as u64 * self.cycles_per_step()
    }

    pub fn resources(&self) -> Resources {
        let dsps = self.alus * dsps_per_mac(self.fmt.total_bits);
        let weight_bits =
            (self.n_in as u64 + self.n_h as u64) * 4 * self.n_h as u64 * self.fmt.total_bits as u64;
        let state_bits = 2 * self.n_h as u64 * self.fmt.total_bits as u64;
        let brams = bram18_for_bits(weight_bits + state_bits);
        let base = Resources::new(
            CTRL_LUTS + 90 + 14 * self.alus,
            CTRL_FFS + 120 + 18 * self.alus + if self.pipelined { 128 } else { 0 },
            brams,
            dsps,
        );
        // one sigmoid unit + one tanh unit (time-multiplexed across gates)
        base.add(&self.sigmoid.resources()).add(&self.tanh.resources())
    }

    pub fn crit_path_ns(&self) -> f64 {
        let act = self.sigmoid.logic_delay_ns().max(self.tanh.logic_delay_ns());
        let mut d: f64 = DSP_DELAY_NS.max(BRAM_DELAY_NS);
        if self.pipelined {
            d = d.max(act * 0.75);
        } else {
            d = d.max(act) + SEQ_MUX_DELAY_NS;
        }
        d
    }

    pub fn profile(&self) -> ComponentProfile {
        ComponentProfile {
            name: self.name.clone(),
            resources: self.resources(),
            cycles: self.cycles(),
            crit_path_ns: self.crit_path_ns(),
            macs: self.macs(),
            active_fraction: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::activation::{ActImpl, ActKind};
    use crate::rtl::fixed_point::Q16_8;

    fn exact() -> (ActVariant, ActVariant) {
        (
            ActVariant::new(ActKind::Sigmoid, ActImpl::Exact),
            ActVariant::new(ActKind::Tanh, ActImpl::Exact),
        )
    }

    fn hard() -> (ActVariant, ActVariant) {
        (
            ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard),
            ActVariant::new(ActKind::HardTanh, ActImpl::Hard),
        )
    }

    fn base(sig: ActVariant, tan: ActVariant) -> LstmTemplate {
        LstmTemplate::new("lstm", 6, 20, 24, sig, tan, Q16_8).with_alus(8)
    }

    #[test]
    fn e1_shape_pipelined_hard_beats_sequential_exact() {
        let (se, te) = exact();
        let (sh, th) = hard();
        let baseline = base(se, te);
        let optimised = base(sh, th).pipelined(true);
        let ratio = baseline.cycles() as f64 / optimised.cycles() as f64;
        // the paper reports a 47.37% latency reduction (1.90x); the
        // analytical model must land in the same regime
        assert!(ratio > 1.5 && ratio < 3.5, "latency ratio {ratio}");
    }

    #[test]
    fn gate_macs_formula() {
        let (s, t) = hard();
        let l = LstmTemplate::new("x", 6, 20, 1, s, t, Q16_8);
        assert_eq!(l.gate_macs_per_step(), 26 * 80);
        assert_eq!(l.ew_macs_per_step(), 60);
    }

    #[test]
    fn cycles_scale_with_timesteps() {
        let (s, t) = hard();
        let one = LstmTemplate::new("x", 6, 20, 1, s, t, Q16_8).cycles();
        let many = LstmTemplate::new("x", 6, 20, 24, s, t, Q16_8).cycles();
        assert_eq!(many, 24 * one);
    }

    #[test]
    fn pipelining_costs_ffs_saves_cycles() {
        let (s, t) = exact();
        let seq = base(s, t);
        let pipe = base(s, t).pipelined(true);
        assert!(pipe.cycles() < seq.cycles());
        assert!(pipe.resources().ffs > seq.resources().ffs);
    }

    #[test]
    fn exact_acts_stretch_critical_path() {
        let (se, te) = exact();
        let (sh, th) = hard();
        assert!(base(se, te).crit_path_ns() > base(sh, th).crit_path_ns());
    }

    #[test]
    fn fits_on_xc7s15() {
        use crate::fpga::device::device;
        let (sh, th) = hard();
        let l = base(sh, th).pipelined(true);
        assert!(l
            .resources()
            .fits_in(&device("xc7s15").unwrap().resources));
    }
}
