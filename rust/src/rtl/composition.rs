//! Accelerator composition: a generated accelerator is a layer-serial chain
//! of template instances sharing one clock domain (the design style of the
//! paper's template library — each layer gets its own engine, engines run
//! back-to-back, weights live on-chip).

use super::activation::{ActImpl, ActKind, ActVariant};
use super::attention::AttentionTemplate;
use super::component::ComponentProfile;
use super::conv::ConvTemplate;
use super::fc::FcTemplate;
use super::fixed_point::QFormat;
use super::lstm::LstmTemplate;
use crate::fpga::device::{FpgaDevice, Resources};
use crate::models::{self, Topology};
use crate::util::units::{Hertz, Secs};

/// A fully specified accelerator design (pre-synthesis).
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: String,
    pub components: Vec<ComponentProfile>,
    pub fmt: QFormat,
}

impl Accelerator {
    pub fn new(name: &str, fmt: QFormat) -> Accelerator {
        Accelerator {
            name: name.to_string(),
            components: Vec::new(),
            fmt,
        }
    }

    pub fn push(&mut self, p: ComponentProfile) -> &mut Self {
        self.components.push(p);
        self
    }

    /// Total fabric demand (7-series-equivalent units).
    pub fn resources(&self) -> Resources {
        self.components
            .iter()
            .fold(Resources::default(), |acc, c| acc.add(&c.resources))
    }

    /// Cycles per inference (layer-serial execution).
    pub fn cycles(&self) -> u64 {
        self.components.iter().map(|c| c.cycles).sum()
    }

    pub fn macs(&self) -> u64 {
        self.components.iter().map(|c| c.macs).sum()
    }

    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Longest pre-routing combinational path across components.
    pub fn crit_path_ns(&self) -> f64 {
        self.components
            .iter()
            .map(|c| c.crit_path_ns)
            .fold(0.0, f64::max)
    }

    pub fn fits(&self, device: &FpgaDevice) -> bool {
        self.resources().fits_in(&device.resources)
    }

    /// Inference latency at a given clock.
    pub fn latency(&self, clock: Hertz) -> Secs {
        clock.cycles(self.cycles())
    }
}

/// Schedule/implementation knobs shared by the builder (the manifest's
/// L3-side attributes).
#[derive(Debug, Clone, Copy)]
pub struct BuildOpts {
    pub fmt: QFormat,
    pub sigmoid: ActVariant,
    pub tanh: ActVariant,
    pub alus: u32,
    pub pipelined: bool,
}

impl BuildOpts {
    /// The E1 baseline of [2]: same MAC array as the optimised design,
    /// sequential schedule, exact activation units.
    pub fn baseline(fmt: QFormat) -> BuildOpts {
        BuildOpts {
            fmt,
            sigmoid: ActVariant::new(ActKind::Sigmoid, ActImpl::Exact),
            tanh: ActVariant::new(ActKind::Tanh, ActImpl::Exact),
            alus: 4,
            pipelined: false,
        }
    }

    pub fn optimised(fmt: QFormat) -> BuildOpts {
        BuildOpts {
            fmt,
            sigmoid: ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard),
            tanh: ActVariant::new(ActKind::HardTanh, ActImpl::Hard),
            alus: 4,
            pipelined: true,
        }
    }
}

/// Instantiate the template chain for a model topology.
pub fn build(topology: Topology, opts: &BuildOpts) -> Accelerator {
    let mut acc = Accelerator::new(topology.name(), opts.fmt);
    match topology {
        Topology::MlpFluid => {
            for (i, &(n_in, n_out)) in models::MLP_LAYERS.iter().enumerate() {
                let mut fc = FcTemplate::new(&format!("fc{i}"), n_in, n_out, opts.fmt)
                    .with_alus(opts.alus)
                    .pipelined(opts.pipelined);
                if i + 1 < models::MLP_LAYERS.len() {
                    fc = fc.with_act(opts.sigmoid);
                }
                acc.push(fc.profile());
            }
        }
        Topology::LstmHar => {
            acc.push(
                LstmTemplate::new(
                    "lstm",
                    models::LSTM_IN,
                    models::LSTM_H,
                    models::LSTM_T,
                    opts.sigmoid,
                    opts.tanh,
                    opts.fmt,
                )
                .with_alus(opts.alus)
                .pipelined(opts.pipelined)
                .profile(),
            );
            acc.push(
                FcTemplate::new("head", models::LSTM_H, models::LSTM_CLASSES, opts.fmt)
                    .with_alus(opts.alus)
                    .pipelined(opts.pipelined)
                    .profile(),
            );
        }
        Topology::CnnEcg => {
            let mut t = models::CNN_T;
            for (i, &(c_in, c_out, kw, stride)) in models::CNN_SPEC.iter().enumerate() {
                acc.push(
                    ConvTemplate::new(&format!("conv{i}"), t, c_in, kw, c_out, stride, opts.fmt)
                        .with_alus(opts.alus)
                        .pipelined(opts.pipelined)
                        .with_act(opts.tanh)
                        .profile(),
                );
                t = (t - kw) / stride + 1;
            }
            acc.push(
                FcTemplate::new(
                    "head",
                    models::CNN_SPEC.last().unwrap().1,
                    models::CNN_CLASSES,
                    opts.fmt,
                )
                .with_alus(opts.alus)
                .pipelined(opts.pipelined)
                .profile(),
            );
        }
        Topology::AttnTiny => {
            acc.push(
                AttentionTemplate::new("attn", models::ATTN_T, models::ATTN_D, opts.fmt)
                    .with_alus(opts.alus)
                    .pipelined(opts.pipelined)
                    .profile(),
            );
            acc.push(
                FcTemplate::new("head", models::ATTN_D, models::ATTN_CLASSES, opts.fmt)
                    .with_alus(opts.alus)
                    .pipelined(opts.pipelined)
                    .profile(),
            );
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn mlp_builds_three_layers() {
        let acc = build(Topology::MlpFluid, &BuildOpts::baseline(Q16_8));
        assert_eq!(acc.components.len(), 3);
        assert_eq!(acc.macs(), 8 * 16 + 16 * 8 + 8);
    }

    #[test]
    fn optimised_faster_than_baseline_everywhere() {
        for t in Topology::all() {
            let base = build(*t, &BuildOpts::baseline(Q16_8));
            let opt = build(*t, &BuildOpts::optimised(Q16_8));
            assert!(
                opt.cycles() < base.cycles(),
                "{}: {} !< {}",
                t.name(),
                opt.cycles(),
                base.cycles()
            );
        }
    }

    #[test]
    fn all_models_fit_on_xc7s25() {
        let d = device("xc7s25").unwrap();
        for t in Topology::all() {
            let acc = build(*t, &BuildOpts::optimised(Q16_8));
            assert!(acc.fits(d), "{} does not fit", t.name());
        }
    }

    #[test]
    fn latency_at_clock() {
        let acc = build(Topology::MlpFluid, &BuildOpts::optimised(Q16_8));
        let lat = acc.latency(Hertz::from_mhz(100.0));
        assert!(lat.value() > 0.0 && lat.us() < 1000.0);
    }

    #[test]
    fn crit_path_is_max() {
        let acc = build(Topology::LstmHar, &BuildOpts::baseline(Q16_8));
        let max = acc
            .components
            .iter()
            .map(|c| c.crit_path_ns)
            .fold(0.0, f64::max);
        assert_eq!(acc.crit_path_ns(), max);
    }
}
