//! Single-head attention RTL template (§3.1 "attention modules in
//! Transformer models").
//!
//! Embedded design point: Q/K/V projections and both matmuls run on the MAC
//! array; the softmax is a dedicated exact unit (shares the Exact activation
//! profile scaled by the row reduction).

use super::activation::{ActImpl, ActKind, ActVariant};
use super::component::{
    bram18_for_bits, dsps_per_mac, ComponentProfile, BRAM_DELAY_NS, CTRL_FFS, CTRL_LUTS,
    DSP_DELAY_NS, PIPELINE_FILL,
};
use super::fixed_point::QFormat;
use crate::fpga::device::Resources;

#[derive(Debug, Clone)]
pub struct AttentionTemplate {
    pub name: String,
    /// Sequence length.
    pub t: u32,
    /// Head dimension.
    pub d: u32,
    pub alus: u32,
    pub pipelined: bool,
    pub fmt: QFormat,
}

impl AttentionTemplate {
    pub fn new(name: &str, t: u32, d: u32, fmt: QFormat) -> AttentionTemplate {
        AttentionTemplate {
            name: name.to_string(),
            t,
            d,
            alus: 1,
            pipelined: false,
            fmt,
        }
    }

    pub fn with_alus(mut self, alus: u32) -> AttentionTemplate {
        assert!(alus >= 1);
        self.alus = alus;
        self
    }

    pub fn pipelined(mut self, on: bool) -> AttentionTemplate {
        self.pipelined = on;
        self
    }

    pub fn macs(&self) -> u64 {
        let (t, d) = (self.t as u64, self.d as u64);
        // projections: 3 * T*d*d; scores: T*T*d; weighted sum: T*T*d
        3 * t * d * d + 2 * t * t * d
    }

    /// Softmax unit modelled as an exact transcendental per score row
    /// element (exp) plus the division pass.
    fn softmax_cycles(&self) -> u64 {
        let exact = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact);
        let elems = self.t as u64 * self.t as u64;
        elems * exact.ii() + 2 * self.t as u64 + exact.latency()
    }

    pub fn cycles(&self) -> u64 {
        let mac = self.macs().div_ceil(self.alus as u64);
        let fill = if self.pipelined { PIPELINE_FILL } else { self.t as u64 };
        mac + self.softmax_cycles() + fill
    }

    pub fn resources(&self) -> Resources {
        let dsps = self.alus * dsps_per_mac(self.fmt.total_bits);
        let weight_bits = 3 * self.d as u64 * self.d as u64 * self.fmt.total_bits as u64;
        let score_bits = self.t as u64 * self.t as u64 * self.fmt.total_bits as u64;
        let brams = bram18_for_bits(weight_bits + score_bits);
        let softmax = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact).resources();
        Resources::new(
            CTRL_LUTS + 150 + 14 * self.alus,
            CTRL_FFS + 160 + 18 * self.alus,
            brams,
            dsps,
        )
        .add(&softmax)
    }

    pub fn crit_path_ns(&self) -> f64 {
        let softmax = ActVariant::new(ActKind::Sigmoid, ActImpl::Exact).logic_delay_ns();
        DSP_DELAY_NS.max(BRAM_DELAY_NS).max(if self.pipelined {
            softmax * 0.75
        } else {
            softmax
        })
    }

    pub fn profile(&self) -> ComponentProfile {
        ComponentProfile {
            name: self.name.clone(),
            resources: self.resources(),
            cycles: self.cycles(),
            crit_path_ns: self.crit_path_ns(),
            macs: self.macs(),
            active_fraction: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn macs_formula() {
        let a = AttentionTemplate::new("a", 16, 16, Q16_8);
        assert_eq!(a.macs(), 3 * 16 * 16 * 16 + 2 * 16 * 16 * 16);
    }

    #[test]
    fn parallelism_helps() {
        let a = AttentionTemplate::new("a", 16, 16, Q16_8);
        assert!(a.clone().with_alus(8).cycles() < a.cycles());
    }

    #[test]
    fn softmax_in_resources() {
        let a = AttentionTemplate::new("a", 16, 16, Q16_8);
        assert!(a.resources().dsps >= 2); // exact unit brings DSPs
    }
}
