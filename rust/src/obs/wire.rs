//! Schema-tagged JSON codecs for the journal's event types — the JSONL
//! journal is a wire format like the dist shard protocol, and it lives
//! under the same statically-checked hygiene rules (schema tag on every
//! record, full two-way field coverage, encode/decode key parity; see
//! `analysis/wire.rs` — `src/obs/wire.rs` is wire-scoped by the
//! classifier).
//!
//! Conventions copied from `generator/dist/wire.rs`: every object leads
//! with its `schema` tag and every decoder checks it; `Option` fields
//! are absent when `None` (and decode absent-or-null back to `None`);
//! u64 trace ids cross as strings so an id at or above 2^53 cannot be
//! silently rounded through f64.

use super::journal::{CycleEvent, Event, SpanEvent, SwapEvent, WorkerEvent};
use crate::util::json::Json;
use anyhow::anyhow;

pub const SPAN_SCHEMA: &str = "elastic-gen/obs-span/v1";
pub const CYCLE_SCHEMA: &str = "elastic-gen/obs-cycle/v1";
pub const SWAP_SCHEMA: &str = "elastic-gen/obs-swap/v1";
pub const WORKER_SCHEMA: &str = "elastic-gen/obs-worker/v1";

// -- field helpers (the dist/wire.rs idiom) ----------------------------------

fn num(j: &Json, k: &str) -> anyhow::Result<f64> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{k}'"))
}

fn string<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a str> {
    j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing or non-string field '{k}'"))
}

fn boolean(j: &Json, k: &str) -> anyhow::Result<bool> {
    j.get(k)
        .and_then(|v| v.as_bool())
        .ok_or_else(|| anyhow!("missing or non-bool field '{k}'"))
}

/// u64 carried as a string (an f64 would round at or above 2^53).
fn uint64(j: &Json, k: &str) -> anyhow::Result<u64> {
    let text = string(j, k)?;
    text.parse::<u64>().map_err(|_| anyhow!("bad u64 field '{k}': '{text}'"))
}

fn opt_num(j: &Json, k: &str) -> anyhow::Result<Option<f64>> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow!("non-numeric optional field '{k}'")),
    }
}

fn opt_uint(j: &Json, k: &str) -> anyhow::Result<Option<usize>> {
    match opt_num(j, k)? {
        None => Ok(None),
        Some(x) => {
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0,
                "optional field '{k}' is not a whole number: {x}"
            );
            Ok(Some(x as usize))
        }
    }
}

fn opt_u64(j: &Json, k: &str) -> anyhow::Result<Option<u64>> {
    match opt_num(j, k)? {
        None => Ok(None),
        Some(x) => {
            anyhow::ensure!(
                x >= 0.0 && x.fract() == 0.0,
                "optional field '{k}' is not a whole number: {x}"
            );
            Ok(Some(x as u64))
        }
    }
}

fn opt_bool(j: &Json, k: &str) -> anyhow::Result<Option<bool>> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| anyhow!("non-bool optional field '{k}'")),
    }
}

fn opt_string(j: &Json, k: &str) -> anyhow::Result<Option<String>> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| anyhow!("non-string optional field '{k}'")),
    }
}

fn check_schema(j: &Json, want: &str) -> anyhow::Result<()> {
    let got = string(j, "schema")?;
    anyhow::ensure!(got == want, "schema mismatch: got '{got}', want '{want}'");
    Ok(())
}

// -- span codec --------------------------------------------------------------

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("schema", Json::Str(SPAN_SCHEMA.to_string())),
            ("t_s", Json::Num(self.t_s)),
            ("id", Json::Str(self.id.to_string())),
            ("stage", Json::Str(self.stage.clone())),
            ("artifact", Json::Str(self.artifact.clone())),
        ];
        if let Some(s) = self.shard {
            pairs.push(("shard", Json::Num(s as f64)));
        }
        if let Some(q) = self.queue_wait_s {
            pairs.push(("queue_wait_s", Json::Num(q)));
        }
        if let Some(x) = self.exec_s {
            pairs.push(("exec_s", Json::Num(x)));
        }
        if let Some(b) = self.batch {
            pairs.push(("batch", Json::Num(b as f64)));
        }
        if let Some(ok) = self.ok {
            pairs.push(("ok", Json::Bool(ok)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SpanEvent> {
        check_schema(j, SPAN_SCHEMA)?;
        Ok(SpanEvent {
            t_s: num(j, "t_s")?,
            id: uint64(j, "id")?,
            stage: string(j, "stage")?.to_string(),
            artifact: string(j, "artifact")?.to_string(),
            shard: opt_uint(j, "shard")?,
            queue_wait_s: opt_num(j, "queue_wait_s")?,
            exec_s: opt_num(j, "exec_s")?,
            batch: opt_uint(j, "batch")?,
            ok: opt_bool(j, "ok")?,
        })
    }
}

// -- cycle codec -------------------------------------------------------------

impl CycleEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("schema", Json::Str(CYCLE_SCHEMA.to_string())),
            ("t_s", Json::Num(self.t_s)),
            ("cycle", Json::Str(self.cycle.to_string())),
            ("state", Json::Str(self.state.clone())),
            ("artifact", Json::Str(self.artifact.clone())),
            ("decided", Json::Bool(self.decided)),
            ("switched", Json::Bool(self.switched)),
        ];
        if let Some(d) = self.drift {
            pairs.push(("drift", Json::Num(d)));
        }
        if let Some(f) = &self.family {
            pairs.push(("family", Json::Str(f.clone())));
        }
        if let Some(s) = self.sweep_s {
            pairs.push(("sweep_s", Json::Num(s)));
        }
        if let Some(t) = &self.to {
            pairs.push(("to", Json::Str(t.clone())));
        }
        if let Some(x) = self.before_mj {
            pairs.push(("before_mj", Json::Num(x)));
        }
        if let Some(x) = self.after_mj {
            pairs.push(("after_mj", Json::Num(x)));
        }
        if let Some(x) = self.reconfig_mj {
            pairs.push(("reconfig_mj", Json::Num(x)));
        }
        if let Some(x) = self.amortized_mj {
            pairs.push(("amortized_mj", Json::Num(x)));
        }
        if let Some(x) = self.net_gain_mj {
            pairs.push(("net_gain_mj", Json::Num(x)));
        }
        if let Some(x) = self.margin_mj {
            pairs.push(("margin_mj", Json::Num(x)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CycleEvent> {
        check_schema(j, CYCLE_SCHEMA)?;
        Ok(CycleEvent {
            t_s: num(j, "t_s")?,
            cycle: uint64(j, "cycle")?,
            state: string(j, "state")?.to_string(),
            artifact: string(j, "artifact")?.to_string(),
            drift: opt_num(j, "drift")?,
            family: opt_string(j, "family")?,
            sweep_s: opt_num(j, "sweep_s")?,
            decided: boolean(j, "decided")?,
            switched: boolean(j, "switched")?,
            to: opt_string(j, "to")?,
            before_mj: opt_num(j, "before_mj")?,
            after_mj: opt_num(j, "after_mj")?,
            reconfig_mj: opt_num(j, "reconfig_mj")?,
            amortized_mj: opt_num(j, "amortized_mj")?,
            net_gain_mj: opt_num(j, "net_gain_mj")?,
            margin_mj: opt_num(j, "margin_mj")?,
        })
    }
}

// -- swap codec --------------------------------------------------------------

impl SwapEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("schema", Json::Str(SWAP_SCHEMA.to_string())),
            ("t_s", Json::Num(self.t_s)),
            ("phase", Json::Str(self.phase.clone())),
            ("to", Json::Str(self.to.clone())),
        ];
        if let Some(s) = self.shard {
            pairs.push(("shard", Json::Num(s as f64)));
        }
        if let Some(d) = self.drain_rejected {
            pairs.push(("drain_rejected", Json::Num(d as f64)));
        }
        if let Some(d) = &self.detail {
            pairs.push(("detail", Json::Str(d.clone())));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SwapEvent> {
        check_schema(j, SWAP_SCHEMA)?;
        Ok(SwapEvent {
            t_s: num(j, "t_s")?,
            phase: string(j, "phase")?.to_string(),
            to: string(j, "to")?.to_string(),
            shard: opt_uint(j, "shard")?,
            drain_rejected: opt_u64(j, "drain_rejected")?,
            detail: opt_string(j, "detail")?,
        })
    }
}

// -- worker codec ------------------------------------------------------------

impl WorkerEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("schema", Json::Str(WORKER_SCHEMA.to_string())),
            ("t_s", Json::Num(self.t_s)),
            ("kind", Json::Str(self.kind.clone())),
            ("shard", Json::Num(self.shard as f64)),
        ];
        if let Some(a) = self.attempt {
            pairs.push(("attempt", Json::Num(a as f64)));
        }
        if let Some(d) = &self.detail {
            pairs.push(("detail", Json::Str(d.clone())));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<WorkerEvent> {
        check_schema(j, WORKER_SCHEMA)?;
        let shard_f = num(j, "shard")?;
        anyhow::ensure!(
            shard_f >= 0.0 && shard_f.fract() == 0.0,
            "field 'shard' is not a whole number: {shard_f}"
        );
        Ok(WorkerEvent {
            t_s: num(j, "t_s")?,
            kind: string(j, "kind")?.to_string(),
            shard: shard_f as usize,
            attempt: opt_uint(j, "attempt")?,
            detail: opt_string(j, "detail")?,
        })
    }
}

// -- envelope ----------------------------------------------------------------

/// Encode any event as its schema-tagged JSON object (one JSONL line
/// when dumped).
pub fn encode(ev: &Event) -> Json {
    match ev {
        Event::Span(e) => e.to_json(),
        Event::Cycle(e) => e.to_json(),
        Event::Swap(e) => e.to_json(),
        Event::Worker(e) => e.to_json(),
    }
}

/// Decode one journal record by its schema tag.
pub fn decode(j: &Json) -> anyhow::Result<Event> {
    let schema = j
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or_else(|| anyhow!("journal record without a schema tag"))?;
    match schema {
        SPAN_SCHEMA => Ok(Event::Span(SpanEvent::from_json(j)?)),
        CYCLE_SCHEMA => Ok(Event::Cycle(CycleEvent::from_json(j)?)),
        SWAP_SCHEMA => Ok(Event::Swap(SwapEvent::from_json(j)?)),
        WORKER_SCHEMA => Ok(Event::Worker(WorkerEvent::from_json(j)?)),
        other => Err(anyhow!("unknown journal schema '{other}'")),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn round_trip(ev: &Event) {
        let line = encode(ev).dump();
        let back = decode(&parse(&line).unwrap()).unwrap();
        assert_eq!(*ev, back, "round trip changed the event: {line}");
    }

    #[test]
    fn span_round_trips_minimal_and_full() {
        let mut e = SpanEvent::new(1, "submit", "syn.0");
        e.t_s = 0.25;
        round_trip(&Event::Span(e));
        let full = SpanEvent {
            t_s: 1.5,
            id: u64::MAX - 1,
            stage: "done".into(),
            artifact: "syn.1".into(),
            shard: Some(3),
            queue_wait_s: Some(0.001),
            exec_s: Some(0.002),
            batch: Some(4),
            ok: Some(true),
        };
        round_trip(&Event::Span(full));
    }

    #[test]
    fn cycle_round_trips_rejection_arithmetic() {
        let mut e = CycleEvent::new(7, "sweeping", "syn.0");
        e.t_s = 2.5;
        e.drift = Some(0.75);
        e.family = Some("poisson".into());
        e.sweep_s = Some(0.125);
        e.decided = true;
        e.switched = false;
        e.to = Some("cand-b".into());
        e.before_mj = Some(1.25);
        e.after_mj = Some(1.0);
        e.reconfig_mj = Some(10.0);
        e.amortized_mj = Some(0.5);
        e.net_gain_mj = Some(-0.25);
        e.margin_mj = Some(0.0);
        round_trip(&Event::Cycle(e));
        let mut bare = CycleEvent::new(0, "observing", "syn.0");
        bare.t_s = 0.5;
        round_trip(&Event::Cycle(bare));
    }

    #[test]
    fn swap_and_worker_round_trip() {
        let mut s = SwapEvent::new("committed", "cand-b");
        s.t_s = 3.25;
        s.shard = Some(1);
        s.drain_rejected = Some(2);
        s.detail = Some("drain ok".into());
        round_trip(&Event::Swap(s));
        let mut w = WorkerEvent::new("timeout", 5);
        w.t_s = 4.5;
        w.attempt = Some(2);
        w.detail = Some("worker timed out after 300s".into());
        round_trip(&Event::Worker(w));
    }

    #[test]
    fn decode_rejects_bad_schema_and_missing_fields() {
        assert!(decode(&parse("{\"x\":1}").unwrap()).is_err());
        assert!(decode(&parse("{\"schema\":\"elastic-gen/obs-span/v9\"}").unwrap()).is_err());
        // right tag, missing required field
        let j = parse(&format!("{{\"schema\":\"{SPAN_SCHEMA}\",\"t_s\":1.0}}")).unwrap();
        assert!(SpanEvent::from_json(&j).is_err());
    }

    #[test]
    fn u64_ids_cross_exactly() {
        let mut e = SpanEvent::new(u64::MAX, "submit", "a");
        e.t_s = 1.0;
        let line = e.to_json().dump();
        let back = SpanEvent::from_json(&parse(&line).unwrap()).unwrap();
        assert_eq!(back.id, u64::MAX);
    }
}
