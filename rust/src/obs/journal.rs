//! Bounded, poison-safe structured event journal.
//!
//! Every energy/latency decision the serving + dist stack makes leaves a
//! typed event here: request spans (`SpanEvent`, one per lifecycle
//! stage), supervisor cycles (`CycleEvent`, rejected switch decisions
//! included with their margin arithmetic), coordinator swap phases
//! (`SwapEvent`) and dist-driver worker lifecycle (`WorkerEvent`).
//!
//! The in-memory ring is bounded (`cap`, oldest evicted first) so a
//! long-lived server cannot leak; when a JSONL writer is attached
//! (`with_writer`, the `--obs-log` flag) every event is *also* streamed
//! to disk before eviction, so the on-disk journal is complete even when
//! the ring has wrapped.  Locks go through `util::sync::locked` — a
//! panicking recorder must not take observability down with it — and
//! the ring and writer are guarded separately so neither is ever
//! acquired under the other.
//!
//! Timestamps are seconds since the journal's creation, stamped here
//! (`record`) rather than by callers: the parity-scoped dist driver can
//! then emit lifecycle events without touching a wall clock itself.
//! Span ids reuse the coordinator's deterministic request counter — no
//! entropy anywhere in the layer, so parity tests stay bit-identical.

use crate::util::sync::locked;
use anyhow::Context;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default bound on the in-memory event ring.
pub const DEFAULT_RING_CAP: usize = 16_384;

/// One stage of a request's lifecycle.  A served request emits the chain
/// submit → enqueue → exec → done under one `id`; an admission loss
/// emits a single terminal `reject`/`drain-reject` with `id` 0 (the
/// request never earned an id).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    /// Seconds since the journal epoch (stamped by `Journal::record`).
    pub t_s: f64,
    /// Trace id — the coordinator's request id (deterministic counter).
    pub id: u64,
    /// submit | enqueue | exec | done | reject | drain-reject.
    pub stage: String,
    pub artifact: String,
    pub shard: Option<usize>,
    /// Stamped on `exec`: seconds spent queued before batch pickup.
    pub queue_wait_s: Option<f64>,
    /// Stamped on `done`: engine execution seconds.
    pub exec_s: Option<f64>,
    /// Stamped on `exec`: how many requests the micro-batch drained.
    pub batch: Option<usize>,
    /// Stamped on `done`: engine success or failure.
    pub ok: Option<bool>,
}

impl SpanEvent {
    pub fn new(id: u64, stage: &str, artifact: &str) -> SpanEvent {
        SpanEvent {
            t_s: 0.0,
            id,
            stage: stage.to_string(),
            artifact: artifact.to_string(),
            shard: None,
            queue_wait_s: None,
            exec_s: None,
            batch: None,
            ok: None,
        }
    }
}

/// One supervisor cycle: what the drift monitor observed and — when a
/// sweep ran — the full switch-decision arithmetic, rejections included
/// (a decision that *doesn't* fire is exactly what anti-flapping
/// analysis needs to see).
#[derive(Debug, Clone, PartialEq)]
pub struct CycleEvent {
    pub t_s: f64,
    /// Monotonic cycle counter within this supervisor.
    pub cycle: u64,
    /// AdaptState name at the end of the cycle.
    pub state: String,
    pub artifact: String,
    pub drift: Option<f64>,
    /// Fitted interarrival family, when the cycle got as far as fitting.
    pub family: Option<String>,
    /// Background sweep wall-clock seconds, when a sweep ran.
    pub sweep_s: Option<f64>,
    /// True when the cycle produced a switch decision (either way).
    pub decided: bool,
    /// True when that decision committed a swap.
    pub switched: bool,
    pub to: Option<String>,
    pub before_mj: Option<f64>,
    pub after_mj: Option<f64>,
    pub reconfig_mj: Option<f64>,
    pub amortized_mj: Option<f64>,
    /// before - after - amortized: the quantity the margin gates.
    pub net_gain_mj: Option<f64>,
    pub margin_mj: Option<f64>,
}

impl CycleEvent {
    pub fn new(cycle: u64, state: &str, artifact: &str) -> CycleEvent {
        CycleEvent {
            t_s: 0.0,
            cycle,
            state: state.to_string(),
            artifact: artifact.to_string(),
            drift: None,
            family: None,
            sweep_s: None,
            decided: false,
            switched: false,
            to: None,
            before_mj: None,
            after_mj: None,
            reconfig_mj: None,
            amortized_mj: None,
            net_gain_mj: None,
            margin_mj: None,
        }
    }
}

/// One phase of a drain-and-switch engine swap.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapEvent {
    pub t_s: f64,
    /// drain-start | engine-built | aborted | committed.
    pub phase: String,
    /// Target candidate/config description.
    pub to: String,
    /// Set on per-shard phases (engine-built / aborted).
    pub shard: Option<usize>,
    /// Set on committed: requests bounced during this drain window.
    pub drain_rejected: Option<u64>,
    pub detail: Option<String>,
}

impl SwapEvent {
    pub fn new(phase: &str, to: &str) -> SwapEvent {
        SwapEvent {
            t_s: 0.0,
            phase: phase.to_string(),
            to: to.to_string(),
            shard: None,
            drain_rejected: None,
            detail: None,
        }
    }
}

/// One dist-driver worker lifecycle transition.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerEvent {
    pub t_s: f64,
    /// spawn | exit | timeout | reassign | quarantine.
    pub kind: String,
    /// Shard index the worker was executing.
    pub shard: usize,
    /// Subprocess attempt number, when attributable to one.
    pub attempt: Option<usize>,
    /// Failure text / quarantine cause.
    pub detail: Option<String>,
}

impl WorkerEvent {
    pub fn new(kind: &str, shard: usize) -> WorkerEvent {
        WorkerEvent {
            t_s: 0.0,
            kind: kind.to_string(),
            shard,
            attempt: None,
            detail: None,
        }
    }
}

/// Any journal event (the ring's element type; see `obs::wire` for the
/// schema-tagged codecs).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Span(SpanEvent),
    Cycle(CycleEvent),
    Swap(SwapEvent),
    Worker(WorkerEvent),
}

impl Event {
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Span(_) => "span",
            Event::Cycle(_) => "cycle",
            Event::Swap(_) => "swap",
            Event::Worker(_) => "worker",
        }
    }

    pub fn t_s(&self) -> f64 {
        match self {
            Event::Span(e) => e.t_s,
            Event::Cycle(e) => e.t_s,
            Event::Swap(e) => e.t_s,
            Event::Worker(e) => e.t_s,
        }
    }

    /// Stamp an unset (0.0) timestamp — the `record_switch(at_s == 0.0)`
    /// convention, so replay/test events with explicit times pass
    /// through untouched.
    fn stamp(&mut self, t: f64) {
        let slot = match self {
            Event::Span(e) => &mut e.t_s,
            Event::Cycle(e) => &mut e.t_s,
            Event::Swap(e) => &mut e.t_s,
            Event::Worker(e) => &mut e.t_s,
        };
        if *slot == 0.0 {
            *slot = t;
        }
    }
}

/// Thread-safe bounded event journal with optional JSONL streaming.
#[derive(Debug)]
pub struct Journal {
    start: Instant,
    cap: usize,
    ring: Mutex<VecDeque<Event>>,
    writer: Mutex<Option<BufWriter<File>>>,
    recorded: AtomicU64,
    evicted: AtomicU64,
    write_errors: AtomicU64,
}

impl Journal {
    /// In-memory journal bounded at `cap` events.
    pub fn new(cap: usize) -> Journal {
        Journal {
            start: Instant::now(),
            cap: cap.max(1),
            ring: Mutex::new(VecDeque::new()),
            writer: Mutex::new(None),
            recorded: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
        }
    }

    /// Journal that additionally streams every event to `path` as JSONL
    /// (one schema-tagged object per line) — the `--obs-log` sink.
    pub fn with_writer(cap: usize, path: &Path) -> anyhow::Result<Journal> {
        let file = File::create(path)
            .with_context(|| format!("creating obs log {}", path.display()))?;
        let j = Journal::new(cap);
        *locked(&j.writer) = Some(BufWriter::new(file));
        Ok(j)
    }

    /// Seconds since the journal epoch.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Record one event: stamp its timestamp (if unset), append to the
    /// bounded ring, and stream it to the writer when one is attached.
    /// Never blocks on anything but the two short internal locks and
    /// never panics — a full ring evicts, a failed write counts.
    pub fn record(&self, mut ev: Event) {
        ev.stamp(self.elapsed_s());
        self.recorded.fetch_add(1, Ordering::Relaxed);
        let line = super::wire::encode(&ev).dump();
        {
            let mut ring = locked(&self.ring);
            while ring.len() >= self.cap {
                ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
            ring.push_back(ev);
        }
        let mut w = locked(&self.writer);
        if let Some(out) = w.as_mut() {
            if writeln!(out, "{line}").is_err() {
                self.write_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Current ring contents, oldest first.
    pub fn events(&self) -> Vec<Event> {
        locked(&self.ring).iter().cloned().collect()
    }

    /// Events currently held in the ring (≤ cap).
    pub fn len(&self) -> usize {
        locked(&self.ring).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded.
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Events evicted from the ring to stay under cap (still on disk
    /// when a writer is attached).
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Flush the JSONL writer and surface any write failures swallowed
    /// on the record path.
    pub fn flush(&self) -> anyhow::Result<()> {
        {
            let mut w = locked(&self.writer);
            if let Some(out) = w.as_mut() {
                out.flush().context("flushing obs log")?;
            }
        }
        let errs = self.write_errors.load(Ordering::Relaxed);
        anyhow::ensure!(errs == 0, "{errs} obs log write(s) failed");
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_is_bounded_and_counts_evictions() {
        let j = Journal::new(16);
        for i in 0..100 {
            j.record(Event::Span(SpanEvent::new(i, "submit", "a")));
        }
        assert_eq!(j.len(), 16);
        assert_eq!(j.recorded(), 100);
        assert_eq!(j.evicted(), 84);
        let evs = j.events();
        // oldest evicted: ring holds ids 84..=99
        match &evs[0] {
            Event::Span(s) => assert_eq!(s.id, 84),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn record_stamps_unset_timestamps_monotonically() {
        let j = Journal::new(8);
        j.record(Event::Span(SpanEvent::new(1, "submit", "a")));
        j.record(Event::Span(SpanEvent::new(1, "enqueue", "a")));
        let evs = j.events();
        assert!(evs[0].t_s() >= 0.0);
        assert!(evs[1].t_s() >= evs[0].t_s());
        // an explicit timestamp passes through untouched
        let mut pre = SpanEvent::new(2, "exec", "a");
        pre.t_s = 123.5;
        j.record(Event::Span(pre));
        assert_eq!(j.events()[2].t_s(), 123.5);
    }

    #[test]
    fn journal_survives_a_poisoned_ring_lock() {
        let j = Arc::new(Journal::new(8));
        j.record(Event::Worker(WorkerEvent::new("spawn", 0)));
        let j2 = j.clone();
        let _ = std::thread::spawn(move || {
            let _guard = j2.ring.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert!(j.ring.is_poisoned());
        j.record(Event::Worker(WorkerEvent::new("exit", 0)));
        assert_eq!(j.len(), 2);
        assert!(j.flush().is_ok());
    }

    #[test]
    fn writer_streams_past_ring_eviction() {
        let dir = std::env::temp_dir().join(format!("elastic-obs-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        let j = Journal::with_writer(4, &path).unwrap();
        for i in 0..20 {
            j.record(Event::Span(SpanEvent::new(i, "submit", "a")));
        }
        j.flush().unwrap();
        assert_eq!(j.len(), 4, "ring stays bounded");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 20, "the file keeps what the ring evicts");
        for line in lines {
            let parsed = crate::util::json::parse(line).unwrap();
            super::super::wire::decode(&parsed).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
