//! Structured observability: bounded histograms, the event journal, and
//! its report renderer.
//!
//! Three pieces, all fixed-memory and panic-free (this module is serving
//! scope under the repo linter):
//!
//! * [`hist::Hist`] — 256-bucket log-scaled latency histograms with
//!   exact count/mean/std/min/max and bucket-interpolated p50/p90/p99;
//!   they replace the unbounded per-request sample vectors `Metrics`
//!   used to keep.
//! * [`journal::Journal`] — a bounded, poison-safe ring of typed events
//!   ([`journal::SpanEvent`] request lifecycles, [`journal::CycleEvent`]
//!   supervisor decisions including rejections, [`journal::SwapEvent`]
//!   drain-and-switch phases, [`journal::WorkerEvent`] dist worker
//!   lifecycle) with optional JSONL streaming (`--obs-log`).
//! * [`report`] — renders a decoded journal into the `elastic-gen obs`
//!   tables: per-stage latency, switch-decision audit, worker timeline.
//!
//! The JSONL journal is a wire format; [`wire`] holds the schema-tagged
//! codecs and lives under the same lint wire rules as the dist shard
//! protocol.

#![warn(clippy::unwrap_used, clippy::indexing_slicing)]

pub mod hist;
pub mod journal;
pub mod report;
pub mod wire;

pub use hist::Hist;
pub use journal::{
    CycleEvent, Event, Journal, SpanEvent, SwapEvent, WorkerEvent, DEFAULT_RING_CAP,
};
pub use report::{chains, render, ChainSummary};
