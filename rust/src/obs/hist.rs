//! Fixed-memory log-bucketed latency histogram.
//!
//! `Metrics` used to keep every latency sample in a per-artifact
//! `Vec<f64>` — O(requests) memory on a server whose north star is
//! millions of users.  `Hist` replaces those vectors with a fixed
//! 256-bucket geometric layout: bucket 0 absorbs everything at or below
//! 1 ns, and each later bucket spans a factor of 2^(1/4) (four buckets
//! per octave), reaching past 10^10 s at the top.  Quantiles are read
//! back by linear interpolation inside the owning bucket, so p50/p90/p99
//! carry at most ~9% relative error while count/sum/min/max — and
//! therefore mean and std — stay exact.
//!
//! Histograms are mergeable (`merge`), which is what lets per-shard and
//! per-worker recordings fold into one fleet view without shipping raw
//! samples, and the snapshot surface (`summary`) is the same
//! `Option<Summary>` the old vectors produced, so `MetricsSnapshot`
//! consumers did not have to change.

use crate::util::stats::Summary;

/// Bucket count; fixed, so `size_of::<Hist>()` is the whole story.
pub const BUCKETS: usize = 256;

/// Upper edge of bucket 0 (seconds): nothing we time resolves below 1 ns.
const LO: f64 = 1e-9;

/// Sub-buckets per octave; 2^(1/4) ≈ 1.19 per step bounds the relative
/// quantile error at the bucket width.
const PER_OCTAVE: f64 = 4.0;

/// Fixed-memory latency histogram with exact count/sum/min/max and
/// bucket-interpolated quantiles.
#[derive(Debug, Clone)]
pub struct Hist {
    count: u64,
    dropped: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
    buckets: [u64; BUCKETS],
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            count: 0,
            dropped: 0,
            sum: 0.0,
            sumsq: 0.0,
            // ±inf sentinels so the first sample seeds min/max; `summary`
            // never leaks them (empty -> None)
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; BUCKETS],
        }
    }

    fn index(v: f64) -> usize {
        if v <= LO {
            return 0;
        }
        let i = ((v / LO).log2() * PER_OCTAVE).floor() as isize + 1;
        i.clamp(0, BUCKETS as isize - 1) as usize
    }

    /// Value bounds of bucket `i` (geometric except bucket 0).
    fn bounds(i: usize) -> (f64, f64) {
        if i == 0 {
            return (0.0, LO);
        }
        let lo = LO * 2f64.powf((i as f64 - 1.0) / PER_OCTAVE);
        let hi = LO * 2f64.powf(i as f64 / PER_OCTAVE);
        (lo, hi)
    }

    /// Record one sample (seconds).  Non-finite samples are dropped and
    /// counted, mirroring `Summary::of`; negatives clamp to 0 (a latency
    /// below the clock's resolution, not a defect worth panicking over).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            self.dropped += 1;
            return;
        }
        let v = v.max(0.0);
        self.count += 1;
        self.sum += v;
        self.sumsq += v * v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        if let Some(b) = self.buckets.get_mut(Self::index(v)) {
            *b += 1;
        }
    }

    /// Fold another histogram in (shard/worker aggregation).
    pub fn merge(&mut self, o: &Hist) {
        self.count += o.count;
        self.dropped += o.dropped;
        self.sum += o.sum;
        self.sumsq += o.sumsq;
        if o.min < self.min {
            self.min = o.min;
        }
        if o.max > self.max {
            self.max = o.max;
        }
        for (mine, theirs) in self.buckets.iter_mut().zip(o.buckets.iter()) {
            *mine += theirs;
        }
    }

    /// Finite samples recorded (exact).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Non-finite samples dropped (exact).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// True when nothing was ever recorded (dropped included).
    pub fn is_empty(&self) -> bool {
        self.count == 0 && self.dropped == 0
    }

    /// Largest sample (exact); 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Bucket-interpolated quantile, `p` in [0, 100].  The rank
    /// convention matches `stats::percentile_sorted` (rank p/100·(n-1));
    /// the returned value is clamped into [min, max] so a single-valued
    /// series reads back its exact value.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = p.clamp(0.0, 100.0) / 100.0 * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let next = cum + n;
            if (next as f64) > rank {
                let (lo, hi) = Self::bounds(i);
                // mid-sample offset: k samples occupy the bucket at
                // fractions (0.5, 1.5, …)/k of its width
                let frac = ((rank - cum as f64 + 0.5) / n as f64).clamp(0.0, 1.0);
                return (lo + (hi - lo) * frac).clamp(self.min, self.max);
            }
            cum = next;
        }
        self.max()
    }

    /// `Option<Summary>`-compatible snapshot: `None` before the first
    /// `record` call, an all-zero summary when every sample was dropped
    /// as non-finite — the exact contract `Summary::of` gave the old
    /// sample vectors.  mean/std/min/max are exact; p50/p90/p99 are
    /// bucket-interpolated.
    pub fn summary(&self) -> Option<Summary> {
        if self.is_empty() {
            return None;
        }
        if self.count == 0 {
            return Some(Summary {
                n: 0,
                dropped: self.dropped as usize,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            });
        }
        let n = self.count as f64;
        let mean = self.sum / n;
        let var = (self.sumsq / n - mean * mean).max(0.0);
        Some(Summary {
            n: self.count as usize,
            dropped: self.dropped as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            p50: self.quantile(50.0),
            p90: self.quantile(90.0),
            p99: self.quantile(99.0),
            max: self.max,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn empty_and_single_value() {
        let mut h = Hist::new();
        assert!(h.summary().is_none());
        assert_eq!(h.quantile(50.0), 0.0);
        h.record(0.0035);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 1);
        assert!((s.mean - 0.0035).abs() < 1e-15);
        assert_eq!(s.min, 0.0035);
        assert_eq!(s.max, 0.0035);
        // single value: clamped interpolation reads back exactly
        assert_eq!(s.p50, 0.0035);
        assert_eq!(s.p99, 0.0035);
    }

    #[test]
    fn mean_and_std_are_exact() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut h = Hist::new();
        for &x in &xs {
            h.record(x);
        }
        let s = h.summary().unwrap();
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
        assert_eq!(s.n, 8);
    }

    #[test]
    fn quantiles_track_exact_percentiles() {
        // log-uniform latencies over ~4 decades: the realistic worst case
        // for a geometric layout
        let mut rng = Rng::new(42);
        let samples: Vec<f64> = (0..20_000)
            .map(|_| 1e-5 * 10f64.powf(rng.f64() * 4.0))
            .collect();
        let mut h = Hist::new();
        for &x in &samples {
            h.record(x);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for p in [50.0, 90.0, 99.0] {
            let exact = percentile_sorted(&sorted, p);
            let approx = h.quantile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.10, "p{p}: exact {exact:.6e}, approx {approx:.6e}, rel {rel:.3}");
        }
        assert_eq!(h.summary().unwrap().max, sorted[sorted.len() - 1]);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..500).map(|_| rng.f64() * 0.01).collect();
        let (a_half, b_half) = xs.split_at(250);
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut all = Hist::new();
        for &x in a_half {
            a.record(x);
            all.record(x);
        }
        for &x in b_half {
            b.record(x);
            all.record(x);
        }
        a.merge(&b);
        let (sa, sc) = (a.summary().unwrap(), all.summary().unwrap());
        assert_eq!(sa.n, sc.n);
        assert_eq!(sa.min, sc.min);
        assert_eq!(sa.max, sc.max);
        assert!((sa.mean - sc.mean).abs() < 1e-15);
        assert_eq!(sa.p50, sc.p50);
        assert_eq!(sa.p99, sc.p99);
    }

    #[test]
    fn non_finite_dropped_and_counted() {
        let mut h = Hist::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 0);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.mean, 0.0);
        h.record(1.0);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 1);
        assert_eq!(s.dropped, 2);
        assert!((s.mean - 1.0).abs() < 1e-15);
    }

    #[test]
    fn extremes_land_in_end_buckets_without_panicking() {
        let mut h = Hist::new();
        h.record(0.0);
        h.record(-1.0); // clamps to 0
        h.record(1e-12);
        h.record(1e12);
        let s = h.summary().unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 1e12);
    }

    #[test]
    fn memory_is_fixed() {
        // the whole point: recording more samples allocates nothing
        let before = std::mem::size_of::<Hist>();
        let mut h = Hist::new();
        for i in 0..100_000 {
            h.record(i as f64 * 1e-6);
        }
        assert_eq!(std::mem::size_of_val(&h), before);
        assert_eq!(h.count(), 100_000);
    }
}
