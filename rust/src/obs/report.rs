//! Render a decoded journal into the `elastic-gen obs` report: per-stage
//! latency breakdowns (rebuilt into `Hist`s, so the report's quantiles
//! use the same bucket scheme the live metrics do), a switch-decision
//! audit table with the full margin arithmetic (rejections included —
//! that is the whole point of recording them), and the dist worker
//! lifecycle timeline.
//!
//! Everything here is pure over `&[Event]` and returns `String`s; the
//! unscoped CLI layer owns the actual printing (this module is serving
//! scope, where `obs-print` forbids direct stdout).

use super::hist::Hist;
use super::journal::Event;
use crate::util::table::{num, Table};
use std::collections::BTreeMap;

/// Span-chain completeness over a journal: every accepted request must
/// show the full submit → enqueue → exec → done chain under its id, and
/// every admission loss must show a terminal reject event (id 0).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChainSummary {
    /// Distinct non-zero trace ids seen.
    pub ids: usize,
    /// Ids whose chain carries all four stages.
    pub complete: usize,
    /// Ids with at least one stage missing, ascending.
    pub incomplete: Vec<u64>,
    /// Terminal `reject` events.
    pub rejects: usize,
    /// Terminal `drain-reject` events.
    pub drain_rejects: usize,
}

impl ChainSummary {
    pub fn all_complete(&self) -> bool {
        self.incomplete.is_empty()
    }
}

fn stage_bit(stage: &str) -> u8 {
    match stage {
        "submit" => 1,
        "enqueue" => 2,
        "exec" => 4,
        "done" => 8,
        _ => 0,
    }
}

/// Fold span events into a completeness summary.
pub fn chains(events: &[Event]) -> ChainSummary {
    let mut seen: BTreeMap<u64, u8> = BTreeMap::new();
    let mut out = ChainSummary::default();
    for ev in events {
        let Event::Span(s) = ev else { continue };
        match s.stage.as_str() {
            "reject" => out.rejects += 1,
            "drain-reject" => out.drain_rejects += 1,
            stage if s.id != 0 => {
                *seen.entry(s.id).or_insert(0) |= stage_bit(stage);
            }
            _ => {}
        }
    }
    out.ids = seen.len();
    for (id, mask) in seen {
        if mask == 0b1111 {
            out.complete += 1;
        } else {
            out.incomplete.push(id);
        }
    }
    out
}

/// Per-artifact stage histograms rebuilt from span events.
#[derive(Debug, Default)]
struct StageHists {
    spans: u64,
    queue: Hist,
    exec: Hist,
    e2e: Hist,
}

fn ms(seconds: f64) -> String {
    num(seconds * 1e3, 3)
}

fn opt4(x: Option<f64>) -> String {
    match x {
        Some(v) => num(v, 4),
        None => "-".to_string(),
    }
}

/// Per-artifact latency breakdown table (queue wait from `exec` spans,
/// engine time from `done` spans, end-to-end from matched submit→done
/// timestamps under one id).
fn latency_breakdown(events: &[Event]) -> String {
    // first pass: submit/done timestamps per id, for the e2e read
    let mut submit_t: BTreeMap<u64, f64> = BTreeMap::new();
    for ev in events {
        let Event::Span(s) = ev else { continue };
        if s.id != 0 && s.stage == "submit" {
            submit_t.insert(s.id, s.t_s);
        }
    }
    let mut per: BTreeMap<String, StageHists> = BTreeMap::new();
    for ev in events {
        let Event::Span(s) = ev else { continue };
        if s.id == 0 {
            continue;
        }
        let slot = per.entry(s.artifact.clone()).or_default();
        match s.stage.as_str() {
            "submit" => slot.spans += 1,
            "exec" => {
                if let Some(q) = s.queue_wait_s {
                    slot.queue.record(q);
                }
            }
            "done" => {
                if let Some(x) = s.exec_s {
                    slot.exec.record(x);
                }
                if let Some(t0) = submit_t.get(&s.id) {
                    slot.e2e.record(s.t_s - t0);
                }
            }
            _ => {}
        }
    }
    if per.is_empty() {
        return "no request spans in the journal\n".to_string();
    }
    let mut t = Table::new(&[
        "artifact", "spans", "queue p50", "queue p99", "exec p50", "exec p99", "e2e p50",
        "e2e p99", "e2e max",
    ])
    .with_title("Per-stage latency (ms)");
    for (artifact, h) in &per {
        t.row(&[
            artifact.clone(),
            h.spans.to_string(),
            ms(h.queue.quantile(50.0)),
            ms(h.queue.quantile(99.0)),
            ms(h.exec.quantile(50.0)),
            ms(h.exec.quantile(99.0)),
            ms(h.e2e.quantile(50.0)),
            ms(h.e2e.quantile(99.0)),
            ms(h.e2e.max()),
        ]);
    }
    t.render()
}

/// Supervisor decision audit: one row per decided cycle with the margin
/// arithmetic spelled out, plus the swap phases that followed.
fn switch_audit(events: &[Event]) -> String {
    let mut t = Table::new(&[
        "t_s", "cycle", "state", "drift", "before_mj", "after_mj", "amortized_mj",
        "net_gain_mj", "margin_mj", "to", "verdict",
    ])
    .with_title("Switch-decision audit");
    let mut cycles_without_decision = 0usize;
    for ev in events {
        let Event::Cycle(c) = ev else { continue };
        if !c.decided {
            cycles_without_decision += 1;
            continue;
        }
        t.row(&[
            num(c.t_s, 2),
            c.cycle.to_string(),
            c.state.clone(),
            opt4(c.drift),
            opt4(c.before_mj),
            opt4(c.after_mj),
            opt4(c.amortized_mj),
            opt4(c.net_gain_mj),
            opt4(c.margin_mj),
            c.to.clone().unwrap_or_else(|| "-".to_string()),
            if c.switched { "committed" } else { "rejected" }.to_string(),
        ]);
    }
    let mut out = String::new();
    if t.is_empty() {
        out.push_str("no switch decisions in the journal\n");
    } else {
        out.push_str(&t.render());
    }
    if cycles_without_decision > 0 {
        out.push_str(&format!(
            "({cycles_without_decision} cycle(s) ended before a decision: observing/fitting)\n"
        ));
    }

    let mut phases = Table::new(&["t_s", "phase", "to", "shard", "drain_rejected", "detail"])
        .with_title("Swap phases");
    for ev in events {
        let Event::Swap(s) = ev else { continue };
        phases.row(&[
            num(s.t_s, 2),
            s.phase.clone(),
            s.to.clone(),
            s.shard.map(|x| x.to_string()).unwrap_or_else(|| "-".to_string()),
            s.drain_rejected
                .map(|x| x.to_string())
                .unwrap_or_else(|| "-".to_string()),
            s.detail.clone().unwrap_or_else(|| "-".to_string()),
        ]);
    }
    if !phases.is_empty() {
        out.push('\n');
        out.push_str(&phases.render());
    }
    out
}

/// Dist-driver worker lifecycle timeline.
fn worker_timeline(events: &[Event]) -> String {
    let mut t = Table::new(&["t_s", "kind", "shard", "attempt", "detail"])
        .with_title("Worker lifecycle");
    for ev in events {
        let Event::Worker(w) = ev else { continue };
        t.row(&[
            num(w.t_s, 2),
            w.kind.clone(),
            w.shard.to_string(),
            w.attempt.map(|a| a.to_string()).unwrap_or_else(|| "-".to_string()),
            w.detail.clone().unwrap_or_else(|| "-".to_string()),
        ]);
    }
    if t.is_empty() {
        String::new()
    } else {
        t.render()
    }
}

/// The full `elastic-gen obs` report over a decoded journal.
pub fn render(events: &[Event]) -> String {
    if events.is_empty() {
        return "journal is empty\n".to_string();
    }
    let mut out = String::new();
    let c = chains(events);
    out.push_str(&format!(
        "journal: {} event(s); span chains: {} id(s), {} complete, {} incomplete, \
         {} reject(s), {} drain-reject(s)\n",
        events.len(),
        c.ids,
        c.complete,
        c.incomplete.len(),
        c.rejects,
        c.drain_rejects,
    ));
    if !c.incomplete.is_empty() {
        let shown: Vec<String> =
            c.incomplete.iter().take(8).map(|id| id.to_string()).collect();
        out.push_str(&format!("incomplete chain ids: {}\n", shown.join(", ")));
    }
    out.push('\n');
    out.push_str(&latency_breakdown(events));
    out.push('\n');
    out.push_str(&switch_audit(events));
    let workers = worker_timeline(events);
    if !workers.is_empty() {
        out.push('\n');
        out.push_str(&workers);
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::journal::{CycleEvent, SpanEvent, SwapEvent, WorkerEvent};
    use super::*;

    fn span(id: u64, stage: &str, t: f64) -> Event {
        let mut s = SpanEvent::new(id, stage, "syn.0");
        s.t_s = t;
        if stage == "exec" {
            s.queue_wait_s = Some(0.001);
            s.batch = Some(2);
        }
        if stage == "done" {
            s.exec_s = Some(0.002);
            s.ok = Some(true);
        }
        Event::Span(s)
    }

    fn full_chain(id: u64, t0: f64) -> Vec<Event> {
        vec![
            span(id, "submit", t0),
            span(id, "enqueue", t0 + 0.0001),
            span(id, "exec", t0 + 0.001),
            span(id, "done", t0 + 0.003),
        ]
    }

    #[test]
    fn chains_classify_complete_incomplete_and_rejects() {
        let mut evs = full_chain(1, 0.1);
        evs.extend(full_chain(2, 0.2));
        evs.push(span(3, "submit", 0.3)); // truncated chain
        evs.push(span(0, "reject", 0.4));
        evs.push(span(0, "drain-reject", 0.5));
        let c = chains(&evs);
        assert_eq!(c.ids, 3);
        assert_eq!(c.complete, 2);
        assert_eq!(c.incomplete, vec![3]);
        assert_eq!(c.rejects, 1);
        assert_eq!(c.drain_rejects, 1);
        assert!(!c.all_complete());
    }

    #[test]
    fn render_covers_every_section() {
        let mut evs = full_chain(1, 0.1);
        let mut rejected = CycleEvent::new(3, "sweeping", "syn.0");
        rejected.t_s = 1.0;
        rejected.decided = true;
        rejected.net_gain_mj = Some(-0.5);
        rejected.margin_mj = Some(0.0);
        rejected.to = Some("cand-b".into());
        evs.push(Event::Cycle(rejected));
        let mut committed = CycleEvent::new(4, "switched", "syn.0");
        committed.t_s = 2.0;
        committed.decided = true;
        committed.switched = true;
        committed.net_gain_mj = Some(1.5);
        committed.to = Some("cand-b".into());
        evs.push(Event::Cycle(committed));
        let mut swap = SwapEvent::new("committed", "cand-b");
        swap.t_s = 2.1;
        swap.drain_rejected = Some(2);
        evs.push(Event::Swap(swap));
        let mut w = WorkerEvent::new("quarantine", 1);
        w.t_s = 3.0;
        w.detail = Some("replay disagreement".into());
        evs.push(Event::Worker(w));

        let text = render(&evs);
        assert!(text.contains("1 id(s), 1 complete"), "{text}");
        assert!(text.contains("Per-stage latency"), "{text}");
        assert!(text.contains("rejected"), "{text}");
        assert!(text.contains("committed"), "{text}");
        assert!(text.contains("Swap phases"), "{text}");
        assert!(text.contains("Worker lifecycle"), "{text}");
        assert!(text.contains("quarantine"), "{text}");
    }

    #[test]
    fn render_empty_journal_is_graceful() {
        assert_eq!(render(&[]), "journal is empty\n");
        // spans only — audit and worker sections degrade, no panic
        let text = render(&full_chain(9, 0.0));
        assert!(text.contains("no switch decisions"), "{text}");
        assert!(!text.contains("Worker lifecycle"), "{text}");
    }
}
