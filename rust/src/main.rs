//! `elastic-gen` CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! * `generate` — run the Generator for an application scenario and print
//!   the winning configuration + its EDA report (Fig. 1 end-to-end).
//!   `--distributed N` shards the sweep across N worker processes.
//! * `dse` — the distributed sweep entry point: shard planner → worker
//!   processes → calibration-guarded Pareto-front merge
//!   (`--verify-parity` cross-checks against the single-process sweep).
//! * `dse-worker` — internal worker protocol: JSON shard spec on stdin,
//!   self-contained JSON shard result on stdout.
//! * `calibrate` — close the estimator↔simulator loop: replay each
//!   scenario's Pareto finalists through the DES, fit the closed-form
//!   energy constants against the simulated ledgers, and report rank
//!   agreement (Kendall tau) before/after, plus the refined sweep winner.
//! * `report`   — EDA-style report for an explicit design point.
//! * `simulate` — workload simulation comparing all strategies.
//! * `serve`    — load compiled artifacts and serve a synthetic request
//!   stream through the PJRT engine, printing latency metrics.
//!   `--adapt` closes the serving loop: observe arrivals, fit the
//!   workload, run the calibrated sweep in the background, and
//!   drain-and-switch the shards when the winner justifies it.
//! * `obs`      — decode a `--obs-log` JSONL event journal and render the
//!   report: span-chain completeness, per-stage latency, switch-decision
//!   audit (rejections included), worker timeline.
//! * `devices`  — print the device catalog.
//! * `verify`   — cross-check PJRT execution and the behavioural
//!   simulator against the golden vectors.
//! * `lint`     — repo-invariant static analysis (determinism /
//!   panic-surface / wire-hygiene / interprocedural panic-reach + lock
//!   discipline); exits 0 clean, 1 on unsuppressed findings, 2 on
//!   usage or I/O error.  Runs in CI and as a tier-1 test.

use anyhow::Context as _;
use elastic_gen::coordinator::{Coordinator, CoordinatorConfig, EngineSpec, SubmitError};
use elastic_gen::eda;
use elastic_gen::elastic_node::Platform;
use elastic_gen::fpga::{device, ConfigController, DEVICES};
use elastic_gen::generator::calibrate::{
    calibrate_and_refine, calibrate_and_refine_dist, calibrate_finalists, refine_with,
    CalibrateOpts, CalibratedEstimator, ModelScales,
};
use elastic_gen::generator::dist::{
    assert_front_parity, single_process_reference, worker_stdio, DistCalOutcome, DistOpts,
    DistSweep, ShardRun, WorkerMode,
};
use elastic_gen::generator::estimator::Estimate;
use elastic_gen::generator::search::exhaustive::{rank_with, Exhaustive};
use elastic_gen::generator::{
    default_threads, design_space, generate_portfolio, AppSpec, Calibration, EvalPool, Evaluator,
    Searcher, StrategyKind,
};
use elastic_gen::models::Topology;
use elastic_gen::obs::Journal;
use elastic_gen::rtl::composition::{build, BuildOpts};
use elastic_gen::rtl::fixed_point::QFormat;
use elastic_gen::runtime::{AdaptConfig, AdaptState, Golden, Manifest, Supervisor};
use elastic_gen::sim::{cost_model, NodeSim};
use elastic_gen::strategy::Strategy;
use elastic_gen::util::cli::Args;
use elastic_gen::util::rng::Rng;
use elastic_gen::util::table::{num, Table};
use elastic_gen::util::units::{Hertz, Joules, Secs};
use elastic_gen::workload::Workload;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let r = match args.subcommand() {
        Some("generate") => cmd_generate(&args),
        Some("dse") => cmd_dse(&args),
        Some("dse-worker") => worker_stdio(),
        Some("calibrate") => cmd_calibrate(&args),
        Some("report") => cmd_report(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("obs") => cmd_obs(&args),
        Some("devices") => cmd_devices(),
        Some("verify") => cmd_verify(&args),
        // lint has a three-way exit contract (0 clean / 1 findings /
        // 2 usage-or-IO error) that CI and the meta-tests script against
        Some("lint") => std::process::exit(cmd_lint(&args)),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "elastic-gen — energy-efficient DL accelerator generator\n\n\
         USAGE: elastic-gen <subcommand> [--options]\n\n\
         SUBCOMMANDS\n\
           generate  --app <soft-sensor|ecg-monitor|har-wearable> [--top N]\n\
                     [--jobs N] [--budget N] [--calibrate] [--distributed N]\n\
                     (--distributed + --calibrate = distributed refinement)\n\
           generate  --all [--jobs N] [--budget N]   (cross-scenario sweep)\n\
           dse       --workers N [--app <name>] [--jobs N] [--budget N]\n\
                     [--requests N] [--in-process] [--verify-parity]\n\
                     [--calibrate] [--obs-log <journal.jsonl>]\n\
                     (process-sharded sweep, calibration-guarded merge;\n\
                     --calibrate adds the fit + the distributed\n\
                     refinement re-rank)\n\
           dse-worker   (internal: JSON shard spec on stdin -> stdout)\n\
           calibrate [--app <name>] [--jobs N] [--requests N] [--budget N]\n\
                     [--quick] [--workers N [--in-process] [--verify-parity]]\n\
                     (estimator vs DES: fit + rank agreement; --workers\n\
                     runs the sweep AND the refinement process-sharded)\n\
           report    --model <mlp_fluid|lstm_har|cnn_ecg|attn_tiny> --device <name>\n\
                     [--clock-mhz 100] [--optimised]\n\
           simulate  --period-ms <f> [--requests N] [--device <name>]\n\
           serve     [--requests N] [--artifact <name>] [--shards N]\n\
                     [--queue-cap N] [--batch-max N] [--synthetic]\n\
                     [--obs-log <journal.jsonl>]\n\
           serve     --adapt [--inject-drift] [--expect-switch] [--quick]\n\
                     [--drift-threshold F] [--margin-mj F] [--amortize-s F]\n\
                     [--deploy-strategy <name>] [--workers N [--in-process]]\n\
                     [--obs-log <journal.jsonl>]\n\
                     (adaptive serving loop on the synthetic backend:\n\
                     observe -> fit -> calibrated sweep -> drain-and-switch)\n\
           obs       <journal.jsonl>  (render a --obs-log event journal:\n\
                     span chains, per-stage latency, switch audit,\n\
                     worker timeline)\n\
           verify    [--artifact <name>]\n\
           lint      [--root <crate-dir>] [--json <report-path>] [--graph]\n\
                     [--units] [--max-suppressions N]  (repo-invariant static\n\
                     analysis: determinism / panic-surface / wire-hygiene /\n\
                     call-graph panic-reach + lock discipline + dimensional\n\
                     unit consistency; exit 0 clean, 1 on findings, 2 on\n\
                     usage or I/O error)\n\
           devices"
    );
}

/// `elastic-gen lint` exit codes: 0 = clean, 1 = unsuppressed findings
/// (or the suppression inventory exceeds `--max-suppressions`), 2 =
/// usage or I/O error (bad root, unwritable report).  Findings are a
/// *result*, not a failure — scripts distinguish "the tree is dirty"
/// from "the tool could not run".
fn cmd_lint(args: &Args) -> i32 {
    let root = match args.get("root") {
        Some(p) => std::path::PathBuf::from(p),
        None => match elastic_gen::analysis::find_crate_root() {
            Ok(r) => r,
            Err(e) => {
                eprintln!("lint: error: {e:#}");
                return 2;
            }
        },
    };
    let out = match elastic_gen::analysis::lint_tree(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("lint: error: {e:#}");
            return 2;
        }
    };
    for f in out.unsuppressed() {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
    }
    let unsuppressed = out.unsuppressed_count();
    println!(
        "lint: {} files, {} unsuppressed finding(s), {} suppressed, {} allow pragma(s)",
        out.files_scanned,
        unsuppressed,
        out.suppressed_count(),
        out.allow_count
    );
    if args.has_flag("graph") {
        let g = &out.graph;
        println!(
            "graph: {} symbols, {} edges ({} via unique methods), {} unresolved call(s)",
            g.symbols, g.edges, g.method_edges, g.unresolved_calls
        );
        println!(
            "graph: {} fn(s) panic directly, {} may reach a panic, {} serving entries, {} on the panic frontier",
            g.base_panic_fns,
            g.may_panic_fns,
            g.serving_entries,
            g.panic_frontier.len()
        );
        for e in &g.panic_frontier {
            println!("graph:   frontier {e}");
        }
        for (a, b, n) in &g.lock_order {
            println!("graph:   lock order {a} -> {b} ({n} site(s))");
        }
    }
    if args.has_flag("units") {
        let u = &out.units;
        println!(
            "units: {} file(s) checked, {} fn(s), {} expr node(s) ({} resolved to a unit)",
            u.files_checked, u.fns_checked, u.exprs, u.resolved
        );
        println!(
            "units: {} same-unit check(s), {} finding(s); declared types: {} field(s), {} fn(s)",
            u.checks, u.findings, u.fields_typed, u.fns_typed
        );
    }
    if let Some(path) = args.get("json") {
        let report = elastic_gen::analysis::report_json(&out);
        if let Err(e) =
            std::fs::write(path, report.dump()).with_context(|| format!("writing {path}"))
        {
            eprintln!("lint: error: {e:#}");
            return 2;
        }
        println!("lint: report written to {path}");
    }
    let max_allows = args.get_usize("max-suppressions", usize::MAX);
    if out.allow_count > max_allows {
        eprintln!(
            "lint: suppression inventory {} exceeds --max-suppressions {}",
            out.allow_count, max_allows
        );
        return 1;
    }
    if unsuppressed > 0 {
        eprintln!("lint: {unsuppressed} unsuppressed finding(s)");
        return 1;
    }
    0
}

fn scenario(name: &str) -> anyhow::Result<AppSpec> {
    AppSpec::scenarios()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown app '{name}' (see usage)"))
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let jobs = args.get_usize("jobs", default_threads());
    let budget = args.get_usize("budget", 0);
    if args.has_flag("all") {
        return cmd_generate_all(jobs, budget);
    }
    if args.has_flag("distributed") {
        // shard this sweep across worker processes instead
        return cmd_dse(args);
    }
    let spec = scenario(args.get_or("app", "soft-sensor"))?;
    let top = args.get_usize("top", 5);
    println!(
        "Generating accelerators for '{}' ({} / goal {:?})",
        spec.name,
        spec.workload.describe(),
        spec.goal
    );
    let space = design_space::enumerate(&spec.device_allowlist);
    let mut pool = EvalPool::new(jobs);
    if budget > 0 {
        pool = pool.with_budget(budget);
    }
    let ranked = rank_with(&spec, &space, &mut pool);
    println!(
        "design space: {} candidates, {} feasible, Pareto front {} ({} jobs{})\n",
        space.len(),
        ranked.len(),
        pool.front().len(),
        jobs,
        if pool.budget_exhausted() {
            ", budget exhausted"
        } else {
            ""
        }
    );
    let mut t = Table::new(&[
        "#", "configuration", "E/item (mJ)", "latency (us)", "GOPS/s/W", "util %",
    ]);
    for (i, e) in ranked.iter().take(top).enumerate() {
        t.row(&[
            (i + 1).to_string(),
            e.candidate.describe(),
            num(e.energy_per_item.mj(), 4),
            num(e.latency.us(), 1),
            num(e.gops_per_watt, 2),
            num(e.utilization * 100.0, 1),
        ]);
    }
    println!("{}", t.render());

    if let Some(best) = ranked.first() {
        let acc = build(spec.topology, &best.candidate.build_opts());
        let rep = eda::report(
            &acc,
            best.candidate.device,
            Hertz::from_mhz(best.candidate.clock_mhz),
        );
        println!("{}", rep.render());
    }

    // --calibrate: replay the front through the DES, fit the constants,
    // and re-rank under the corrected model.  The refinement sweep
    // reuses this command's pool, so it costs no new estimator
    // evaluations (and respects --budget).
    if args.has_flag("calibrate") {
        let finalists = pool.take_front().into_members();
        let opts = CalibrateOpts { threads: jobs, ..Default::default() };
        let mut cal = calibrate_finalists(&spec, finalists, &opts);
        cal.sweep_best = ranked.first().cloned();
        let refined = refine_with(&spec, &space, CalibratedEstimator::new(pool, cal.scales));
        let mut t = Table::new(&calibration_columns()).with_title("Estimator↔DES calibration");
        t.row(&calibration_row(&cal, refined.best.as_ref())?);
        println!("{}", t.render());
    }
    Ok(())
}

/// Render one phase's per-shard table (sweep or refinement).
fn shard_table(title: &str, shards: &[ShardRun]) -> String {
    let mut t = Table::new(&[
        "shard", "evals", "finalists", "θ busy", "θ cold", "tau post", "status",
    ])
    .with_title(title);
    for s in shards {
        let r = &s.result;
        let mut status: Vec<String> = Vec::new();
        if s.reassigned {
            status.push(match &s.failure {
                Some(cause) => format!("reassigned ({cause})"),
                None => "reassigned".into(),
            });
        }
        if s.reranked {
            status.push("reranked".into());
        }
        if r.fell_back {
            status.push("fit fell back".into());
        }
        if r.budget_exhausted {
            status.push("budget!".into());
        }
        if status.is_empty() {
            status.push("ok".into());
        }
        t.row(&[
            format!("{}/{}", r.shard, r.of),
            r.evaluations.to_string(),
            r.front.len().to_string(),
            num(r.scales.busy, 3),
            num(r.scales.cold, 3),
            num(r.post.tau, 3),
            status.join(", "),
        ]);
    }
    t.render()
}

/// Bitwise equality of two fitted scale sets — the parity checks compare
/// corrected constants exactly, not approximately.
fn ensure_scales_bit_equal(a: &ModelScales, b: &ModelScales) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.to_bits() == b.to_bits(),
        "fitted scales differ: {a:?} vs {b:?}"
    );
    Ok(())
}

/// `elastic-gen dse` / `generate --distributed N`: shard the scenario's
/// sweep across N worker processes (or in-process workers with
/// `--in-process`), merge the fronts under the calibration guard, and —
/// with `--verify-parity` — fail unless the merged front is bit-identical
/// to the single-process sweep (the CI smoke runs through this path).
/// With `--calibrate` the driver fits the corrected constants on the
/// merged front and re-shards the space for a distributed refinement
/// re-rank, bit-identical to the single-process `calibrate_and_refine`.
fn cmd_dse(args: &Args) -> anyhow::Result<()> {
    let spec = scenario(args.get_or("app", "soft-sensor"))?;
    let workers = args
        .get_usize("workers", args.get_usize("distributed", 2))
        .max(1);
    // --jobs is the host-wide worker target, like the other subcommands:
    // split it across the shard processes' local pools
    let threads = (args.get_usize("jobs", workers) / workers).max(1);
    let budget = args.get_usize("budget", 0);
    let budget_opt = if budget > 0 { Some(budget) } else { None };
    let requests = args.get_usize("requests", 200);
    let in_process = args.has_flag("in-process");
    let calibrated = args.has_flag("calibrate");
    let mode = if in_process {
        WorkerMode::InProcess
    } else {
        WorkerMode::Subprocess(std::env::current_exe()?)
    };
    println!(
        "Distributed DSE for '{}': {} {} worker(s), {} replayed requests per finalist{}{}",
        spec.name,
        workers,
        if in_process { "in-process" } else { "subprocess" },
        requests,
        if budget > 0 {
            format!(", budget {budget}")
        } else {
            String::new()
        },
        if calibrated {
            " + distributed calibrated refinement"
        } else {
            ""
        },
    );
    let t0 = std::time::Instant::now();
    let journal = obs_journal(args)?;
    let dopts = DistOpts {
        workers,
        mode,
        budget: budget_opt,
        requests,
        threads,
        journal: journal.clone(),
        ..DistOpts::default()
    };
    if calibrated {
        let copts = CalibrateOpts {
            threads: default_threads(),
            requests,
            budget: budget_opt,
            ..Default::default()
        };
        let out = calibrate_and_refine_dist(&spec, &copts, &dopts)?;
        let wall = t0.elapsed();
        // the wall below covers the whole pipeline, not the sweep alone
        print_dist_sweep(&spec, &out.sweep, None)?;
        print_dist_refinement(&out)?;
        println!(
            "distributed pipeline (sweep + fit + refinement) completed in {:.2}s",
            wall.as_secs_f64()
        );
        if args.has_flag("verify-parity") {
            verify_calibrated_parity(&spec, &copts, &out)?;
        }
        obs_journal_close(&journal, args)?;
        return Ok(());
    }
    let out = DistSweep::new(dopts).run(&spec)?;
    let wall = t0.elapsed();
    print_dist_sweep(&spec, &out, Some(wall))?;

    if args.has_flag("verify-parity") {
        let (reference, ref_best, ref_evals) =
            single_process_reference(&spec, budget_opt, default_threads());
        assert_front_parity(&reference, &out.front)?;
        anyhow::ensure!(
            out.evaluations == ref_evals,
            "evaluation counts differ: distributed {} vs single-process {}",
            out.evaluations,
            ref_evals
        );
        let a = ref_best.as_ref().map(|e| e.candidate.describe());
        let b = out.best.as_ref().map(|e| e.candidate.describe());
        anyhow::ensure!(
            a == b,
            "best configuration differs: single-process {a:?} vs distributed {b:?}"
        );
        println!(
            "parity verified: merged front bit-identical to the single-process sweep ({} members)",
            out.front.len()
        );
    }
    obs_journal_close(&journal, args)?;
    Ok(())
}

/// Print the sweep phase: per-shard table, merged front, consensus.
/// `wall` is printed only when it covers the sweep alone — the
/// calibrated pipeline reports its total separately.
fn print_dist_sweep(
    spec: &AppSpec,
    out: &elastic_gen::generator::DistOutcome,
    wall: Option<std::time::Duration>,
) -> anyhow::Result<()> {
    println!("{}", shard_table("Shards (sweep)", &out.shards));
    let best = out
        .best
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("{}: no feasible configuration", spec.name))?;
    println!(
        "merged front: {} members, best {} at {} mJ/item, {} evaluations{}",
        out.front.len(),
        best.candidate.describe(),
        num(best.energy_per_item.mj(), 4),
        out.evaluations,
        match wall {
            Some(w) => format!(" in {:.2}s", w.as_secs_f64()),
            None => String::new(),
        },
    );
    println!(
        "consensus scales: busy {:.3} idle {:.3} off {:.3} cold {:.3} ({} shard(s) reranked, {} reassigned)",
        out.consensus.busy,
        out.consensus.idle,
        out.consensus.off,
        out.consensus.cold,
        out.reranked,
        out.reassigned
    );
    Ok(())
}

/// Print the calibration fit + distributed refinement phase.
fn print_dist_refinement(out: &DistCalOutcome) -> anyhow::Result<()> {
    let mut t =
        Table::new(&calibration_columns()).with_title("Estimator↔DES calibration (distributed)");
    t.row(&calibration_row(&out.calibration, out.refined.best.as_ref())?);
    println!("{}", t.render());
    println!("{}", shard_table("Shards (refinement)", &out.refined.shards));
    println!(
        "refined front: {} members in the corrected coordinates, {} evaluations ({} shard(s) reranked, {} reassigned)",
        out.refined.front.len(),
        out.refined.evaluations,
        out.refined.reranked,
        out.refined.reassigned
    );
    Ok(())
}

/// `--verify-parity` for the calibrated pipeline: the distributed fit,
/// agreement, refined front and refined best must all be bit-identical
/// to the single-process `calibrate_and_refine`.
fn verify_calibrated_parity(
    spec: &AppSpec,
    copts: &CalibrateOpts,
    out: &DistCalOutcome,
) -> anyhow::Result<()> {
    let (ref_cal, ref_refined) = calibrate_and_refine(spec, copts);
    ensure_scales_bit_equal(&ref_cal.scales, &out.calibration.scales)?;
    anyhow::ensure!(
        ref_cal.before == out.calibration.before && ref_cal.after == out.calibration.after,
        "{}: rank agreement differs from the single-process calibration",
        spec.name
    );
    anyhow::ensure!(
        ref_cal.fell_back == out.calibration.fell_back,
        "{}: fallback decision differs from the single-process calibration",
        spec.name
    );
    assert_front_parity(&ref_refined.front, &out.refined.front)
        .with_context(|| format!("{}: refined front parity", spec.name))?;
    let a = ref_refined.best.as_ref().map(|e| e.candidate.describe());
    let b = out.refined.best.as_ref().map(|e| e.candidate.describe());
    anyhow::ensure!(
        a == b,
        "{}: refined best differs: single-process {a:?} vs distributed {b:?}",
        spec.name
    );
    println!(
        "parity verified: distributed calibration + refinement bit-identical to the \
         single-process loop ({} refined front members)",
        out.refined.front.len()
    );
    Ok(())
}

/// Shared column set of the calibration agreement tables.
fn calibration_columns() -> [&'static str; 10] {
    [
        "scenario", "finalists", "θ busy", "θ idle", "θ off", "θ cold", "tau pre", "tau post",
        "crossovers", "refined best (mJ)",
    ]
}

/// One scenario's row for the agreement table; errors when refinement
/// found nothing feasible, when the shipped scales regress agreement
/// (impossible by construction — a violated guard is a bug), or when
/// estimator↔DES rank agreement has collapsed outright (tau <= 0, i.e.
/// the closed form no longer correlates with simulated ground truth).
/// The CI smoke runs through here, so those conditions fail the
/// pipeline; a fit the guard discarded is surfaced in the finalists
/// column as "(fit fell back)".  `refined_best` is the refinement
/// sweep's winner — single-process or distributed, both phases share
/// this row.
fn calibration_row(
    cal: &Calibration,
    refined_best: Option<&Estimate>,
) -> anyhow::Result<Vec<String>> {
    let spec = &cal.spec;
    anyhow::ensure!(
        cal.after.tau + 1e-9 >= cal.before.tau,
        "{}: post-calibration rank agreement regressed ({:.3} < {:.3})",
        spec.name,
        cal.after.tau,
        cal.before.tau
    );
    anyhow::ensure!(
        cal.after.tau > 0.0,
        "{}: estimator and DES rank agreement collapsed (tau {:.3}; fitted-scales tau {:.3})",
        spec.name,
        cal.after.tau,
        cal.fitted.tau
    );
    let best = refined_best
        .ok_or_else(|| anyhow::anyhow!("{}: refinement found nothing feasible", spec.name))?;
    let moved = match &cal.sweep_best {
        Some(b) if b.candidate.describe() == best.candidate.describe() => "winner unchanged",
        Some(_) => "winner moved",
        None => "-",
    };
    Ok(vec![
        spec.name.clone(),
        format!(
            "{}{}",
            cal.replays.len(),
            if cal.fell_back { " (fit fell back)" } else { "" }
        ),
        num(cal.scales.busy, 3),
        num(cal.scales.idle, 3),
        num(cal.scales.off, 3),
        num(cal.scales.cold, 3),
        num(cal.before.tau, 3),
        num(cal.after.tau, 3),
        format!(
            "{} -> {} of {}",
            cal.before.crossovers, cal.after.crossovers, cal.before.pairs
        ),
        format!("{} ({moved})", num(best.energy_per_item.mj(), 4)),
    ])
}

/// `elastic-gen calibrate`: the full estimator↔simulator loop per
/// scenario — sweep, DES replay of the Pareto finalists, least-squares
/// fit, rank agreement, calibrated refinement sweep.  With `--workers N`
/// both the sweep and the refinement run process-sharded
/// (`calibrate_and_refine_dist`); `--verify-parity` then cross-checks
/// every scenario against the single-process loop.
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let jobs = args.get_usize("jobs", default_threads());
    let quick = args.has_flag("quick");
    let requests = args.get_usize("requests", if quick { 200 } else { 600 });
    let budget = args.get_usize("budget", 0);
    let workers = args.get_usize("workers", 0);
    let specs = match args.get("app") {
        Some(name) => vec![scenario(name)?],
        None => AppSpec::scenarios(),
    };
    let opts = CalibrateOpts {
        threads: jobs,
        requests,
        budget: if budget > 0 { Some(budget) } else { None },
        ..Default::default()
    };
    if workers > 0 {
        return cmd_calibrate_dist(args, &specs, &opts, workers, quick);
    }
    println!(
        "Calibrating the closed-form estimator against the DES: {} scenario(s), {jobs} jobs, {requests} replayed requests per finalist{}\n",
        specs.len(),
        if quick { " (quick)" } else { "" }
    );
    let mut t = Table::new(&calibration_columns()).with_title("Estimator↔DES calibration");
    for spec in &specs {
        let (cal, refined) = calibrate_and_refine(spec, &opts);
        t.row(&calibration_row(&cal, refined.best.as_ref())?);
        if cal.fell_back {
            println!(
                "note: {}: fitted scales regressed tau ({:.3} vs {:.3}) and were discarded",
                spec.name, cal.fitted.tau, cal.before.tau
            );
        }
    }
    println!("{}", t.render());
    println!("θ are multiplicative corrections fitted by least squares against the DES ledger:");
    println!("busy -> dyn_mw_per_mhz_per_klut + DSP/BRAM surcharges, cold -> cold-start energy,");
    println!("idle/off -> gap overheads.  A fit that does not improve Kendall tau is replaced");
    println!("by the identity constants, so tau post >= tau pre on every scenario.");
    Ok(())
}

/// `elastic-gen calibrate --workers N`: the distributed loop — sweep and
/// refinement both process-sharded, with the fit performed by the driver
/// on the merged front so every number matches the single-process loop
/// bit for bit (`--verify-parity` enforces exactly that; the CI smoke
/// runs through here).
fn cmd_calibrate_dist(
    args: &Args,
    specs: &[AppSpec],
    opts: &CalibrateOpts,
    workers: usize,
    quick: bool,
) -> anyhow::Result<()> {
    let in_process = args.has_flag("in-process");
    let verify = args.has_flag("verify-parity");
    let threads = (opts.threads / workers).max(1);
    let mode = if in_process {
        WorkerMode::InProcess
    } else {
        WorkerMode::Subprocess(std::env::current_exe()?)
    };
    println!(
        "Calibrating distributed: {} scenario(s), {workers} {} worker(s), {} replayed requests per finalist{}\n",
        specs.len(),
        if in_process { "in-process" } else { "subprocess" },
        opts.requests,
        if quick { " (quick)" } else { "" }
    );
    let dopts = DistOpts {
        workers,
        mode,
        threads,
        ..DistOpts::default()
    };
    let mut t = Table::new(&calibration_columns())
        .with_title(&format!("Estimator↔DES calibration ({workers} workers)"));
    for spec in specs {
        let out = calibrate_and_refine_dist(spec, opts, &dopts)?;
        t.row(&calibration_row(&out.calibration, out.refined.best.as_ref())?);
        if out.calibration.fell_back {
            println!(
                "note: {}: fitted scales regressed tau ({:.3} vs {:.3}) and were discarded",
                spec.name, out.calibration.fitted.tau, out.calibration.before.tau
            );
        }
        if out.sweep.reassigned + out.refined.reassigned > 0 {
            println!(
                "note: {}: {} sweep / {} refinement shard(s) reassigned in-process",
                spec.name, out.sweep.reassigned, out.refined.reassigned
            );
        }
        if verify {
            verify_calibrated_parity(spec, opts, &out)?;
        }
    }
    println!("{}", t.render());
    Ok(())
}

/// Multi-scenario sweep: every `AppSpec::scenarios()` entry evaluated in
/// parallel (one thread + one worker pool each), rendered as a
/// cross-scenario comparison of the full sweep and the heuristic
/// portfolio.
fn cmd_generate_all(jobs: usize, budget: usize) -> anyhow::Result<()> {
    let scenarios = AppSpec::scenarios();
    let per = (jobs / scenarios.len()).max(1);
    println!(
        "Sweeping {} scenarios in parallel ({} jobs total, {} per scenario) ...\n",
        scenarios.len(),
        jobs,
        per
    );

    type Row = (
        AppSpec,
        elastic_gen::generator::SearchResult, // full sweep
        usize,                                // sweep Pareto size
        elastic_gen::generator::Portfolio,    // heuristic portfolio
        std::time::Duration,
    );
    let rows: Vec<Row> = std::thread::scope(|s| {
        let handles: Vec<_> = scenarios
            .iter()
            .map(|spec| {
                s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    let space = design_space::enumerate(&spec.device_allowlist);
                    let mut pool = EvalPool::new(per);
                    if budget > 0 {
                        pool = pool.with_budget(budget);
                    }
                    let sweep = Exhaustive.search_with(spec, &space, &mut pool);
                    // the portfolio budget is a total: the successive-
                    // halving scheduler splits it across the heuristics
                    // and keeps reallocating toward whichever is still
                    // improving, so the two evals columns compare under
                    // the same total spend
                    let folio = generate_portfolio(
                        spec,
                        per,
                        if budget > 0 { Some(budget) } else { None },
                    );
                    (spec.clone(), sweep, pool.front().len(), folio, t0.elapsed())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scenario thread panicked"))
            .collect()
    });

    let mut t = Table::new(&[
        "scenario", "workload", "best configuration", "E/item (mJ)", "GOPS/s/W", "Pareto",
        "sweep evals", "portfolio evals", "heuristic gap", "time (ms)",
    ])
    .with_title("Cross-scenario sweep");
    for (spec, sweep, front_len, folio, wall) in &rows {
        let best = sweep
            .best
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{}: no feasible configuration", spec.name))?;
        let gap = folio
            .best
            .as_ref()
            .map(|h| {
                format!(
                    "{:.2}x",
                    h.energy_per_item.value() / best.energy_per_item.value()
                )
            })
            .unwrap_or_else(|| "-".into());
        t.row(&[
            spec.name.clone(),
            spec.workload.describe(),
            best.candidate.describe(),
            num(best.energy_per_item.mj(), 4),
            num(best.gops_per_watt, 2),
            front_len.to_string(),
            format!(
                "{}{}",
                sweep.evaluations,
                if sweep.budget_exhausted { "!" } else { "" }
            ),
            folio.evaluations.to_string(),
            gap,
            num(wall.as_secs_f64() * 1e3, 0),
        ]);
    }
    println!("{}", t.render());
    if rows.iter().any(|(_, s, _, f, _)| {
        s.budget_exhausted || f.runs.iter().any(|(_, r)| r.budget_exhausted)
    }) {
        println!("(! = evaluation budget exhausted before the full space was swept)");
    }
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let topo = Topology::parse(args.get_or("model", "lstm_har"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let dev = device(args.get_or("device", "xc7s15"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let clock = Hertz::from_mhz(args.get_f64("clock-mhz", 100.0));
    let fmt = QFormat::parse(args.get_or("fmt", "q16_8"))
        .ok_or_else(|| anyhow::anyhow!("bad --fmt"))?;
    let opts = if args.has_flag("optimised") {
        BuildOpts::optimised(fmt)
    } else {
        BuildOpts::baseline(fmt)
    };
    let acc = build(topo, &opts);
    println!("{}", eda::report(&acc, dev, clock).render());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let dev = device(args.get_or("device", "xc7s15"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let period = Secs::from_ms(args.get_f64("period-ms", 40.0));
    let n = args.get_usize("requests", 1000);
    let acc = build(Topology::LstmHar, &BuildOpts::optimised(elastic_gen::rtl::Q16_8));
    let cost = cost_model(
        &acc,
        dev,
        Hertz::from_mhz(100.0),
        &Platform::default(),
        &ConfigController::raw(dev),
    );
    let arrivals = Workload::Periodic { period }.arrivals(n, &mut Rng::new(42));
    let sim = NodeSim::new(cost);

    // one strategy instance per kind, via the shared factory the
    // calibration replays and E7 use — keeps `simulate` from drifting
    // when a deployment default changes
    let mut strategies: Vec<Box<dyn Strategy>> =
        StrategyKind::all().iter().map(|k| k.instantiate()).collect();
    let mut t = Table::new(&[
        "strategy", "served", "E total (mJ)", "E/item (mJ)", "p50 lat (ms)", "config (mJ)",
        "idle (mJ)",
    ])
    .with_title(&format!(
        "Workload simulation: {} requests, period {:.1} ms, {} @100MHz",
        n,
        period.ms(),
        dev.name
    ));
    for s in strategies.iter_mut() {
        let r = sim.run(&arrivals, s.as_mut());
        let lat = elastic_gen::util::stats::Summary::of(&r.latencies);
        t.row(&[
            r.strategy.to_string(),
            r.served.to_string(),
            num(r.energy.total().mj(), 2),
            num(r.energy_per_item().mj(), 4),
            num(lat.p50 * 1e3, 3),
            num(r.energy.config.mj(), 2),
            num(r.energy.idle.mj(), 2),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.has_flag("adapt") {
        return cmd_serve_adapt(args);
    }
    let n = args.get_usize("requests", 200);
    let journal = obs_journal(args)?;
    let base = CoordinatorConfig {
        shards: args.get_usize("shards", 0),
        queue_cap: args.get_usize("queue-cap", 256),
        batch_max: args.get_usize("batch-max", 16),
        journal: journal.clone(),
        ..CoordinatorConfig::default()
    };
    // --synthetic serves the manifest-free CPU-burner artifacts, so the
    // sharded serving path can be demonstrated without `make artifacts`
    let (config, artifact, input_len) = if args.has_flag("synthetic") {
        let spec = elastic_gen::runtime::SyntheticSpec::uniform(4, 16, 4, 50_000);
        let artifact = args.get_or("artifact", "syn.0").to_string();
        let meta = spec
            .artifacts
            .iter()
            .find(|a| a.name == artifact)
            .ok_or_else(|| anyhow::anyhow!("unknown synthetic artifact '{artifact}'"))?;
        let input_len = meta.input_len;
        (
            CoordinatorConfig {
                engine: EngineSpec::Synthetic(spec),
                ..base
            },
            artifact,
            input_len,
        )
    } else {
        let manifest = Manifest::load(&elastic_gen::artifacts_dir())?;
        let artifact = args.get_or("artifact", "lstm_har.opt").to_string();
        let meta = manifest
            .get(&artifact)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{artifact}'"))?;
        (base, artifact, meta.input_len())
    };
    let coord = Coordinator::start(config)?;
    let mut rng = Rng::new(7);
    println!(
        "serving {n} requests against '{artifact}' on {} shard(s) ...",
        coord.shard_count()
    );
    for _ in 0..n {
        let input = synth_input(input_len, &mut rng);
        let resp = coord.infer(&artifact, input)?;
        if let Err(e) = &resp.output {
            anyhow::bail!("inference failed: {e}");
        }
    }
    println!("{}", coord.metrics().snapshot().render());
    obs_journal_close(&journal, args)?;
    Ok(())
}

/// `--obs-log <path>`: attach a streaming JSONL event journal (bounded
/// in-memory ring; every event also hits the file before eviction).
fn obs_journal(args: &Args) -> anyhow::Result<Option<Arc<Journal>>> {
    match args.get("obs-log") {
        Some(path) => {
            let j = Journal::with_writer(
                elastic_gen::obs::DEFAULT_RING_CAP,
                std::path::Path::new(path),
            )?;
            Ok(Some(Arc::new(j)))
        }
        None => Ok(None),
    }
}

/// Flush the `--obs-log` journal and report what it captured.
fn obs_journal_close(journal: &Option<Arc<Journal>>, path_args: &Args) -> anyhow::Result<()> {
    if let Some(j) = journal {
        j.flush()?;
        println!(
            "obs journal: {} event(s) recorded to {} ({} in ring, {} evicted)",
            j.recorded(),
            path_args.get_or("obs-log", "?"),
            j.len(),
            j.evicted()
        );
    }
    Ok(())
}

/// One synthetic input vector, quantised the way the engines expect.
fn synth_input(len: usize, rng: &mut Rng) -> Vec<f32> {
    (0..len)
        .map(|_| (rng.range(-2.0, 2.0) * 256.0).floor() as f32 / 256.0)
        .collect()
}

/// The best feasible candidate for `spec` pinned to one power strategy —
/// the "deployed" baseline the adaptive loop measures drift against.
/// Pinning to a strategy (rather than the global winner) leaves a
/// drastically drifted workload room to justify a switch.
fn deployed_estimate(
    spec: &AppSpec,
    strategy: StrategyKind,
    jobs: usize,
) -> anyhow::Result<Estimate> {
    let space = design_space::enumerate(&spec.device_allowlist);
    let mut pool = EvalPool::new(jobs);
    let mut best: Option<Estimate> = None;
    for c in space.iter().filter(|c| c.strategy == strategy) {
        if let Some(e) = pool.evaluate(spec, c) {
            if e.feasible
                && best
                    .as_ref()
                    .map(|b| e.score(spec.goal) > b.score(spec.goal))
                    .unwrap_or(true)
            {
                best = Some(e);
            }
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!(
            "no feasible {} candidate for '{}'",
            strategy.name(),
            spec.name
        )
    })
}

/// `elastic-gen serve --adapt`: the closed adaptive serving loop on the
/// synthetic backend.  Phase 1 serves an observed stream (arrivals land
/// in the per-artifact ring); `--inject-drift` then replaces the ring
/// with a seeded trace from a 50x slower Poisson workload so the
/// fit -> sweep -> switch decision is reproducible run to run.  Phase 2
/// spawns the supervisor in the background and keeps serving a second
/// stream concurrently — only the drain windows of an actual switch may
/// bounce submissions (they are retried and counted).  The CI smoke runs
/// through here with `--quick --inject-drift --expect-switch`.
fn cmd_serve_adapt(args: &Args) -> anyhow::Result<()> {
    let quick = args.has_flag("quick");
    let jobs = args.get_usize("jobs", default_threads());
    let n = args.get_usize("requests", if quick { 120 } else { 400 });
    let workers = args.get_usize("workers", 0);

    // always the manifest-free synthetic backend: hermetic, and the
    // engine swap is observable without `make artifacts`
    let spec_syn = elastic_gen::runtime::SyntheticSpec::uniform(4, 16, 4, 50_000);
    let artifact = args.get_or("artifact", "syn.0").to_string();
    let load_artifact = "syn.1".to_string();
    anyhow::ensure!(
        artifact != load_artifact,
        "'{load_artifact}' is reserved for the concurrent load stream"
    );
    let input_len = spec_syn
        .artifacts
        .iter()
        .find(|a| a.name == artifact)
        .ok_or_else(|| anyhow::anyhow!("unknown synthetic artifact '{artifact}'"))?
        .input_len;
    let journal = obs_journal(args)?;
    let config = CoordinatorConfig {
        shards: args.get_usize("shards", 2),
        queue_cap: args.get_usize("queue-cap", 256),
        batch_max: args.get_usize("batch-max", 16),
        engine: EngineSpec::Synthetic(spec_syn),
        journal: journal.clone(),
        ..CoordinatorConfig::default()
    };
    let coord = Arc::new(Coordinator::start(config)?);

    let mut spec = scenario(args.get_or("app", "soft-sensor"))?;
    if quick {
        // narrow the sweep so the background re-exploration fits the
        // smoke timeout
        spec.device_allowlist = vec!["xc7s6"];
    }
    let strategy = StrategyKind::parse(args.get_or("deploy-strategy", "idle-wait"))
        .ok_or_else(|| {
            let names: Vec<&str> = StrategyKind::all().iter().map(|k| k.name()).collect();
            anyhow::anyhow!("unknown --deploy-strategy (one of: {})", names.join(", "))
        })?;
    let deployed = deployed_estimate(&spec, strategy, jobs)?;
    println!(
        "deployed: {} [{}] at {} mJ/item under {}",
        deployed.candidate.describe(),
        strategy.name(),
        num(deployed.energy_per_item.mj(), 4),
        spec.workload.describe()
    );

    let mut cfg = AdaptConfig::new(spec, deployed);
    cfg.journal = journal.clone();
    cfg.drift_threshold = args.get_f64("drift-threshold", 0.5);
    cfg.margin = Joules(args.get_f64("margin-mj", 0.0) * 1e-3);
    cfg.amortize_horizon = Secs(args.get_f64("amortize-s", 60.0));
    cfg.calibrate = CalibrateOpts {
        threads: jobs,
        requests: args.get_usize("cal-requests", if quick { 120 } else { 400 }),
        ..Default::default()
    };
    if workers > 0 {
        let mode = if args.has_flag("in-process") {
            WorkerMode::InProcess
        } else {
            WorkerMode::Subprocess(std::env::current_exe()?)
        };
        cfg.dist = Some(DistOpts {
            workers,
            mode,
            threads: (jobs / workers).max(1),
            journal: journal.clone(),
            ..DistOpts::default()
        });
    }

    // phase 1: the observed stream — every accepted submission lands in
    // the per-artifact arrival ring
    let mut rng = Rng::new(7);
    println!(
        "serving {n} observed requests against '{artifact}' on {} shard(s) ...",
        coord.shard_count()
    );
    for _ in 0..n {
        let input = synth_input(input_len, &mut rng);
        let resp = coord.infer(&artifact, input)?;
        if let Err(e) = &resp.output {
            anyhow::bail!("inference failed: {e}");
        }
    }

    let inject = args.has_flag("inject-drift");
    if inject {
        let drifted = Workload::Poisson {
            mean_gap: Secs(2.5),
        };
        let trace = drifted.arrivals(512, &mut Rng::new(11));
        coord.metrics().reset_arrivals(&artifact);
        for t in &trace {
            coord.metrics().record_arrival_at(&artifact, t.value());
        }
        println!(
            "injected drifted trace: {} arrivals under {} (ring reset)",
            trace.len(),
            drifted.describe()
        );
    }

    // phase 2: the supervisor watches the observed artifact in the
    // background while the foreground serves a second stream
    let stop = Arc::new(AtomicBool::new(false));
    let interval = Duration::from_millis(args.get_usize("interval-ms", 100) as u64);
    // kept for the post-switch probe: `spawn` consumes the supervisor
    let probe_cfg = cfg.clone();
    let handle = Supervisor::new(cfg).spawn(
        Arc::clone(&coord),
        artifact.clone(),
        interval,
        Arc::clone(&stop),
    )?;

    let mut drain_rejects = 0usize;
    for _ in 0..n {
        let input = synth_input(input_len, &mut rng);
        loop {
            match coord.submit(&load_artifact, input.clone()) {
                Ok(rx) => {
                    let resp = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("engine shard died before replying"))?;
                    if let Err(e) = &resp.output {
                        anyhow::bail!("inference failed: {e}");
                    }
                    break;
                }
                Err(SubmitError::Draining { .. }) => {
                    drain_rejects += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    // wait (bounded) for the cycle that switches; without an injected
    // drift the supervisor may legitimately keep observing
    let deadline =
        std::time::Instant::now() + Duration::from_secs(args.get_usize("wait-s", 120) as u64);
    while inject
        && coord.metrics().switch_events().is_empty()
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(50));
    }
    stop.store(true, Ordering::SeqCst);
    let outcomes = handle.join().expect("adapt supervisor panicked");

    for (i, o) in outcomes.iter().enumerate() {
        let drift = match o.drift {
            Some(d) => num(d, 3),
            None => "-".into(),
        };
        match &o.decision {
            Some(d) => println!(
                "cycle {}: {} — fit {}, drift {}, {} -> {} mJ/item (amortized {}, net gain {}) => {}{}",
                i + 1,
                o.state.name(),
                o.fit.family.name(),
                drift,
                num(d.before.mj(), 4),
                num(d.after.mj(), 4),
                num(d.amortized.mj(), 4),
                num(d.net_gain.mj(), 4),
                if d.switch { "switch" } else { "keep" },
                if o.dist_fell_back {
                    " (dist fell back)"
                } else {
                    ""
                },
            ),
            None => println!(
                "cycle {}: {} — fit {}, drift {}, {} arrival(s)",
                i + 1,
                o.state.name(),
                o.fit.family.name(),
                drift,
                o.fit.stats.arrivals,
            ),
        }
    }
    if drain_rejects > 0 {
        println!("foreground stream absorbed {drain_rejects} drain reject(s) while switching");
    }

    // post-switch probe: one forced re-evaluation from the *switched*
    // deployment's point of view.  The winner just became the baseline,
    // so the same drifted trace nets about -amortized, below any
    // non-negative margin — a recorded *rejection*, so a single smoke
    // run leaves both verdicts in the decision log and the journal.
    if inject {
        let rebased = outcomes
            .iter()
            .rev()
            .find(|o| o.state == AdaptState::Switched)
            .and_then(|o| match (&o.decision, &o.fit.fitted) {
                (Some(d), Some(w)) => Some((d.to.clone(), w.clone())),
                _ => None,
            });
        if let Some((to, fitted)) = rebased {
            let mut pc = probe_cfg;
            pc.deployed = to;
            pc.spec.workload = fitted;
            // the switch rebaselined and cleared the ring; re-inject the
            // same deterministic trace the supervisor decided on
            let drifted = Workload::Poisson {
                mean_gap: Secs(2.5),
            };
            let trace = drifted.arrivals(512, &mut Rng::new(11));
            coord.metrics().reset_arrivals(&artifact);
            for t in &trace {
                coord.metrics().record_arrival_at(&artifact, t.value());
            }
            let probe = Supervisor::new(pc).probe(&coord, &artifact);
            match &probe.decision {
                Some(d) => println!(
                    "post-switch probe: {} -> {} mJ/item (net gain {}) => {}",
                    num(d.before.mj(), 4),
                    num(d.after.mj(), 4),
                    num(d.net_gain.mj(), 4),
                    if d.switch { "switch" } else { "keep" },
                ),
                None => println!("post-switch probe: no feasible alternative"),
            }
        }
    }

    println!("{}", coord.metrics().snapshot().render());
    obs_journal_close(&journal, args)?;

    if args.has_flag("expect-switch") {
        let events = coord.metrics().switch_events();
        anyhow::ensure!(
            events.len() == 1,
            "expected exactly one switch event, saw {}",
            events.len()
        );
        println!("adaptive cycle complete: observe -> fit -> sweep -> switch verified");
    }
    Ok(())
}

/// `elastic-gen obs <journal.jsonl>`: render a recorded event journal —
/// span-chain completeness, per-artifact latency/exec histograms, the
/// adapt-cycle decision trail, swap phases, and worker lifecycle events.
fn cmd_obs(args: &Args) -> anyhow::Result<()> {
    let path = args.positional.get(1).ok_or_else(|| {
        anyhow::anyhow!("usage: elastic-gen obs <journal.jsonl>  (see serve --obs-log)")
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading journal '{path}': {e}"))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = elastic_gen::util::json::parse(line)
            .map_err(|e| anyhow::anyhow!("{path}:{}: bad JSON: {e}", i + 1))?;
        let ev = elastic_gen::obs::wire::decode(&j)
            .map_err(|e| anyhow::anyhow!("{path}:{}: {e}", i + 1))?;
        events.push(ev);
    }
    println!("{}", elastic_gen::obs::render(&events));
    Ok(())
}

fn cmd_devices() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "device", "family", "LUTs", "FFs", "BRAM18", "DSPs", "static mW", "bitstream kB",
        "config ms",
    ])
    .with_title("FPGA device catalog");
    for d in DEVICES {
        t.row(&[
            d.name.to_string(),
            format!("{:?}", d.family),
            d.resources.luts.to_string(),
            d.resources.ffs.to_string(),
            d.resources.bram18.to_string(),
            d.resources.dsps.to_string(),
            num(d.static_power.mw(), 2),
            num(d.bitstream_bytes as f64 / 1024.0, 0),
            num(d.config_time_s() * 1e3, 1),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = elastic_gen::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    let only = args.get("artifact");
    let engine = elastic_gen::runtime::Engine::load(
        &dir,
        &manifest
            .artifacts
            .iter()
            .filter(|a| only.map(|o| a.name == o).unwrap_or(true))
            .map(|a| a.name.as_str())
            .collect::<Vec<_>>(),
    )?;
    println!("platform: {}", engine.platform());
    let mut checked = 0;
    for meta in &manifest.artifacts {
        if let Some(o) = only {
            if meta.name != o {
                continue;
            }
        }
        let golden = Golden::load(&dir, &meta.name)?;
        for (i, case) in golden.cases.iter().enumerate() {
            let input: Vec<f32> = case.input.iter().map(|&x| x as f32).collect();
            let got = engine.infer(&meta.name, &input)?;
            let tol = 1.5 * meta.fmt.resolution() as f64;
            for (g, w) in got.iter().zip(&case.output) {
                if (*g as f64 - w).abs() > tol {
                    anyhow::bail!(
                        "{} case {i}: PJRT {} vs golden {} (tol {tol})",
                        meta.name,
                        g,
                        w
                    );
                }
            }
        }
        checked += 1;
        println!("  OK {}", meta.name);
    }
    println!("verified {checked} artifacts against golden vectors");
    Ok(())
}
