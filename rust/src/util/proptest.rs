//! Property-based testing harness (proptest is not in the vendored crate
//! set, so the crate carries its own minimal, deterministic equivalent).
//!
//! A property is checked over `cases` randomly generated inputs; on failure
//! the harness greedily shrinks the input with the strategy's `shrink`
//! candidates until no smaller failing input is found, then panics with the
//! minimal counterexample and the seed that reproduces it.
//!
//! ```ignore
//! // (doctest binaries cannot link libstdc++ in the offline sandbox;
//! // the same example runs as a unit test below)
//! use elastic_gen::util::proptest::{check, vec_f64};
//! check("sum is commutative", 100, vec_f64(0, 16, -1e3..1e3), |v| {
//!     let s1: f64 = v.iter().sum();
//!     let s2: f64 = v.iter().rev().sum();
//!     (s1 - s2).abs() < 1e-6
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// A generation + shrinking strategy for values of type `T`.
pub trait Strategy {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate smaller values; empty when fully shrunk.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value>;
}

/// Default seed; override with env `PROPTEST_SEED` for replay.
fn seed() -> u64 {
    std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xE1A5_71C6_0001)
}

/// Check `prop` over `cases` generated inputs; panics with the minimal
/// failing case otherwise.
pub fn check<S: Strategy>(
    name: &str,
    cases: usize,
    strategy: S,
    prop: impl Fn(&S::Value) -> bool,
) {
    let mut rng = Rng::new(seed() ^ hash_name(name));
    for case in 0..cases {
        let value = strategy.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&strategy, value, &prop);
            panic!(
                "property '{name}' failed at case {case}\n  minimal counterexample: {minimal:?}\n  \
                 replay with PROPTEST_SEED={}",
                seed()
            );
        }
    }
}

fn shrink_loop<S: Strategy>(
    strategy: &S,
    mut failing: S::Value,
    prop: &impl Fn(&S::Value) -> bool,
) -> S::Value {
    // bounded effort so pathological strategies terminate
    for _ in 0..10_000 {
        let mut advanced = false;
        for cand in strategy.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// built-in strategies
// ---------------------------------------------------------------------------

/// Uniform f64 in a range; shrinks toward 0 / the low bound.
pub struct F64Range(pub Range<f64>);

impl Strategy for F64Range {
    type Value = f64;

    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range(self.0.start, self.0.end)
    }

    fn shrink(&self, v: &f64) -> Vec<f64> {
        let target = if self.0.contains(&0.0) { 0.0 } else { self.0.start };
        if (v - target).abs() < 1e-12 {
            return vec![];
        }
        vec![target, target + (v - target) / 2.0]
    }
}

/// Uniform i64 in an inclusive range; shrinks toward 0 / low bound.
pub struct I64Range(pub i64, pub i64);

impl Strategy for I64Range {
    type Value = i64;

    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.int_range(self.0, self.1)
    }

    fn shrink(&self, v: &i64) -> Vec<i64> {
        let target = if self.0 <= 0 && self.1 >= 0 { 0 } else { self.0 };
        if *v == target {
            return vec![];
        }
        let mut out = vec![target];
        let mid = target + (v - target) / 2;
        if mid != *v {
            out.push(mid);
        }
        // unit step toward the target so halving can't overshoot the
        // true boundary
        out.push(v - (v - target).signum());
        out
    }
}

/// Vec of f64 with length in [min_len, max_len]; shrinks by halving the
/// vector and shrinking elements toward zero.
pub struct VecF64 {
    pub min_len: usize,
    pub max_len: usize,
    pub range: Range<f64>,
}

pub fn vec_f64(min_len: usize, max_len: usize, range: Range<f64>) -> VecF64 {
    VecF64 {
        min_len,
        max_len,
        range,
    }
}

impl Strategy for VecF64 {
    type Value = Vec<f64>;

    fn generate(&self, rng: &mut Rng) -> Vec<f64> {
        let len = rng.int_range(self.min_len as i64, self.max_len as i64) as usize;
        (0..len)
            .map(|_| rng.range(self.range.start, self.range.end))
            .collect()
    }

    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
        }
        // shrink the largest-magnitude element toward zero
        if let Some((i, _)) = v
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        {
            if v[i].abs() > 1e-12 {
                let mut w = v.clone();
                w[i] /= 2.0;
                out.push(w);
            }
        }
        out
    }
}

/// Pair of independent strategies.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// One of a fixed set of choices (no shrinking).
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);

impl<T: Clone + std::fmt::Debug> Strategy for OneOf<T> {
    type Value = T;

    fn generate(&self, rng: &mut Rng) -> T {
        rng.choice(&self.0).clone()
    }

    fn shrink(&self, _v: &T) -> Vec<T> {
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("abs is non-negative", 200, F64Range(-100.0..100.0), |x| {
            x.abs() >= 0.0
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let r = std::panic::catch_unwind(|| {
            check("all below 50", 500, I64Range(0, 1000), |x| *x < 50);
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        // minimal counterexample of "x < 50" over [0,1000] is exactly 50
        assert!(msg.contains("minimal counterexample: 50"), "{msg}");
    }

    #[test]
    fn vec_shrink_respects_min_len() {
        let s = vec_f64(2, 8, -1.0..1.0);
        let shrunk = s.shrink(&vec![0.5, -0.5]);
        assert!(shrunk.iter().all(|v| v.len() >= 2 || !v.is_empty()));
    }

    #[test]
    fn pair_generates_both() {
        let mut rng = Rng::new(1);
        let s = Pair(I64Range(1, 5), F64Range(0.0..1.0));
        let (a, b) = s.generate(&mut rng);
        assert!((1..=5).contains(&a));
        assert!((0.0..1.0).contains(&b));
    }
}
