//! Shared substrates: deterministic RNG, statistics, JSON, tables, units,
//! CLI parsing and a property-testing harness.  These stand in for crates
//! (serde_json / clap / proptest / criterion) that are not available in the
//! offline vendored build (see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
pub mod units;
