//! ASCII table rendering for bench harnesses and EDA-style reports.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table: header row + data rows, auto-sized columns.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            align: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Table {
        self.title = Some(title.to_string());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.align[col] = a;
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch: {} vs {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }

        let sep: String = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };

        let fmt_row = |cells: &[String], align: &[Align]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                match align[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(c);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(c);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };

        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.align));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format an f64 with `digits` significant decimals, trimming noise.
pub fn num(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["alpha", "1.0"]);
        t.row_strs(&["b", "123.45"]);
        let s = t.render();
        assert!(s.contains("| alpha |"));
        assert!(s.contains("| 123.45 |"));
        let lines: Vec<&str> = s.lines().collect();
        // all lines equal width
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new(&["a", "b"]).row_strs(&["only-one"]);
    }

    #[test]
    fn title_rendered_first() {
        let mut t = Table::new(&["x"]).with_title("T1");
        t.row_strs(&["1"]);
        assert!(t.render().starts_with("T1\n"));
    }
}
