//! Hand-rolled command-line parsing (clap is not in the vendored crate set).
//!
//! Supports the subcommand + `--key value` / `--flag` grammar used by the
//! `elastic-gen` binary and the examples:
//!
//! ```text
//! elastic-gen generate --app soft-sensor --device xc7s15 --goal energy
//! ```

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order plus `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token is NOT the program
    /// name — strip it before calling).
    pub fn parse(tokens: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    a.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(name.to_string());
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        a
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        let v: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&v)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| {
                s.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{key} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| {
                s.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    /// True when `--name` was given at all.  A flag followed by a
    /// positional token (`serve --synthetic 200`) parses as an option
    /// with that value; it must still count as the flag being set rather
    /// than being silently dropped.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(&toks("generate --device xc7s15 --budget 2.5 --verbose"));
        assert_eq!(a.subcommand(), Some("generate"));
        assert_eq!(a.get("device"), Some("xc7s15"));
        assert_eq!(a.get_f64("budget", 0.0), 2.5);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn key_equals_value() {
        let a = Args::parse(&toks("run --n=10"));
        assert_eq!(a.get_usize("n", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&toks(""));
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&toks("cmd --flag"));
        assert!(a.has_flag("flag"));
    }

    #[test]
    fn flag_followed_by_positional_still_counts() {
        let a = Args::parse(&toks("serve --synthetic 200"));
        assert!(a.has_flag("synthetic"));
        let b = Args::parse(&toks("generate --all --jobs 4"));
        assert!(b.has_flag("all"));
        assert_eq!(b.get_usize("jobs", 1), 4);
    }
}
