//! Deterministic pseudo-random number generation.
//!
//! The crate never touches OS entropy: every stochastic component (workload
//! generators, search algorithms, measurement noise) takes an explicit
//! seed so experiments are exactly reproducible.  The generator is
//! xoshiro256++ seeded via SplitMix64 (the reference seeding procedure).

/// FNV-1a over a string: a stable, platform-independent 64-bit hash
/// (std's `DefaultHasher` is randomly seeded per process, which would make
/// shard affinity non-reproducible across runs).
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for sub-components) without
    /// correlating with `self`'s future output.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = &mut self.s;
        let result = s0.wrapping_add(*s3).rotate_left(23).wrapping_add(*s0);
        let t = *s1 << 17;
        *s2 ^= *s0;
        *s3 ^= *s1;
        *s1 ^= *s2;
        *s0 ^= *s3;
        *s2 ^= t;
        *s3 = s3.rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased for practical n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection-free multiply-shift is fine here: bias < 2^-64 * n
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small, normal
    /// approximation above 30 — callers here stay far below that boundary's
    /// accuracy needs).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Random element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(15);
        let n = 20_000;
        let m = (0..n).map(|_| r.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 4.0).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fnv1a_stable_and_distinct() {
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("mlp_fluid.hard"), fnv1a("mlp_fluid.hard"));
        assert_ne!(fnv1a("mlp_fluid.hard"), fnv1a("lstm_har.opt"));
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(21);
        let mut f = a.fork();
        assert_ne!(a.next_u64(), f.next_u64());
    }
}
