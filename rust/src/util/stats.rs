//! Descriptive statistics used by the benchmark harness, the measurement
//! emulation and the evaluation reports.

/// Streaming mean/variance via Welford's algorithm plus min/max.
#[derive(Debug, Clone)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

// Hand-written so `default()` seeds min/max with the ±inf sentinels; the
// derived impl zeroed them, silently pinning min() at 0 for any
// all-positive series pushed through a default-constructed instance.
impl Default for OnlineStats {
    fn default() -> Self {
        OnlineStats::new()
    }
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample, or 0.0 for an empty series (never leaks the
    /// +inf seeding sentinel into reports).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample, or 0.0 for an empty series.
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Full-sample summary with percentiles (sorts a copy).  Non-finite
/// samples (NaN/±inf timing artifacts) are dropped before summarizing —
/// counted in `dropped` — so every reported statistic is finite.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Finite samples summarized.
    pub n: usize,
    /// Non-finite samples dropped from the input.
    pub dropped: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut v: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        let dropped = samples.len() - v.len();
        if v.is_empty() {
            // every sample was non-finite: an all-zero summary beats
            // poisoning mean/std/max with NaN downstream
            return Summary {
                n: 0,
                dropped,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        v.sort_by(|a, b| a.total_cmp(b));
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            dropped,
            mean,
            std: var.sqrt(),
            min: v.first().copied().unwrap_or(0.0),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: v.last().copied().unwrap_or(0.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice; `p` in [0, 100].
/// Returns 0.0 for an empty slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len().saturating_sub(1)) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let w = rank - lo as f64;
    match (sorted.get(lo), sorted.get(hi)) {
        (Some(&a), Some(&b)) if lo != hi => a * (1.0 - w) + b * w,
        (Some(&a), _) => a,
        _ => 0.0,
    }
}

/// Arithmetic mean, 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.std() - 2.0).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p99 - 99.01).abs() < 0.1);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn percentile_edges() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 3.0);
        assert_eq!(percentile_sorted(&v, 50.0), 2.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn summary_drops_non_finite_samples() {
        // reachable from metrics rendering on a zero-duration timing
        // sample — NaN/±inf must not poison max/p99/mean/std; they are
        // dropped (and counted) before summarizing
        let s = Summary::of(&[2.0, f64::NAN, 1.0, f64::INFINITY]);
        assert_eq!(s.n, 2);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.mean, 1.5);
        assert!(s.p99.is_finite() && s.std.is_finite());
        let neg = Summary::of(&[f64::NEG_INFINITY, 0.5, f64::NAN]);
        assert_eq!(neg.n, 1);
        assert_eq!(neg.dropped, 2);
        assert_eq!(neg.min, 0.5);
        assert_eq!(neg.max, 0.5);
    }

    #[test]
    fn summary_of_all_non_finite_is_zeroed() {
        let s = Summary::of(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]);
        assert_eq!(s.n, 0);
        assert_eq!(s.dropped, 3);
        for x in [s.mean, s.std, s.min, s.p50, s.p90, s.p99, s.max] {
            assert_eq!(x, 0.0);
        }
    }

    #[test]
    fn online_stats_empty_series_bounds() {
        let o = OnlineStats::new();
        assert_eq!(o.min(), 0.0);
        assert_eq!(o.max(), 0.0);
        assert_eq!(o.mean(), 0.0);
    }

    #[test]
    fn online_stats_default_tracks_extremes() {
        // the derived Default seeded min/max at 0.0, pinning min() there
        // for all-positive series — the handwritten impl must not
        let mut o = OnlineStats::default();
        o.push(5.0);
        o.push(3.0);
        assert_eq!(o.min(), 3.0);
        assert_eq!(o.max(), 5.0);
    }
}
