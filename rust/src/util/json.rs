//! Minimal JSON reader/writer.
//!
//! serde_json is not in the vendored crate set, and the interchange needs
//! are narrow (artifact manifest, golden vectors, exported weights, bench
//! result dumps), so this module implements the subset of JSON the repo
//! actually uses: objects, arrays, strings with standard escapes, f64
//! numbers, booleans and null.  Numbers are always parsed as f64 — the
//! python exporter writes plain floats/ints only.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn path(&self, keys: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in keys {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    /// Flatten a numeric array (possibly nested) into f64s.
    pub fn to_f64_vec(&self) -> Vec<f64> {
        let mut out = Vec::new();
        fn walk(j: &Json, out: &mut Vec<f64>) {
            match j {
                Json::Num(x) => out.push(*x),
                Json::Arr(v) => v.iter().for_each(|e| walk(e, out)),
                _ => {}
            }
        }
        walk(self, &mut out);
        out
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -- serialisation -------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                // JSON has no NaN/Infinity literal; `format!("{x}")` would
                // emit one and make the document unparseable (empty-series
                // stats reach here via bench dumps).  Emit null instead.
                // -0.0 must skip the integer fast-path: `0` would parse
                // back as +0.0 and break bit-exact round-trips.
                if !x.is_finite() {
                    out.push_str("null");
                } else if x.fract() == 0.0
                    && x.abs() < 1e15
                    && !(*x == 0.0 && x.is_sign_negative())
                {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

// -- parsing -----------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError {
            msg: msg.to_string(),
            pos: self.i,
        })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => self.err("bad number"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError {
                                    msg: "bad \\u escape".into(),
                                    pos: self.i,
                                })?;
                            self.i += 4;
                            if (0xD800..0xDC00).contains(&code) {
                                // high surrogate: JSON encodes astral-plane
                                // chars as UTF-16 pairs — combine with an
                                // immediately following \uDC00..\uDFFF
                                // escape into the real code point
                                let lo = (self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                    && self.i + 6 < self.b.len())
                                    .then(|| {
                                        std::str::from_utf8(&self.b[self.i + 3..self.i + 7]).ok()
                                    })
                                    .flatten()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .filter(|c| (0xDC00..0xE000).contains(c));
                                match lo {
                                    Some(lo) => {
                                        let c =
                                            0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                        self.i += 6;
                                    }
                                    // lone high surrogate: replacement char
                                    None => s.push('\u{FFFD}'),
                                }
                            } else {
                                // lone low surrogates also land on FFFD here
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy the full utf-8 sequence
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError {
                            msg: "invalid utf-8".into(),
                            pos: self.i,
                        })?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Ok(parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null], "s": "x\ny"}"#;
        let j = parse(src).unwrap();
        assert_eq!(j.path(&["a"]).as_f64(), Some(1.0));
        assert_eq!(j.path(&["b"]).as_arr().unwrap().len(), 4);
        assert_eq!(j.path(&["s"]).as_str(), Some("x\ny"));
        // dump -> parse -> same value
        let j2 = parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn nested_path_access() {
        let j = parse(r#"{"a": {"b": {"c": 42}}}"#).unwrap();
        assert_eq!(j.path(&["a", "b", "c"]).as_f64(), Some(42.0));
        assert_eq!(j.path(&["a", "missing"]), &Json::Null);
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e-3", 1e-3), ("-2.5E2", -250.0)] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn to_f64_vec_flattens() {
        let j = parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(j.to_f64_vec(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn dump_integers_clean() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // format!("{x}") would emit "NaN"/"inf", which parse() rejects —
        // the writer must degrade to null so dumps stay valid JSON
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump(), "null");
        let doc = Json::obj(vec![("min", Json::Num(f64::INFINITY)), ("n", Json::Num(0.0))]);
        let back = parse(&doc.dump()).expect("non-finite dump must stay parseable");
        assert_eq!(back.path(&["min"]), &Json::Null);
        assert_eq!(back.path(&["n"]).as_f64(), Some(0.0));
    }

    #[test]
    fn finite_f64_roundtrips_bit_exactly() {
        for x in [1.0 / 3.0, 1e-300, -0.0, 123456.789, f64::MIN_POSITIVE] {
            let back = parse(&Json::Num(x).dump()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 escapes to the UTF-16 pair \ud83d\ude00 in JSON; the
        // old parser decoded it as two U+FFFD replacement chars
        let pair = r#""\ud83d\ude00""#;
        assert_eq!(parse(pair).unwrap().as_str(), Some("\u{1F600}"));
        let mixed = r#""x\ud83d\ude00y""#;
        assert_eq!(parse(mixed).unwrap().as_str(), Some("x\u{1F600}y"));
        // raw astral chars round-trip through dump -> parse
        let j = Json::Str("a\u{1F600}b".into());
        assert_eq!(parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn lone_surrogates_fall_back_to_replacement() {
        // high surrogate with no continuation
        assert_eq!(parse(r#""\ud83dx""#).unwrap().as_str(), Some("\u{FFFD}x"));
        // high surrogate followed by an ordinary character stays lone
        assert_eq!(
            parse(r#""\ud83dA""#).unwrap().as_str(),
            Some("\u{FFFD}A")
        );
        // lone low surrogate
        assert_eq!(parse(r#""\ude00""#).unwrap().as_str(), Some("\u{FFFD}"));
        // high surrogate at end of input must not read out of bounds
        assert_eq!(parse(r#""\ud83d""#).unwrap().as_str(), Some("\u{FFFD}"));
    }
}
