//! Poison-tolerant mutex locking for the serving path.
//!
//! `Mutex::lock().unwrap()` turns one panicking worker thread into a
//! cascade: every later lock of the same mutex panics too, taking down
//! metrics reads and shard drains that were otherwise healthy.  The
//! serving stack guards plain data (counters, rings, senders) whose
//! invariants hold between statements, so recovering the guard from a
//! poisoned lock is always safe here — the data is at worst one update
//! stale, never structurally torn.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn locked_recovers_from_a_poisoned_lock() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        // poison the lock by panicking while holding it
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert!(m.is_poisoned());
        // locked() still hands out the guard, data intact
        assert_eq!(*locked(&m), 7);
        *locked(&m) += 1;
        assert_eq!(*locked(&m), 8);
    }
}
