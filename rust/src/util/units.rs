//! Physical-unit newtypes for the energy model.
//!
//! The whole evaluation pipeline turns on correct joule accounting, so time,
//! power and energy get distinct types with only the physically meaningful
//! arithmetic: `Power * Time = Energy`, `Energy / Time = Power`, etc.
//! All values are f64 SI (seconds, watts, joules, hertz).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

macro_rules! unit {
    ($name:ident, $sym:expr) => {
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        pub struct $name(pub f64);

        impl $name {
            pub const ZERO: $name = $name(0.0);

            pub fn value(self) -> f64 {
                self.0
            }

            pub fn max(self, other: $name) -> $name {
                $name(self.0.max(other.0))
            }

            pub fn min(self, other: $name) -> $name {
                $name(self.0.min(other.0))
            }

            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }
        }

        impl Add for $name {
            type Output = $name;
            fn add(self, o: $name) -> $name {
                $name(self.0 + o.0)
            }
        }

        impl Sub for $name {
            type Output = $name;
            fn sub(self, o: $name) -> $name {
                $name(self.0 - o.0)
            }
        }

        impl AddAssign for $name {
            fn add_assign(&mut self, o: $name) {
                self.0 += o.0;
            }
        }

        impl SubAssign for $name {
            fn sub_assign(&mut self, o: $name) {
                self.0 -= o.0;
            }
        }

        impl Neg for $name {
            type Output = $name;
            fn neg(self) -> $name {
                $name(-self.0)
            }
        }

        impl Mul<f64> for $name {
            type Output = $name;
            fn mul(self, k: f64) -> $name {
                $name(self.0 * k)
            }
        }

        impl Div<f64> for $name {
            type Output = $name;
            fn div(self, k: f64) -> $name {
                $name(self.0 / k)
            }
        }

        /// Dimensionless ratio of two like quantities.
        impl Div<$name> for $name {
            type Output = f64;
            fn div(self, o: $name) -> f64 {
                self.0 / o.0
            }
        }

        impl Sum for $name {
            fn sum<I: Iterator<Item = $name>>(iter: I) -> $name {
                $name(iter.map(|x| x.0).sum())
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{}{}", format_si(self.0), $sym)
            }
        }
    };
}

unit!(Secs, "s");
unit!(Watts, "W");
unit!(Joules, "J");
unit!(Hertz, "Hz");

impl Mul<Secs> for Watts {
    type Output = Joules;
    fn mul(self, t: Secs) -> Joules {
        Joules(self.0 * t.0)
    }
}

impl Mul<Watts> for Secs {
    type Output = Joules;
    fn mul(self, p: Watts) -> Joules {
        Joules(self.0 * p.0)
    }
}

impl Div<Secs> for Joules {
    type Output = Watts;
    fn div(self, t: Secs) -> Watts {
        Watts(self.0 / t.0)
    }
}

impl Div<Watts> for Joules {
    type Output = Secs;
    fn div(self, p: Watts) -> Secs {
        Secs(self.0 / p.0)
    }
}

impl Secs {
    pub fn from_ms(ms: f64) -> Secs {
        Secs(ms * 1e-3)
    }

    pub fn from_us(us: f64) -> Secs {
        Secs(us * 1e-6)
    }

    pub fn ms(self) -> f64 {
        self.0 * 1e3
    }

    pub fn us(self) -> f64 {
        self.0 * 1e6
    }

    /// Cycles at `f` needed to cover this duration (ceiling).
    pub fn cycles_at(self, f: Hertz) -> u64 {
        (self.0 * f.0).ceil() as u64
    }
}

impl Hertz {
    pub fn from_mhz(mhz: f64) -> Hertz {
        Hertz(mhz * 1e6)
    }

    pub fn mhz(self) -> f64 {
        self.0 * 1e-6
    }

    /// Duration of `cycles` clock cycles at this frequency.
    pub fn cycles(self, cycles: u64) -> Secs {
        Secs(cycles as f64 / self.0)
    }
}

impl Joules {
    pub fn from_mj(mj: f64) -> Joules {
        Joules(mj * 1e-3)
    }

    pub fn from_uj(uj: f64) -> Joules {
        Joules(uj * 1e-6)
    }

    pub fn mj(self) -> f64 {
        self.0 * 1e3
    }

    pub fn uj(self) -> f64 {
        self.0 * 1e6
    }
}

impl Watts {
    pub fn from_mw(mw: f64) -> Watts {
        Watts(mw * 1e-3)
    }

    pub fn mw(self) -> f64 {
        self.0 * 1e3
    }
}

/// Format with an SI prefix at 4 significant digits (e.g. `12.34m`).
pub fn format_si(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    let (scale, prefix) = if a >= 1e9 {
        (1e-9, "G")
    } else if a >= 1e6 {
        (1e-6, "M")
    } else if a >= 1e3 {
        (1e-3, "k")
    } else if a >= 1.0 {
        (1.0, "")
    } else if a >= 1e-3 {
        (1e3, "m")
    } else if a >= 1e-6 {
        (1e6, "u")
    } else if a >= 1e-9 {
        (1e9, "n")
    } else {
        (1e12, "p")
    };
    format!("{:.4}{}", x * scale, prefix)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensional_arithmetic() {
        let e = Watts(2.0) * Secs(3.0);
        assert_eq!(e, Joules(6.0));
        assert_eq!(e / Secs(3.0), Watts(2.0));
        assert_eq!(e / Watts(2.0), Secs(3.0));
        assert!((Joules(6.0) / Joules(3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn conversions() {
        assert_eq!(Secs::from_ms(40.0).value(), 0.04);
        assert!((Secs::from_us(28.07).us() - 28.07).abs() < 1e-9);
        assert_eq!(Hertz::from_mhz(100.0).value(), 100e6);
        assert_eq!(Hertz::from_mhz(100.0).cycles(100), Secs(1e-6));
        assert_eq!(Secs(1e-6).cycles_at(Hertz::from_mhz(100.0)), 100);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(format_si(0.0), "0");
        assert!(format_si(0.0123).starts_with("12.3"));
        assert!(format_si(1.5e6).ends_with('M'));
        assert!(format_si(-2e-6).contains('u'));
    }

    #[test]
    fn sum_and_ordering() {
        let total: Joules = [Joules(1.0), Joules(2.5)].into_iter().sum();
        assert_eq!(total, Joules(3.5));
        assert!(Secs(1.0) < Secs(2.0));
        assert_eq!(Secs(1.0).max(Secs(2.0)), Secs(2.0));
    }
}
