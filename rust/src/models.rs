//! Model topology catalog — the single Rust-side source of truth for the
//! network shapes compiled by `python/compile/model.py`.  The constants
//! must match the python definitions exactly (the cross-layer tests
//! compare behavioural simulation against the compiled artifacts).

/// One FC layer: (n_in, n_out).
pub type FcShape = (u32, u32);

/// One conv layer: (c_in, c_out, kernel_width, stride).
pub type ConvShape = (u32, u32, u32, u32);

/// MLP soft sensor for fluid-flow estimation [4,11].
pub const MLP_LAYERS: &[FcShape] = &[(8, 16), (16, 8), (8, 1)];

/// LSTM HAR/EEG-style classifier [2,20].
pub const LSTM_T: u32 = 24;
pub const LSTM_IN: u32 = 6;
pub const LSTM_H: u32 = 20;
pub const LSTM_CLASSES: u32 = 6;

/// 1-D CNN for on-device ECG analysis [3].
pub const CNN_T: u32 = 128;
pub const CNN_SPEC: &[ConvShape] = &[(1, 8, 7, 2), (8, 16, 5, 2)];
pub const CNN_CLASSES: u32 = 5;

/// Tiny transformer attention block (§3.1).
pub const ATTN_T: u32 = 16;
pub const ATTN_D: u32 = 16;
pub const ATTN_CLASSES: u32 = 4;

/// The four model topologies in the artifact set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    MlpFluid,
    LstmHar,
    CnnEcg,
    AttnTiny,
}

impl Topology {
    pub fn parse(name: &str) -> Option<Topology> {
        match name {
            "mlp_fluid" => Some(Topology::MlpFluid),
            "lstm_har" => Some(Topology::LstmHar),
            "cnn_ecg" => Some(Topology::CnnEcg),
            "attn_tiny" => Some(Topology::AttnTiny),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::MlpFluid => "mlp_fluid",
            Topology::LstmHar => "lstm_har",
            Topology::CnnEcg => "cnn_ecg",
            Topology::AttnTiny => "attn_tiny",
        }
    }

    /// Flat input element count.
    pub fn input_len(&self) -> usize {
        match self {
            Topology::MlpFluid => MLP_LAYERS[0].0 as usize,
            Topology::LstmHar => (LSTM_T * LSTM_IN) as usize,
            Topology::CnnEcg => CNN_T as usize,
            Topology::AttnTiny => (ATTN_T * ATTN_D) as usize,
        }
    }

    /// Flat output element count.
    pub fn output_len(&self) -> usize {
        match self {
            Topology::MlpFluid => MLP_LAYERS.last().unwrap().1 as usize,
            Topology::LstmHar => LSTM_CLASSES as usize,
            Topology::CnnEcg => CNN_CLASSES as usize,
            Topology::AttnTiny => ATTN_CLASSES as usize,
        }
    }

    pub fn all() -> &'static [Topology] {
        &[
            Topology::MlpFluid,
            Topology::LstmHar,
            Topology::CnnEcg,
            Topology::AttnTiny,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for t in Topology::all() {
            assert_eq!(Topology::parse(t.name()), Some(*t));
        }
        assert_eq!(Topology::parse("bogus"), None);
    }

    #[test]
    fn shapes_match_python() {
        assert_eq!(Topology::MlpFluid.input_len(), 8);
        assert_eq!(Topology::MlpFluid.output_len(), 1);
        assert_eq!(Topology::LstmHar.input_len(), 144);
        assert_eq!(Topology::LstmHar.output_len(), 6);
        assert_eq!(Topology::CnnEcg.input_len(), 128);
        assert_eq!(Topology::CnnEcg.output_len(), 5);
        assert_eq!(Topology::AttnTiny.input_len(), 256);
        assert_eq!(Topology::AttnTiny.output_len(), 4);
    }
}
