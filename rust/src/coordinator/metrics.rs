//! Serving metrics: per-artifact latency/throughput accounting plus
//! per-shard counters (queue depth, batch fill, admission rejects),
//! shared between the shard worker threads and observers.
//!
//! Latency samples land in fixed-memory [`Hist`]ograms, so the sink's
//! footprint is O(artifacts + shards) no matter how many requests it
//! records — the unbounded per-request `Vec<f64>`s this module used to
//! keep were a leak under sustained load (`approx_mem_bytes` pins this
//! in tests).  Supervisor switch *decisions* are recorded here too,
//! rejections included: anti-flapping behaviour is only assertable if
//! the decisions that did **not** fire leave a trace.

use crate::obs::Hist;
use crate::util::stats::Summary;
use crate::util::sync::locked;
use crate::util::table::{num, Table};
use crate::util::units::Secs;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default bound on the per-artifact arrival-trace ring.
pub const DEFAULT_ARRIVAL_CAP: usize = 4096;

#[derive(Debug, Default)]
struct ArtifactStats {
    served: u64,
    failed: u64,
    /// Fixed-memory latency histograms; exact mean/min/max, bucketed
    /// quantiles (see `obs::hist`).
    queue_wait_s: Hist,
    exec_s: Hist,
    e2e_s: Hist,
    /// Bounded ring of arrival timestamps (seconds since the metrics
    /// epoch) — the raw material the workload fitter consumes.
    arrivals: VecDeque<f64>,
}

#[derive(Debug, Default)]
struct ShardStats {
    submitted: u64,
    rejected: u64,
    /// Subset of `rejected` bounced because the shard was draining for an
    /// engine swap (bounded by the drain window).
    drain_rejected: u64,
    served: u64,
    failed: u64,
    batches: u64,
    batch_fill_sum: f64,
    exec_s: Hist,
    e2e_s: Hist,
}

/// One completed drain-and-switch reconfiguration.
#[derive(Debug, Clone)]
pub struct SwitchEvent {
    /// Seconds since the metrics epoch.
    pub at_s: f64,
    /// Candidate descriptions (Candidate::describe / Workload::describe).
    pub from: String,
    pub to: String,
    /// Modeled energy/item before and after, when known.
    pub before_mj: Option<f64>,
    pub after_mj: Option<f64>,
    /// Drift score that triggered the re-exploration.
    pub drift: Option<f64>,
    /// Requests rejected during the drain window of this switch.
    pub drain_rejected: u64,
}

impl SwitchEvent {
    fn render_line(&self) -> String {
        let mj = |v: Option<f64>| v.map(|x| format!("{x:.3} mJ/item")).unwrap_or_else(|| "-".into());
        format!(
            "switch @{:.1}s: {} -> {} (before {}, after {}, drift {}, drain rejects {})",
            self.at_s,
            self.from,
            self.to,
            mj(self.before_mj),
            mj(self.after_mj),
            self.drift.map(|d| format!("{d:.2}")).unwrap_or_else(|| "-".into()),
            self.drain_rejected,
        )
    }
}

/// One supervisor switch decision, committed or rejected.  The fields
/// spell out the predicate arithmetic (`net_gain = before - after -
/// amortized`, switch iff `net_gain > margin` strictly) so a rejection
/// carries the losing margin with it.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    /// Seconds since the metrics epoch; 0.0 is stamped on record.
    pub at_s: f64,
    /// Candidate the decision evaluated switching to.
    pub to: String,
    pub before_mj: f64,
    pub after_mj: f64,
    pub reconfig_mj: f64,
    pub amortized_mj: f64,
    pub net_gain_mj: f64,
    pub margin_mj: f64,
    /// Drift score that triggered the sweep, when known.
    pub drift: Option<f64>,
    /// True when the decision committed a swap.
    pub switched: bool,
}

#[derive(Debug, Default)]
struct DecisionLog {
    total: u64,
    rejected: u64,
    last: Option<DecisionRecord>,
}

/// Thread-safe metrics sink.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, ArtifactStats>>,
    shards: Mutex<Vec<ShardStats>>,
    /// Live queue-depth gauges, one per shard (shared with the submit
    /// path; isize because producer increments and worker decrements race
    /// benignly).
    depth_gauges: Mutex<Vec<Arc<AtomicIsize>>>,
    start: Mutex<Option<Instant>>,
    arrival_cap: Mutex<usize>,
    switches: Mutex<Vec<SwitchEvent>>,
    decisions: Mutex<DecisionLog>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            inner: Mutex::default(),
            shards: Mutex::default(),
            depth_gauges: Mutex::default(),
            start: Mutex::default(),
            arrival_cap: Mutex::new(DEFAULT_ARRIVAL_CAP),
            switches: Mutex::default(),
            decisions: Mutex::default(),
        }
    }
}

impl Metrics {
    /// Register the shard layout.  Called once by `Coordinator::start`.
    pub fn init_shards(&self, gauges: Vec<Arc<AtomicIsize>>) {
        {
            let mut shards = locked(&self.shards);
            *shards = Vec::new();
            shards.resize_with(gauges.len(), ShardStats::default);
        }
        *locked(&self.depth_gauges) = gauges;
        *locked(&self.start) = Some(Instant::now());
    }

    fn elapsed_s(&self) -> f64 {
        locked(&self.start)
            .get_or_insert_with(Instant::now)
            .elapsed()
            .as_secs_f64()
    }

    /// Record one served/failed request against its artifact.
    pub fn record(&self, artifact: &str, ok: bool, queue_wait_s: f64, exec_s: f64) {
        // pin the epoch on first use so throughput reflects serving time
        self.elapsed_s();
        let mut m = locked(&self.inner);
        let s = m.entry(artifact.to_string()).or_default();
        if ok {
            s.served += 1;
            s.queue_wait_s.record(queue_wait_s);
            s.exec_s.record(exec_s);
            s.e2e_s.record(queue_wait_s + exec_s);
        } else {
            s.failed += 1;
        }
    }

    /// Record one executed request against both its artifact and shard.
    pub fn record_shard(
        &self,
        shard: usize,
        artifact: &str,
        ok: bool,
        queue_wait_s: f64,
        exec_s: f64,
    ) {
        self.record(artifact, ok, queue_wait_s, exec_s);
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            if ok {
                s.served += 1;
                s.exec_s.record(exec_s);
                s.e2e_s.record(queue_wait_s + exec_s);
            } else {
                s.failed += 1;
            }
        }
    }

    /// An admitted request was enqueued on `shard`.
    pub fn record_submit(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.submitted += 1;
        }
    }

    /// Admission control rejected a request bound for `shard`.
    pub fn record_reject(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.rejected += 1;
        }
    }

    /// A request bounced off `shard` because it was draining for a swap.
    /// Counted both in the total reject tally and separately, so tests can
    /// bound rejects attributable to the drain window.
    pub fn record_drain_reject(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.rejected += 1;
            s.drain_rejected += 1;
        }
    }

    /// Change the arrival-ring bound (existing rings are trimmed lazily on
    /// the next arrival).
    pub fn set_arrival_cap(&self, cap: usize) {
        *locked(&self.arrival_cap) = cap.max(1);
    }

    /// Record an arrival for `artifact` at "now" (seconds since the
    /// metrics epoch).  Called on the submit path.
    pub fn record_arrival(&self, artifact: &str) {
        let t = self.elapsed_s();
        self.record_arrival_at(artifact, t);
    }

    /// Record an arrival at an explicit timestamp.  Test/replay entry
    /// point: the adaptive loop's hermetic tests inject synthetic traces
    /// here instead of depending on the wall clock.
    pub fn record_arrival_at(&self, artifact: &str, t_s: f64) {
        let cap = *locked(&self.arrival_cap);
        let mut m = locked(&self.inner);
        let ring = &mut m.entry(artifact.to_string()).or_default().arrivals;
        while ring.len() >= cap {
            ring.pop_front();
        }
        ring.push_back(t_s);
    }

    /// The recorded arrival trace for `artifact`, oldest first.
    pub fn arrival_trace(&self, artifact: &str) -> Vec<Secs> {
        let m = locked(&self.inner);
        m.get(artifact)
            .map(|s| s.arrivals.iter().map(|&t| Secs(t)).collect())
            .unwrap_or_default()
    }

    /// Drop the recorded arrivals for `artifact` (after a switch the old
    /// trace describes the previous regime and would bias the next fit).
    pub fn reset_arrivals(&self, artifact: &str) {
        let mut m = locked(&self.inner);
        if let Some(s) = m.get_mut(artifact) {
            s.arrivals.clear();
        }
    }

    /// Record a completed drain-and-switch reconfiguration.
    pub fn record_switch(&self, mut event: SwitchEvent) {
        if event.at_s == 0.0 {
            event.at_s = self.elapsed_s();
        }
        locked(&self.switches).push(event);
    }

    /// Completed switch events, oldest first.
    pub fn switch_events(&self) -> Vec<SwitchEvent> {
        locked(&self.switches).clone()
    }

    /// Record one supervisor switch decision — **including rejections**.
    /// Only the last record is kept (plus total/rejected counters), so
    /// the log stays O(1) however long the supervisor runs.
    pub fn record_decision(&self, mut d: DecisionRecord) {
        if d.at_s == 0.0 {
            d.at_s = self.elapsed_s();
        }
        let mut log = locked(&self.decisions);
        log.total += 1;
        if !d.switched {
            log.rejected += 1;
        }
        log.last = Some(d);
    }

    /// Rough heap bytes held by the sink.  Latency histograms are inline
    /// fixed arrays and the arrival rings are capped, so this is a
    /// function of artifact/shard/switch counts — **not** request count;
    /// the long-run test pins that by recording twice and comparing.
    pub fn approx_mem_bytes(&self) -> usize {
        use std::mem::size_of;
        let artifacts = {
            let m = locked(&self.inner);
            m.iter()
                .map(|(k, s)| {
                    k.len()
                        + size_of::<ArtifactStats>()
                        + s.arrivals.capacity() * size_of::<f64>()
                })
                .sum::<usize>()
        };
        let shards = locked(&self.shards).capacity() * size_of::<ShardStats>();
        let switches = {
            let sw = locked(&self.switches);
            sw.capacity() * size_of::<SwitchEvent>()
                + sw.iter().map(|e| e.from.len() + e.to.len()).sum::<usize>()
        };
        artifacts + shards + switches
    }

    /// One micro-batch of `fill` requests drained (window `cap`).
    pub fn record_batch(&self, shard: usize, fill: usize, cap: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.batches += 1;
            s.batch_fill_sum += fill as f64 / cap.max(1) as f64;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let elapsed = self.elapsed_s();
        let m = locked(&self.inner);
        let rows = m
            .iter()
            .map(|(name, s)| ArtifactSnapshot {
                artifact: name.clone(),
                served: s.served,
                failed: s.failed,
                throughput_rps: s.served as f64 / elapsed.max(1e-9),
                queue_wait: s.queue_wait_s.summary(),
                exec: s.exec_s.summary(),
                e2e: s.e2e_s.summary(),
                arrivals: s.arrivals.len(),
            })
            .collect();
        let gauges = locked(&self.depth_gauges);
        let shards = locked(&self.shards)
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSnapshot {
                shard: i,
                submitted: s.submitted,
                rejected: s.rejected,
                drain_rejected: s.drain_rejected,
                served: s.served,
                failed: s.failed,
                queue_depth: gauges
                    .get(i)
                    .map(|g| g.load(Ordering::Relaxed).max(0) as usize)
                    .unwrap_or(0),
                batches: s.batches,
                batch_fill: if s.batches == 0 {
                    0.0
                } else {
                    s.batch_fill_sum / s.batches as f64
                },
                exec: s.exec_s.summary(),
                e2e: s.e2e_s.summary(),
            })
            .collect();
        let (decisions, decisions_rejected, last_decision) = {
            let log = locked(&self.decisions);
            (log.total, log.rejected, log.last.clone())
        };
        MetricsSnapshot {
            elapsed_s: elapsed,
            rows,
            shards,
            switches: locked(&self.switches).clone(),
            decisions,
            decisions_rejected,
            last_decision,
        }
    }
}

#[derive(Debug)]
pub struct ArtifactSnapshot {
    pub artifact: String,
    pub served: u64,
    pub failed: u64,
    pub throughput_rps: f64,
    pub queue_wait: Option<Summary>,
    pub exec: Option<Summary>,
    pub e2e: Option<Summary>,
    /// Arrival timestamps currently held in the bounded trace ring.
    pub arrivals: usize,
}

/// Point-in-time view of one engine shard.
#[derive(Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub submitted: u64,
    pub rejected: u64,
    /// Subset of `rejected` bounced during swap drain windows.
    pub drain_rejected: u64,
    pub served: u64,
    pub failed: u64,
    /// Requests currently waiting in the shard's bounded queue.
    pub queue_depth: usize,
    pub batches: u64,
    /// Mean micro-batch fill ratio in [0, 1] (drained / batch_max).
    pub batch_fill: f64,
    pub exec: Option<Summary>,
    pub e2e: Option<Summary>,
}

#[derive(Debug)]
pub struct MetricsSnapshot {
    pub elapsed_s: f64,
    pub rows: Vec<ArtifactSnapshot>,
    pub shards: Vec<ShardSnapshot>,
    /// Completed drain-and-switch reconfigurations, oldest first.
    pub switches: Vec<SwitchEvent>,
    /// Supervisor switch decisions recorded, committed or not.
    pub decisions: u64,
    /// Subset of `decisions` whose net gain did not clear the margin (or
    /// whose swap aborted) — the anti-flapping evidence.
    pub decisions_rejected: u64,
    /// The most recent decision with its full margin arithmetic.
    pub last_decision: Option<DecisionRecord>,
}

impl MetricsSnapshot {
    pub fn total_served(&self) -> u64 {
        self.rows.iter().map(|r| r.served).sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.rejected).sum()
    }

    pub fn total_drain_rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.drain_rejected).sum()
    }

    pub fn render(&self) -> String {
        let p = |s: &Option<Summary>, f: fn(&Summary) -> f64| {
            s.as_ref().map(|s| num(f(s) * 1e3, 3)).unwrap_or_else(|| "-".into())
        };
        let mut t = Table::new(&[
            "artifact", "served", "fail", "rps", "p50 ms", "p99 ms", "exec p50 ms",
        ])
        .with_title(&format!("Serving metrics ({:.1}s)", self.elapsed_s));
        for r in &self.rows {
            t.row(&[
                r.artifact.clone(),
                r.served.to_string(),
                r.failed.to_string(),
                num(r.throughput_rps, 1),
                p(&r.e2e, |s| s.p50),
                p(&r.e2e, |s| s.p99),
                p(&r.exec, |s| s.p50),
            ]);
        }
        let mut out = t.render();
        if !self.shards.is_empty() {
            let mut st = Table::new(&[
                "shard", "submitted", "rejected", "served", "fail", "depth", "batch fill",
                "p50 ms", "p99 ms",
            ])
            .with_title("Per-shard counters");
            for s in &self.shards {
                st.row(&[
                    s.shard.to_string(),
                    s.submitted.to_string(),
                    s.rejected.to_string(),
                    s.served.to_string(),
                    s.failed.to_string(),
                    s.queue_depth.to_string(),
                    num(s.batch_fill, 2),
                    p(&s.e2e, |x| x.p50),
                    p(&s.e2e, |x| x.p99),
                ]);
            }
            out.push('\n');
            out.push_str(&st.render());
        }
        for sw in &self.switches {
            out.push('\n');
            out.push_str(&sw.render_line());
        }
        if self.decisions > 0 {
            out.push('\n');
            out.push_str(&format!(
                "decisions: {} total, {} rejected",
                self.decisions, self.decisions_rejected
            ));
            if let Some(d) = &self.last_decision {
                out.push_str(&format!(
                    "; last @{:.1}s -> {} (net {:+.3} mJ vs margin {:.3} mJ: {})",
                    d.at_s,
                    d.to,
                    d.net_gain_mj,
                    d.margin_mj,
                    if d.switched { "committed" } else { "rejected" },
                ));
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    #[test]
    fn metrics_survive_a_poisoned_lock() {
        // a worker thread that panics while holding a metrics lock must
        // not cascade into panics on every later record/snapshot call
        let m = Arc::new(Metrics::default());
        m.record("a", true, 0.001, 0.002);
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.inner.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert!(m.inner.is_poisoned());
        m.record("a", true, 0.001, 0.002);
        m.record_arrival_at("a", 0.5);
        let s = m.snapshot();
        assert_eq!(s.total_served(), 2);
        assert_eq!(s.rows[0].arrivals, 1);
    }

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.record("a", true, 0.001, 0.002);
        m.record("a", true, 0.002, 0.002);
        m.record("a", false, 0.0, 0.0);
        m.record("b", true, 0.0, 0.001);
        let s = m.snapshot();
        assert_eq!(s.total_served(), 3);
        let a = &s.rows[0];
        assert_eq!(a.artifact, "a");
        assert_eq!(a.served, 2);
        assert_eq!(a.failed, 1);
        assert!((a.e2e.as_ref().unwrap().mean - 0.0035).abs() < 1e-9);
        assert!(s.render().contains("Serving metrics"));
    }

    #[test]
    fn per_shard_accounting() {
        let m = Metrics::default();
        let gauges: Vec<Arc<AtomicIsize>> =
            (0..2).map(|_| Arc::new(AtomicIsize::new(0))).collect();
        gauges[1].store(3, Ordering::Relaxed);
        m.init_shards(gauges);

        m.record_submit(0);
        m.record_submit(1);
        m.record_submit(1);
        m.record_reject(1);
        m.record_batch(0, 4, 16);
        m.record_batch(0, 8, 16);
        m.record_shard(0, "a", true, 0.001, 0.002);
        m.record_shard(1, "a", false, 0.0, 0.0);

        let s = m.snapshot();
        assert_eq!(s.shards.len(), 2);
        assert_eq!(s.shards[0].submitted, 1);
        assert_eq!(s.shards[0].served, 1);
        assert!((s.shards[0].batch_fill - 0.375).abs() < 1e-9);
        assert_eq!(s.shards[1].submitted, 2);
        assert_eq!(s.shards[1].rejected, 1);
        assert_eq!(s.shards[1].failed, 1);
        assert_eq!(s.shards[1].queue_depth, 3);
        assert_eq!(s.total_rejected(), 1);
        // shard execution also feeds the per-artifact table
        assert_eq!(s.total_served(), 1);
        assert!(s.render().contains("Per-shard counters"));
    }

    #[test]
    fn arrival_ring_is_bounded_and_resettable() {
        let m = Metrics::default();
        m.set_arrival_cap(8);
        for i in 0..20 {
            m.record_arrival_at("a", i as f64 * 0.1);
        }
        let trace = m.arrival_trace("a");
        assert_eq!(trace.len(), 8, "ring must stay bounded");
        // oldest entries evicted: ring holds the last 8 timestamps
        assert!((trace[0].value() - 1.2).abs() < 1e-9);
        assert!((trace[7].value() - 1.9).abs() < 1e-9);
        assert!(trace.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(m.snapshot().rows[0].arrivals, 8);

        m.reset_arrivals("a");
        assert!(m.arrival_trace("a").is_empty());
        // unknown artifact -> empty, no panic
        assert!(m.arrival_trace("nope").is_empty());
    }

    #[test]
    fn switch_events_recorded_and_rendered() {
        let m = Metrics::default();
        let gauges: Vec<Arc<AtomicIsize>> =
            (0..1).map(|_| Arc::new(AtomicIsize::new(0))).collect();
        m.init_shards(gauges);
        m.record_drain_reject(0);
        m.record_drain_reject(0);
        m.record_switch(SwitchEvent {
            at_s: 12.5,
            from: "idle-wait".into(),
            to: "on-off".into(),
            before_mj: Some(1.25),
            after_mj: Some(0.4),
            drift: Some(0.9),
            drain_rejected: 2,
        });
        let s = m.snapshot();
        assert_eq!(s.switches.len(), 1);
        assert_eq!(s.shards[0].drain_rejected, 2);
        assert_eq!(s.shards[0].rejected, 2);
        assert_eq!(s.total_drain_rejected(), 2);
        let r = s.render();
        assert!(r.contains("switch @12.5s: idle-wait -> on-off"), "{r}");
        assert!(r.contains("drain rejects 2"), "{r}");
        assert_eq!(m.switch_events().len(), 1);
    }

    /// The ISSUE-9 leak regression: recording must not grow the sink.
    /// Two identical 50k-request phases must leave `approx_mem_bytes`
    /// exactly where the first left it — O(artifacts + shards), not
    /// O(requests).
    #[test]
    fn memory_is_bounded_by_artifacts_and_shards_not_requests() {
        let m = Metrics::default();
        let gauges: Vec<Arc<AtomicIsize>> =
            (0..2).map(|_| Arc::new(AtomicIsize::new(0))).collect();
        m.init_shards(gauges);
        m.set_arrival_cap(64);
        let phase = |m: &Metrics| {
            for i in 0..50_000usize {
                let artifact = if i % 2 == 0 { "a" } else { "b" };
                m.record_shard(i % 2, artifact, true, 1e-4, 2e-4);
                m.record_arrival_at(artifact, i as f64 * 1e-3);
            }
        };
        phase(&m);
        let after_one_phase = m.approx_mem_bytes();
        phase(&m);
        assert_eq!(
            m.approx_mem_bytes(),
            after_one_phase,
            "50k more requests must not grow the metrics sink"
        );
        let s = m.snapshot();
        assert_eq!(s.total_served(), 100_000);
        // the histograms still summarize correctly at this volume
        let a = s.rows.first().unwrap();
        assert!((a.e2e.as_ref().unwrap().mean - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn decisions_counted_rejections_included() {
        let m = Metrics::default();
        let rejected = DecisionRecord {
            at_s: 1.5,
            to: "cand-b".into(),
            before_mj: 1.2,
            after_mj: 1.0,
            reconfig_mj: 10.0,
            amortized_mj: 0.5,
            net_gain_mj: -0.3,
            margin_mj: 0.0,
            drift: Some(0.8),
            switched: false,
        };
        m.record_decision(rejected.clone());
        m.record_decision(DecisionRecord {
            at_s: 2.5,
            net_gain_mj: 0.7,
            switched: true,
            ..rejected
        });
        let s = m.snapshot();
        assert_eq!(s.decisions, 2);
        assert_eq!(s.decisions_rejected, 1);
        let last = s.last_decision.as_ref().unwrap();
        assert!(last.switched);
        assert!((last.net_gain_mj - 0.7).abs() < 1e-12);
        let r = s.render();
        assert!(r.contains("decisions: 2 total, 1 rejected"), "{r}");
        assert!(r.contains("committed"), "{r}");

        // at_s == 0.0 stamps "now", mirroring record_switch
        m.record_decision(DecisionRecord {
            at_s: 0.0,
            switched: false,
            ..s.last_decision.clone().unwrap()
        });
        let s2 = m.snapshot();
        assert_eq!(s2.decisions_rejected, 2);
        assert!(s2.last_decision.unwrap().at_s >= 0.0);
    }

    #[test]
    fn out_of_range_shard_ignored() {
        let m = Metrics::default();
        // no init_shards: per-shard calls must not panic
        m.record_submit(5);
        m.record_reject(5);
        m.record_batch(5, 1, 1);
        m.record_shard(5, "a", true, 0.0, 0.001);
        assert_eq!(m.snapshot().total_served(), 1);
        assert!(m.snapshot().shards.is_empty());
    }
}
