//! Serving metrics: per-artifact latency/throughput accounting, shared
//! between the worker thread and observers.

use crate::util::stats::Summary;
use crate::util::table::{num, Table};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Default)]
struct ArtifactStats {
    served: u64,
    failed: u64,
    queue_wait_s: Vec<f64>,
    exec_s: Vec<f64>,
    e2e_s: Vec<f64>,
}

/// Thread-safe metrics sink.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, ArtifactStats>>,
    start: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(BTreeMap::new()),
            start: Instant::now(),
        }
    }
}

impl Metrics {
    pub fn record(&self, artifact: &str, ok: bool, queue_wait_s: f64, exec_s: f64) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(artifact.to_string()).or_default();
        if ok {
            s.served += 1;
            s.queue_wait_s.push(queue_wait_s);
            s.exec_s.push(exec_s);
            s.e2e_s.push(queue_wait_s + exec_s);
        } else {
            s.failed += 1;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let elapsed = self.start.elapsed().as_secs_f64();
        let rows = m
            .iter()
            .map(|(name, s)| ArtifactSnapshot {
                artifact: name.clone(),
                served: s.served,
                failed: s.failed,
                throughput_rps: s.served as f64 / elapsed.max(1e-9),
                queue_wait: maybe_summary(&s.queue_wait_s),
                exec: maybe_summary(&s.exec_s),
                e2e: maybe_summary(&s.e2e_s),
            })
            .collect();
        MetricsSnapshot {
            elapsed_s: elapsed,
            rows,
        }
    }
}

fn maybe_summary(v: &[f64]) -> Option<Summary> {
    if v.is_empty() {
        None
    } else {
        Some(Summary::of(v))
    }
}

#[derive(Debug)]
pub struct ArtifactSnapshot {
    pub artifact: String,
    pub served: u64,
    pub failed: u64,
    pub throughput_rps: f64,
    pub queue_wait: Option<Summary>,
    pub exec: Option<Summary>,
    pub e2e: Option<Summary>,
}

#[derive(Debug)]
pub struct MetricsSnapshot {
    pub elapsed_s: f64,
    pub rows: Vec<ArtifactSnapshot>,
}

impl MetricsSnapshot {
    pub fn total_served(&self) -> u64 {
        self.rows.iter().map(|r| r.served).sum()
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "artifact", "served", "fail", "rps", "p50 ms", "p99 ms", "exec p50 ms",
        ])
        .with_title(&format!("Serving metrics ({:.1}s)", self.elapsed_s));
        for r in &self.rows {
            let p = |s: &Option<Summary>, f: fn(&Summary) -> f64| {
                s.as_ref().map(|s| num(f(s) * 1e3, 3)).unwrap_or_else(|| "-".into())
            };
            t.row(&[
                r.artifact.clone(),
                r.served.to_string(),
                r.failed.to_string(),
                num(r.throughput_rps, 1),
                p(&r.e2e, |s| s.p50),
                p(&r.e2e, |s| s.p99),
                p(&r.exec, |s| s.p50),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.record("a", true, 0.001, 0.002);
        m.record("a", true, 0.002, 0.002);
        m.record("a", false, 0.0, 0.0);
        m.record("b", true, 0.0, 0.001);
        let s = m.snapshot();
        assert_eq!(s.total_served(), 3);
        let a = &s.rows[0];
        assert_eq!(a.artifact, "a");
        assert_eq!(a.served, 2);
        assert_eq!(a.failed, 1);
        assert!((a.e2e.as_ref().unwrap().mean - 0.0035).abs() < 1e-9);
        assert!(s.render().contains("Serving metrics"));
    }
}
