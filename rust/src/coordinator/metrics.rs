//! Serving metrics: per-artifact latency/throughput accounting plus
//! per-shard counters (queue depth, batch fill, admission rejects),
//! shared between the shard worker threads and observers.

use crate::util::stats::Summary;
use crate::util::sync::locked;
use crate::util::table::{num, Table};
use crate::util::units::Secs;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicIsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default bound on the per-artifact arrival-trace ring.
pub const DEFAULT_ARRIVAL_CAP: usize = 4096;

#[derive(Debug, Default)]
struct ArtifactStats {
    served: u64,
    failed: u64,
    queue_wait_s: Vec<f64>,
    exec_s: Vec<f64>,
    e2e_s: Vec<f64>,
    /// Bounded ring of arrival timestamps (seconds since the metrics
    /// epoch) — the raw material the workload fitter consumes.
    arrivals: VecDeque<f64>,
}

#[derive(Debug, Default)]
struct ShardStats {
    submitted: u64,
    rejected: u64,
    /// Subset of `rejected` bounced because the shard was draining for an
    /// engine swap (bounded by the drain window).
    drain_rejected: u64,
    served: u64,
    failed: u64,
    batches: u64,
    batch_fill_sum: f64,
    exec_s: Vec<f64>,
    e2e_s: Vec<f64>,
}

/// One completed drain-and-switch reconfiguration.
#[derive(Debug, Clone)]
pub struct SwitchEvent {
    /// Seconds since the metrics epoch.
    pub at_s: f64,
    /// Candidate descriptions (Candidate::describe / Workload::describe).
    pub from: String,
    pub to: String,
    /// Modeled energy/item before and after, when known.
    pub before_mj: Option<f64>,
    pub after_mj: Option<f64>,
    /// Drift score that triggered the re-exploration.
    pub drift: Option<f64>,
    /// Requests rejected during the drain window of this switch.
    pub drain_rejected: u64,
}

impl SwitchEvent {
    fn render_line(&self) -> String {
        let mj = |v: Option<f64>| v.map(|x| format!("{x:.3} mJ/item")).unwrap_or_else(|| "-".into());
        format!(
            "switch @{:.1}s: {} -> {} (before {}, after {}, drift {}, drain rejects {})",
            self.at_s,
            self.from,
            self.to,
            mj(self.before_mj),
            mj(self.after_mj),
            self.drift.map(|d| format!("{d:.2}")).unwrap_or_else(|| "-".into()),
            self.drain_rejected,
        )
    }
}

/// Thread-safe metrics sink.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<BTreeMap<String, ArtifactStats>>,
    shards: Mutex<Vec<ShardStats>>,
    /// Live queue-depth gauges, one per shard (shared with the submit
    /// path; isize because producer increments and worker decrements race
    /// benignly).
    depth_gauges: Mutex<Vec<Arc<AtomicIsize>>>,
    start: Mutex<Option<Instant>>,
    arrival_cap: Mutex<usize>,
    switches: Mutex<Vec<SwitchEvent>>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            inner: Mutex::default(),
            shards: Mutex::default(),
            depth_gauges: Mutex::default(),
            start: Mutex::default(),
            arrival_cap: Mutex::new(DEFAULT_ARRIVAL_CAP),
            switches: Mutex::default(),
        }
    }
}

impl Metrics {
    /// Register the shard layout.  Called once by `Coordinator::start`.
    pub fn init_shards(&self, gauges: Vec<Arc<AtomicIsize>>) {
        {
            let mut shards = locked(&self.shards);
            *shards = Vec::new();
            shards.resize_with(gauges.len(), ShardStats::default);
        }
        *locked(&self.depth_gauges) = gauges;
        *locked(&self.start) = Some(Instant::now());
    }

    fn elapsed_s(&self) -> f64 {
        locked(&self.start)
            .get_or_insert_with(Instant::now)
            .elapsed()
            .as_secs_f64()
    }

    /// Record one served/failed request against its artifact.
    pub fn record(&self, artifact: &str, ok: bool, queue_wait_s: f64, exec_s: f64) {
        // pin the epoch on first use so throughput reflects serving time
        self.elapsed_s();
        let mut m = locked(&self.inner);
        let s = m.entry(artifact.to_string()).or_default();
        if ok {
            s.served += 1;
            s.queue_wait_s.push(queue_wait_s);
            s.exec_s.push(exec_s);
            s.e2e_s.push(queue_wait_s + exec_s);
        } else {
            s.failed += 1;
        }
    }

    /// Record one executed request against both its artifact and shard.
    pub fn record_shard(
        &self,
        shard: usize,
        artifact: &str,
        ok: bool,
        queue_wait_s: f64,
        exec_s: f64,
    ) {
        self.record(artifact, ok, queue_wait_s, exec_s);
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            if ok {
                s.served += 1;
                s.exec_s.push(exec_s);
                s.e2e_s.push(queue_wait_s + exec_s);
            } else {
                s.failed += 1;
            }
        }
    }

    /// An admitted request was enqueued on `shard`.
    pub fn record_submit(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.submitted += 1;
        }
    }

    /// Admission control rejected a request bound for `shard`.
    pub fn record_reject(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.rejected += 1;
        }
    }

    /// A request bounced off `shard` because it was draining for a swap.
    /// Counted both in the total reject tally and separately, so tests can
    /// bound rejects attributable to the drain window.
    pub fn record_drain_reject(&self, shard: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.rejected += 1;
            s.drain_rejected += 1;
        }
    }

    /// Change the arrival-ring bound (existing rings are trimmed lazily on
    /// the next arrival).
    pub fn set_arrival_cap(&self, cap: usize) {
        *locked(&self.arrival_cap) = cap.max(1);
    }

    /// Record an arrival for `artifact` at "now" (seconds since the
    /// metrics epoch).  Called on the submit path.
    pub fn record_arrival(&self, artifact: &str) {
        let t = self.elapsed_s();
        self.record_arrival_at(artifact, t);
    }

    /// Record an arrival at an explicit timestamp.  Test/replay entry
    /// point: the adaptive loop's hermetic tests inject synthetic traces
    /// here instead of depending on the wall clock.
    pub fn record_arrival_at(&self, artifact: &str, t_s: f64) {
        let cap = *locked(&self.arrival_cap);
        let mut m = locked(&self.inner);
        let ring = &mut m.entry(artifact.to_string()).or_default().arrivals;
        while ring.len() >= cap {
            ring.pop_front();
        }
        ring.push_back(t_s);
    }

    /// The recorded arrival trace for `artifact`, oldest first.
    pub fn arrival_trace(&self, artifact: &str) -> Vec<Secs> {
        let m = locked(&self.inner);
        m.get(artifact)
            .map(|s| s.arrivals.iter().map(|&t| Secs(t)).collect())
            .unwrap_or_default()
    }

    /// Drop the recorded arrivals for `artifact` (after a switch the old
    /// trace describes the previous regime and would bias the next fit).
    pub fn reset_arrivals(&self, artifact: &str) {
        let mut m = locked(&self.inner);
        if let Some(s) = m.get_mut(artifact) {
            s.arrivals.clear();
        }
    }

    /// Record a completed drain-and-switch reconfiguration.
    pub fn record_switch(&self, mut event: SwitchEvent) {
        if event.at_s == 0.0 {
            event.at_s = self.elapsed_s();
        }
        locked(&self.switches).push(event);
    }

    /// Completed switch events, oldest first.
    pub fn switch_events(&self) -> Vec<SwitchEvent> {
        locked(&self.switches).clone()
    }

    /// One micro-batch of `fill` requests drained (window `cap`).
    pub fn record_batch(&self, shard: usize, fill: usize, cap: usize) {
        let mut shards = locked(&self.shards);
        if let Some(s) = shards.get_mut(shard) {
            s.batches += 1;
            s.batch_fill_sum += fill as f64 / cap.max(1) as f64;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let elapsed = self.elapsed_s();
        let m = locked(&self.inner);
        let rows = m
            .iter()
            .map(|(name, s)| ArtifactSnapshot {
                artifact: name.clone(),
                served: s.served,
                failed: s.failed,
                throughput_rps: s.served as f64 / elapsed.max(1e-9),
                queue_wait: maybe_summary(&s.queue_wait_s),
                exec: maybe_summary(&s.exec_s),
                e2e: maybe_summary(&s.e2e_s),
                arrivals: s.arrivals.len(),
            })
            .collect();
        let gauges = locked(&self.depth_gauges);
        let shards = locked(&self.shards)
            .iter()
            .enumerate()
            .map(|(i, s)| ShardSnapshot {
                shard: i,
                submitted: s.submitted,
                rejected: s.rejected,
                drain_rejected: s.drain_rejected,
                served: s.served,
                failed: s.failed,
                queue_depth: gauges
                    .get(i)
                    .map(|g| g.load(Ordering::Relaxed).max(0) as usize)
                    .unwrap_or(0),
                batches: s.batches,
                batch_fill: if s.batches == 0 {
                    0.0
                } else {
                    s.batch_fill_sum / s.batches as f64
                },
                exec: maybe_summary(&s.exec_s),
                e2e: maybe_summary(&s.e2e_s),
            })
            .collect();
        MetricsSnapshot {
            elapsed_s: elapsed,
            rows,
            shards,
            switches: locked(&self.switches).clone(),
        }
    }
}

fn maybe_summary(v: &[f64]) -> Option<Summary> {
    if v.is_empty() {
        None
    } else {
        Some(Summary::of(v))
    }
}

#[derive(Debug)]
pub struct ArtifactSnapshot {
    pub artifact: String,
    pub served: u64,
    pub failed: u64,
    pub throughput_rps: f64,
    pub queue_wait: Option<Summary>,
    pub exec: Option<Summary>,
    pub e2e: Option<Summary>,
    /// Arrival timestamps currently held in the bounded trace ring.
    pub arrivals: usize,
}

/// Point-in-time view of one engine shard.
#[derive(Debug)]
pub struct ShardSnapshot {
    pub shard: usize,
    pub submitted: u64,
    pub rejected: u64,
    /// Subset of `rejected` bounced during swap drain windows.
    pub drain_rejected: u64,
    pub served: u64,
    pub failed: u64,
    /// Requests currently waiting in the shard's bounded queue.
    pub queue_depth: usize,
    pub batches: u64,
    /// Mean micro-batch fill ratio in [0, 1] (drained / batch_max).
    pub batch_fill: f64,
    pub exec: Option<Summary>,
    pub e2e: Option<Summary>,
}

#[derive(Debug)]
pub struct MetricsSnapshot {
    pub elapsed_s: f64,
    pub rows: Vec<ArtifactSnapshot>,
    pub shards: Vec<ShardSnapshot>,
    /// Completed drain-and-switch reconfigurations, oldest first.
    pub switches: Vec<SwitchEvent>,
}

impl MetricsSnapshot {
    pub fn total_served(&self) -> u64 {
        self.rows.iter().map(|r| r.served).sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.rejected).sum()
    }

    pub fn total_drain_rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.drain_rejected).sum()
    }

    pub fn render(&self) -> String {
        let p = |s: &Option<Summary>, f: fn(&Summary) -> f64| {
            s.as_ref().map(|s| num(f(s) * 1e3, 3)).unwrap_or_else(|| "-".into())
        };
        let mut t = Table::new(&[
            "artifact", "served", "fail", "rps", "p50 ms", "p99 ms", "exec p50 ms",
        ])
        .with_title(&format!("Serving metrics ({:.1}s)", self.elapsed_s));
        for r in &self.rows {
            t.row(&[
                r.artifact.clone(),
                r.served.to_string(),
                r.failed.to_string(),
                num(r.throughput_rps, 1),
                p(&r.e2e, |s| s.p50),
                p(&r.e2e, |s| s.p99),
                p(&r.exec, |s| s.p50),
            ]);
        }
        let mut out = t.render();
        if !self.shards.is_empty() {
            let mut st = Table::new(&[
                "shard", "submitted", "rejected", "served", "fail", "depth", "batch fill",
                "p50 ms", "p99 ms",
            ])
            .with_title("Per-shard counters");
            for s in &self.shards {
                st.row(&[
                    s.shard.to_string(),
                    s.submitted.to_string(),
                    s.rejected.to_string(),
                    s.served.to_string(),
                    s.failed.to_string(),
                    s.queue_depth.to_string(),
                    num(s.batch_fill, 2),
                    p(&s.e2e, |x| x.p50),
                    p(&s.e2e, |x| x.p99),
                ]);
            }
            out.push('\n');
            out.push_str(&st.render());
        }
        for sw in &self.switches {
            out.push('\n');
            out.push_str(&sw.render_line());
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    #[test]
    fn metrics_survive_a_poisoned_lock() {
        // a worker thread that panics while holding a metrics lock must
        // not cascade into panics on every later record/snapshot call
        let m = Arc::new(Metrics::default());
        m.record("a", true, 0.001, 0.002);
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.inner.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert!(m.inner.is_poisoned());
        m.record("a", true, 0.001, 0.002);
        m.record_arrival_at("a", 0.5);
        let s = m.snapshot();
        assert_eq!(s.total_served(), 2);
        assert_eq!(s.rows[0].arrivals, 1);
    }

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.record("a", true, 0.001, 0.002);
        m.record("a", true, 0.002, 0.002);
        m.record("a", false, 0.0, 0.0);
        m.record("b", true, 0.0, 0.001);
        let s = m.snapshot();
        assert_eq!(s.total_served(), 3);
        let a = &s.rows[0];
        assert_eq!(a.artifact, "a");
        assert_eq!(a.served, 2);
        assert_eq!(a.failed, 1);
        assert!((a.e2e.as_ref().unwrap().mean - 0.0035).abs() < 1e-9);
        assert!(s.render().contains("Serving metrics"));
    }

    #[test]
    fn per_shard_accounting() {
        let m = Metrics::default();
        let gauges: Vec<Arc<AtomicIsize>> =
            (0..2).map(|_| Arc::new(AtomicIsize::new(0))).collect();
        gauges[1].store(3, Ordering::Relaxed);
        m.init_shards(gauges);

        m.record_submit(0);
        m.record_submit(1);
        m.record_submit(1);
        m.record_reject(1);
        m.record_batch(0, 4, 16);
        m.record_batch(0, 8, 16);
        m.record_shard(0, "a", true, 0.001, 0.002);
        m.record_shard(1, "a", false, 0.0, 0.0);

        let s = m.snapshot();
        assert_eq!(s.shards.len(), 2);
        assert_eq!(s.shards[0].submitted, 1);
        assert_eq!(s.shards[0].served, 1);
        assert!((s.shards[0].batch_fill - 0.375).abs() < 1e-9);
        assert_eq!(s.shards[1].submitted, 2);
        assert_eq!(s.shards[1].rejected, 1);
        assert_eq!(s.shards[1].failed, 1);
        assert_eq!(s.shards[1].queue_depth, 3);
        assert_eq!(s.total_rejected(), 1);
        // shard execution also feeds the per-artifact table
        assert_eq!(s.total_served(), 1);
        assert!(s.render().contains("Per-shard counters"));
    }

    #[test]
    fn arrival_ring_is_bounded_and_resettable() {
        let m = Metrics::default();
        m.set_arrival_cap(8);
        for i in 0..20 {
            m.record_arrival_at("a", i as f64 * 0.1);
        }
        let trace = m.arrival_trace("a");
        assert_eq!(trace.len(), 8, "ring must stay bounded");
        // oldest entries evicted: ring holds the last 8 timestamps
        assert!((trace[0].value() - 1.2).abs() < 1e-9);
        assert!((trace[7].value() - 1.9).abs() < 1e-9);
        assert!(trace.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(m.snapshot().rows[0].arrivals, 8);

        m.reset_arrivals("a");
        assert!(m.arrival_trace("a").is_empty());
        // unknown artifact -> empty, no panic
        assert!(m.arrival_trace("nope").is_empty());
    }

    #[test]
    fn switch_events_recorded_and_rendered() {
        let m = Metrics::default();
        let gauges: Vec<Arc<AtomicIsize>> =
            (0..1).map(|_| Arc::new(AtomicIsize::new(0))).collect();
        m.init_shards(gauges);
        m.record_drain_reject(0);
        m.record_drain_reject(0);
        m.record_switch(SwitchEvent {
            at_s: 12.5,
            from: "idle-wait".into(),
            to: "on-off".into(),
            before_mj: Some(1.25),
            after_mj: Some(0.4),
            drift: Some(0.9),
            drain_rejected: 2,
        });
        let s = m.snapshot();
        assert_eq!(s.switches.len(), 1);
        assert_eq!(s.shards[0].drain_rejected, 2);
        assert_eq!(s.shards[0].rejected, 2);
        assert_eq!(s.total_drain_rejected(), 2);
        let r = s.render();
        assert!(r.contains("switch @12.5s: idle-wait -> on-off"), "{r}");
        assert!(r.contains("drain rejects 2"), "{r}");
        assert_eq!(m.switch_events().len(), 1);
    }

    #[test]
    fn out_of_range_shard_ignored() {
        let m = Metrics::default();
        // no init_shards: per-shard calls must not panic
        m.record_submit(5);
        m.record_reject(5);
        m.record_batch(5, 1, 1);
        m.record_shard(5, "a", true, 0.0, 0.001);
        assert_eq!(m.snapshot().total_served(), 1);
        assert!(m.snapshot().shards.is_empty());
    }
}
