//! Variant routing: choose which compiled accelerator artifact serves a
//! model request, using the same application knowledge the Generator
//! consumed (precision budget, energy preference).

use crate::runtime::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Result};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Lowest activation error (exact variants).
    HighestPrecision,
    /// Cheapest variant within an error budget (LSBs at the artifact's
    /// own format) — the Generator's serving-side counterpart.
    CheapestWithin { max_error_lsb: u32 },
    /// A specific named artifact.
    Named,
}

/// Maps model names to artifacts.
#[derive(Debug, Clone)]
pub struct Router {
    entries: Vec<ArtifactMeta>,
}

impl Router {
    pub fn new(manifest: &Manifest) -> Router {
        Router {
            entries: manifest.models().cloned().collect(),
        }
    }

    fn error_lsb(meta: &ArtifactMeta) -> f64 {
        let sig = meta
            .sigmoid_variant()
            .map(|v| v.max_error_lsb(meta.fmt))
            .unwrap_or(0.0);
        let tan = meta
            .tanh_variant()
            .map(|v| v.max_error_lsb(meta.fmt))
            .unwrap_or(0.0);
        sig.max(tan)
    }

    /// Relative serving cost proxy: hard < lut < pla < exact, scaled down
    /// by pipelining (matches the template cycle model's ordering).
    fn cost_rank(meta: &ArtifactMeta) -> f64 {
        let base = match meta.act_impl.as_str() {
            "hard" => 1.0,
            "lut" => 2.0,
            "pla" => 3.0,
            _ => 6.0,
        };
        if meta.pipelined {
            base * 0.5
        } else {
            base
        }
    }

    /// Route a request for `model` under `policy`.
    pub fn route(&self, model: &str, policy: Policy) -> Result<&ArtifactMeta> {
        let candidates: Vec<&ArtifactMeta> =
            self.entries.iter().filter(|a| a.model == model).collect();
        if candidates.is_empty() {
            return Err(anyhow!("no artifact for model '{model}'"));
        }
        let chosen = match policy {
            Policy::Named => candidates[0],
            Policy::HighestPrecision => candidates
                .iter()
                .min_by(|a, b| {
                    Self::error_lsb(a)
                        .partial_cmp(&Self::error_lsb(b))
                        .unwrap()
                })
                .unwrap(),
            Policy::CheapestWithin { max_error_lsb } => {
                let within: Vec<&&ArtifactMeta> = candidates
                    .iter()
                    .filter(|a| Self::error_lsb(a) <= max_error_lsb as f64)
                    .collect();
                if within.is_empty() {
                    return Err(anyhow!(
                        "no {model} variant within {max_error_lsb} LSB error budget"
                    ));
                }
                within
                    .into_iter()
                    .min_by(|a, b| {
                        Self::cost_rank(a).partial_cmp(&Self::cost_rank(b)).unwrap()
                    })
                    .unwrap()
            }
        };
        Ok(chosen)
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|a| a.model.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::fixed_point::Q16_8;
    use std::path::PathBuf;

    fn meta(name: &str, model: &str, act: &str, act_impl: &str, pipelined: bool) -> ArtifactMeta {
        ArtifactMeta {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            kind: "model".into(),
            model: model.into(),
            fmt: Q16_8,
            act: act.into(),
            act_impl: act_impl.into(),
            tanh_impl: String::new(),
            pipelined,
            alus: 1,
            input_shape: vec![8],
            output_shape: vec![1],
            note: String::new(),
        }
    }

    fn router() -> Router {
        Router {
            entries: vec![
                meta("m.base", "mlp_fluid", "sigmoid", "exact", false),
                meta("m.pla", "mlp_fluid", "sigmoid", "pla", false),
                meta("m.hard", "mlp_fluid", "hardsigmoid", "hard", true),
            ],
        }
    }

    #[test]
    fn highest_precision_prefers_exact() {
        let r = router();
        // Hard* variants have zero approximation error to *their own*
        // definition; among sigmoid impls, exact has the least error to
        // sigmoid.  hard ties at 1 LSB -> min_by keeps the first minimum.
        let a = r.route("mlp_fluid", Policy::HighestPrecision).unwrap();
        assert!(a.act_impl == "exact" || a.act_impl == "hard");
    }

    #[test]
    fn cheapest_within_budget_prefers_hard() {
        let r = router();
        let a = r
            .route("mlp_fluid", Policy::CheapestWithin { max_error_lsb: 50 })
            .unwrap();
        assert_eq!(a.act_impl, "hard");
    }

    #[test]
    fn tight_budget_excludes_pla() {
        let r = Router {
            entries: vec![meta("m.pla", "mlp_fluid", "sigmoid", "pla", false)],
        };
        // PLA error ~0.0189 = ~4.8 LSB at q16_8 (+1) -> budget 2 fails
        assert!(r
            .route("mlp_fluid", Policy::CheapestWithin { max_error_lsb: 2 })
            .is_err());
    }

    #[test]
    fn unknown_model_errors() {
        assert!(router().route("nope", Policy::Named).is_err());
        let _ = PathBuf::new(); // silence unused import on some cfgs
    }
}
