//! Routing: (a) variant routing — choose which compiled accelerator
//! artifact serves a model request, using the same application knowledge
//! the Generator consumed (precision budget, energy preference); and
//! (b) shard routing — choose which engine shard executes an admitted
//! request.

use crate::runtime::{ArtifactMeta, Manifest};
use crate::util::rng::fnv1a;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

/// How requests map to engine shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Hash the artifact name to a home shard: every request for one
    /// artifact lands on the same engine (warm executable, predictable
    /// batching).  The default.
    Affinity,
    /// Send to the shard with the shallowest queue (work stealing for
    /// skewed artifact popularity).
    LeastLoaded,
    /// Rotate across shards regardless of artifact (maximum spread; used
    /// by the scaling benchmarks).
    RoundRobin,
}

/// Maps admitted requests to engine shards under a [`ShardPolicy`].
#[derive(Debug)]
pub struct ShardRouter {
    policy: ShardPolicy,
    shards: usize,
    rr: AtomicUsize,
}

impl ShardRouter {
    pub fn new(policy: ShardPolicy, shards: usize) -> ShardRouter {
        assert!(shards > 0, "shard count must be positive");
        ShardRouter {
            policy,
            shards,
            rr: AtomicUsize::new(0),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether `pick` consults queue depths (lets the submit hot path
    /// skip gathering them for depth-blind policies).
    pub fn needs_depths(&self) -> bool {
        self.policy == ShardPolicy::LeastLoaded
    }

    /// The artifact's home shard (stable across processes: FNV-1a).
    pub fn home(&self, artifact: &str) -> usize {
        (fnv1a(artifact) % self.shards as u64) as usize
    }

    /// Pick the shard for one request.  `depths` are the current queue
    /// depths, indexed by shard (only consulted by `LeastLoaded`).
    pub fn pick(&self, artifact: &str, depths: &[usize]) -> usize {
        match self.policy {
            ShardPolicy::Affinity => self.home(artifact),
            ShardPolicy::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % self.shards,
            ShardPolicy::LeastLoaded => depths
                .iter()
                .enumerate()
                .take(self.shards)
                .min_by_key(|(_, &d)| d)
                .map(|(i, _)| i)
                .unwrap_or_else(|| self.home(artifact)),
        }
    }
}

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Lowest activation error (exact variants).
    HighestPrecision,
    /// Cheapest variant within an error budget (LSBs at the artifact's
    /// own format) — the Generator's serving-side counterpart.
    CheapestWithin { max_error_lsb: u32 },
    /// A specific named artifact.
    Named,
}

/// Maps model names to artifacts.
#[derive(Debug, Clone)]
pub struct Router {
    entries: Vec<ArtifactMeta>,
}

impl Router {
    pub fn new(manifest: &Manifest) -> Router {
        Router {
            entries: manifest.models().cloned().collect(),
        }
    }

    fn error_lsb(meta: &ArtifactMeta) -> f64 {
        let sig = meta
            .sigmoid_variant()
            .map(|v| v.max_error_lsb(meta.fmt))
            .unwrap_or(0.0);
        let tan = meta
            .tanh_variant()
            .map(|v| v.max_error_lsb(meta.fmt))
            .unwrap_or(0.0);
        sig.max(tan)
    }

    /// Relative serving cost proxy: hard < lut < pla < exact, scaled down
    /// by pipelining (matches the template cycle model's ordering).
    fn cost_rank(meta: &ArtifactMeta) -> f64 {
        let base = match meta.act_impl.as_str() {
            "hard" => 1.0,
            "lut" => 2.0,
            "pla" => 3.0,
            _ => 6.0,
        };
        if meta.pipelined {
            base * 0.5
        } else {
            base
        }
    }

    /// Route a request for `model` under `policy`.
    pub fn route(&self, model: &str, policy: Policy) -> Result<&ArtifactMeta> {
        let candidates: Vec<&ArtifactMeta> =
            self.entries.iter().filter(|a| a.model == model).collect();
        // `total_cmp` (not `partial_cmp().unwrap()`): error/cost proxies
        // are finite by construction, and a NaN from a future estimator
        // change must not panic the serving path
        let Some(&first) = candidates.first() else {
            return Err(anyhow!("no artifact for model '{model}'"));
        };
        let chosen = match policy {
            Policy::Named => first,
            Policy::HighestPrecision => candidates
                .iter()
                .copied()
                .min_by(|a, b| Self::error_lsb(a).total_cmp(&Self::error_lsb(b)))
                .unwrap_or(first),
            Policy::CheapestWithin { max_error_lsb } => candidates
                .iter()
                .copied()
                .filter(|a| Self::error_lsb(a) <= max_error_lsb as f64)
                .min_by(|a, b| Self::cost_rank(a).total_cmp(&Self::cost_rank(b)))
                .ok_or_else(|| {
                    anyhow!("no {model} variant within {max_error_lsb} LSB error budget")
                })?,
        };
        Ok(chosen)
    }

    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|a| a.model.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::rtl::fixed_point::Q16_8;
    use std::path::PathBuf;

    fn meta(name: &str, model: &str, act: &str, act_impl: &str, pipelined: bool) -> ArtifactMeta {
        ArtifactMeta {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            kind: "model".into(),
            model: model.into(),
            fmt: Q16_8,
            act: act.into(),
            act_impl: act_impl.into(),
            tanh_impl: String::new(),
            pipelined,
            alus: 1,
            input_shape: vec![8],
            output_shape: vec![1],
            note: String::new(),
        }
    }

    fn router() -> Router {
        Router {
            entries: vec![
                meta("m.base", "mlp_fluid", "sigmoid", "exact", false),
                meta("m.pla", "mlp_fluid", "sigmoid", "pla", false),
                meta("m.hard", "mlp_fluid", "hardsigmoid", "hard", true),
            ],
        }
    }

    #[test]
    fn highest_precision_prefers_exact() {
        let r = router();
        // Hard* variants have zero approximation error to *their own*
        // definition; among sigmoid impls, exact has the least error to
        // sigmoid.  hard ties at 1 LSB -> min_by keeps the first minimum.
        let a = r.route("mlp_fluid", Policy::HighestPrecision).unwrap();
        assert!(a.act_impl == "exact" || a.act_impl == "hard");
    }

    #[test]
    fn cheapest_within_budget_prefers_hard() {
        let r = router();
        let a = r
            .route("mlp_fluid", Policy::CheapestWithin { max_error_lsb: 50 })
            .unwrap();
        assert_eq!(a.act_impl, "hard");
    }

    #[test]
    fn tight_budget_excludes_pla() {
        let r = Router {
            entries: vec![meta("m.pla", "mlp_fluid", "sigmoid", "pla", false)],
        };
        // PLA error ~0.0189 = ~4.8 LSB at q16_8 (+1) -> budget 2 fails
        assert!(r
            .route("mlp_fluid", Policy::CheapestWithin { max_error_lsb: 2 })
            .is_err());
    }

    #[test]
    fn unknown_model_errors() {
        assert!(router().route("nope", Policy::Named).is_err());
        let _ = PathBuf::new(); // silence unused import on some cfgs
    }

    #[test]
    fn affinity_is_stable_and_in_range() {
        let r = ShardRouter::new(ShardPolicy::Affinity, 4);
        for name in ["mlp_fluid.hard", "lstm_har.opt", "cnn_ecg.base", "syn.7"] {
            let s = r.pick(name, &[]);
            assert!(s < 4);
            assert_eq!(s, r.pick(name, &[9, 9, 9, 9]), "{name} must be sticky");
            assert_eq!(s, r.home(name));
        }
    }

    #[test]
    fn affinity_spreads_across_shards() {
        let r = ShardRouter::new(ShardPolicy::Affinity, 4);
        let mut hit = [false; 4];
        for i in 0..64 {
            hit[r.home(&format!("artifact.{i}"))] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 names must cover 4 shards: {hit:?}");
    }

    #[test]
    fn round_robin_rotates() {
        let r = ShardRouter::new(ShardPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.pick("same", &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_takes_shallowest_queue() {
        let r = ShardRouter::new(ShardPolicy::LeastLoaded, 3);
        assert_eq!(r.pick("x", &[5, 1, 3]), 1);
        assert_eq!(r.pick("x", &[0, 0, 0]), 0); // tie -> lowest index
    }
}
