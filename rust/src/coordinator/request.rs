//! Request/response types crossing the coordinator's shard queues.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// An inference request bound for one artifact.
pub struct Request {
    pub id: u64,
    pub artifact: String,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Reply channel (one-shot use).
    pub reply: Sender<Response>,
}

/// The served result with timing breakdown.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub artifact: String,
    /// Engine shard that executed the request.
    pub shard: usize,
    pub output: Result<Vec<f32>, String>,
    /// Time spent queued before the engine picked the request up.
    pub queue_wait_s: f64,
    /// Engine execution time.
    pub exec_s: f64,
}

impl Response {
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.exec_s
    }

    pub fn is_ok(&self) -> bool {
        self.output.is_ok()
    }
}

/// Admission-control rejection: the coordinator refuses a request with a
/// reason instead of letting queues grow without bound.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The selected shard's bounded queue is at capacity.
    QueueFull { shard: usize, capacity: usize },
    /// The coordinator is draining; no new work is admitted.
    ShuttingDown,
    /// The selected shard is draining for an engine swap; retry shortly.
    Draining { shard: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { shard, capacity } => {
                write!(f, "shard {shard} queue full (capacity {capacity})")
            }
            SubmitError::ShuttingDown => write!(f, "coordinator is shutting down"),
            SubmitError::Draining { shard } => {
                write!(f, "shard {shard} is draining for an engine swap")
            }
        }
    }
}

impl std::error::Error for SubmitError {}
