//! Request/response types crossing the coordinator queue.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// An inference request bound for one artifact.
pub struct Request {
    pub id: u64,
    pub artifact: String,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// Reply channel (one-shot use).
    pub reply: Sender<Response>,
}

/// The served result with timing breakdown.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub artifact: String,
    pub output: Result<Vec<f32>, String>,
    /// Time spent queued before the engine picked the request up.
    pub queue_wait_s: f64,
    /// Engine execution time.
    pub exec_s: f64,
}

impl Response {
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.exec_s
    }

    pub fn is_ok(&self) -> bool {
        self.output.is_ok()
    }
}
