//! L3 serving coordinator: request routing, micro-batching, a pool of
//! engine shard threads, and serving metrics.
//!
//! The paper's deployment shape is a single FPGA behind an MCU; the
//! software twin generalises it to N engine shards (one accelerator
//! emulation per shard thread), each owning its engine exclusively — PJRT
//! executables hold raw runtime handles and stay on one thread.  Requests
//! affinitise to shards by artifact hash, queue in bounded per-shard
//! channels with admission control, and drain in micro-batches the way
//! the MCU batches sensor windows.  See DESIGN.md §Coordinator.

// serving path: a panic here takes down a shard mid-request, so the
// panic-surface invariant is enforced both by `elastic-gen lint` and at
// the clippy layer (tests opt back out per-module)
#![warn(clippy::unwrap_used, clippy::indexing_slicing)]

pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use metrics::{DecisionRecord, Metrics, MetricsSnapshot, ShardSnapshot, SwitchEvent};
pub use request::{Request, Response, SubmitError};
pub use router::{Router, ShardPolicy, ShardRouter};
pub use server::{Coordinator, CoordinatorConfig, EngineSpec, SwapReport, SwitchInfo};
