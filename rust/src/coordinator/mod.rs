//! L3 serving coordinator: request routing, micro-batching, a dedicated
//! PJRT worker thread, and serving metrics.
//!
//! The paper's deployment shape is a single FPGA behind an MCU; the
//! software twin is a single engine thread owning the PJRT client (the
//! executables hold raw runtime handles and stay on one thread), fed
//! through an MPSC queue.  Batching amortises dispatch overhead the way
//! the MCU batches sensor windows.

pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{Request, Response};
pub use router::Router;
pub use server::{Coordinator, CoordinatorConfig};
