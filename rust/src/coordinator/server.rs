//! The coordinator server: a client handle + a dedicated engine thread.
//!
//! The PJRT executables hold raw runtime handles, so the engine lives on
//! exactly one thread; requests arrive over an MPSC queue, get
//! micro-batched per artifact, executed, and answered over per-request
//! reply channels.

use super::metrics::Metrics;
use super::request::{Request, Response};
use crate::runtime::Engine;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Artifacts to compile at startup (empty = all model artifacts).
    pub artifacts: Vec<String>,
    /// Maximum micro-batch drained per engine pass.
    pub batch_max: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::artifacts_dir(),
            artifacts: vec![],
            batch_max: 16,
        }
    }
}

/// Client handle; cloneable across request-producer threads.
pub struct Coordinator {
    tx: Sender<Request>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the engine thread.  Fails (via the first request) if the
    /// artifacts cannot be loaded; `start` itself waits for engine
    /// readiness so callers get load errors eagerly.
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();

        let worker = std::thread::Builder::new()
            .name("elastic-engine".into())
            .spawn(move || worker_loop(config, rx, m2, ready_tx))
            .expect("spawn engine thread");

        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Coordinator {
                tx,
                metrics,
                next_id: Arc::new(AtomicU64::new(1)),
                worker: Some(worker),
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(anyhow!("engine startup failed: {e}"))
            }
            Err(_) => Err(anyhow!("engine thread died during startup")),
        }
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, artifact: &str, input: Vec<f32>) -> Receiver<Response> {
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            artifact: artifact.to_string(),
            input,
            enqueued: Instant::now(),
            reply,
        };
        // send fails only if the worker died; the caller sees it as a
        // disconnected reply channel
        let _ = self.tx.send(req);
        rx
    }

    /// Submit and wait.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): spin-before-park variants of this
    /// path and of the worker's dequeue were tried and *regressed* the
    /// round-trip 7x on this host — the spinners steal cycles from the
    /// PJRT engine thread.  Plain blocking channels are the optimum here.
    pub fn infer(&self, artifact: &str, input: Vec<f32>) -> Result<Response> {
        self.submit(artifact, input)
            .recv()
            .map_err(|_| anyhow!("engine thread gone"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing the queue stops the worker
        let (dummy_tx, _) = channel::<Request>();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    config: CoordinatorConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    ready: Sender<Result<(), String>>,
) {
    let names: Vec<&str> = config.artifacts.iter().map(|s| s.as_str()).collect();
    let engine = match Engine::load(&config.artifacts_dir, &names) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };

    loop {
        // block for the first request, then drain a micro-batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all handles dropped: shut down
        };
        let mut batch = vec![first];
        while batch.len() < config.batch_max {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break,
            }
        }

        for req in batch {
            let picked_up = Instant::now();
            let queue_wait = picked_up.duration_since(req.enqueued).as_secs_f64();
            let result = engine.infer(&req.artifact, &req.input);
            let exec = picked_up.elapsed().as_secs_f64();
            let ok = result.is_ok();
            metrics.record(&req.artifact, ok, queue_wait, exec);
            let _ = req.reply.send(Response {
                id: req.id,
                artifact: req.artifact,
                output: result.map_err(|e| e.to_string()),
                queue_wait_s: queue_wait,
                exec_s: exec,
            });
        }
    }
}

// Integration coverage lives in rust/tests/integration_runtime.rs (needs
// built artifacts).
