//! The sharded coordinator: a client handle + N engine shard threads.
//!
//! Each shard owns one `Engine` (PJRT executables hold raw runtime
//! handles and must stay on the thread that compiled them), fed by its own
//! **bounded** queue.  Requests hash/affinitise to shards via
//! [`ShardRouter`]; each worker drains micro-batches up to `batch_max`,
//! optionally waiting `batch_window` to let a batch fill.  Admission
//! control rejects with a reason ([`SubmitError`]) instead of letting
//! queues grow without bound, and shutdown drains: every admitted request
//! is answered before the workers exit.
//!
//! Engine bindings are **swappable at runtime** (the "Switch" stage of the
//! adaptive serving loop): [`Coordinator::swap_engines`] drains each shard
//! and replaces its engine without restarting the coordinator.  The swap
//! travels *in-band* through the same bounded FIFO queue as requests, so
//! every request admitted before the swap is served by the old engine and
//! every request after by the new one — nothing is lost or double-served.
//! While a shard drains, new submissions to it bounce with
//! [`SubmitError::Draining`]; the reject window is exactly the time the
//! worker needs to serve its backlog plus one engine build.

use super::metrics::{Metrics, SwitchEvent};
use super::request::{Request, Response, SubmitError};
use super::router::{ShardPolicy, ShardRouter};
use crate::obs::{Event, Journal, SpanEvent, SwapEvent};
use crate::runtime::{Engine, Manifest, SyntheticSpec};
use crate::util::sync::locked;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Which engine each shard loads.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// Compiled artifacts from `artifacts_dir` (PJRT under the `pjrt`
    /// feature, the behavioural executor otherwise).
    Artifacts,
    /// Manifest-free synthetic artifacts (hermetic tests / benchmarks).
    Synthetic(SyntheticSpec),
}

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Artifacts to compile at startup (empty = all model artifacts).
    pub artifacts: Vec<String>,
    /// Maximum micro-batch drained per engine pass.
    pub batch_max: usize,
    /// Engine shard count; 0 = one per CPU core, capped at 4.
    pub shards: usize,
    /// Per-shard queue bound; admission control rejects beyond this.
    pub queue_cap: usize,
    /// How long a worker waits for a micro-batch to fill once the first
    /// request arrives.  Zero = drain whatever is already queued.
    pub batch_window: Duration,
    /// How requests map to shards.
    pub shard_policy: ShardPolicy,
    pub engine: EngineSpec,
    /// When set, every request lifecycle stage and swap phase is
    /// recorded as a structured event (`--obs-log`).  `None` keeps the
    /// hot path allocation- and lock-free.
    pub journal: Option<Arc<Journal>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::artifacts_dir(),
            artifacts: vec![],
            batch_max: 16,
            shards: 0,
            queue_cap: 256,
            batch_window: Duration::ZERO,
            shard_policy: ShardPolicy::Affinity,
            engine: EngineSpec::Artifacts,
            journal: None,
        }
    }
}

fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// The engine one shard loads: its artifact group, resolved at startup.
enum ShardEngine {
    Artifacts { names: Vec<String> },
    Synthetic(SyntheticSpec),
}

/// Resolve the per-shard artifact groups.  Under `Affinity` each shard
/// loads only the artifacts that hash home to it (no request for another
/// artifact can ever reach it); `LeastLoaded` and `RoundRobin` can route
/// any artifact anywhere, so every shard loads the full set.
fn shard_engines(config: &CoordinatorConfig, router: &ShardRouter) -> Result<Vec<ShardEngine>> {
    let n = router.shards();
    match &config.engine {
        EngineSpec::Synthetic(spec) => Ok((0..n)
            .map(|shard| {
                let artifacts = if config.shard_policy == ShardPolicy::Affinity {
                    spec.artifacts
                        .iter()
                        .filter(|a| router.home(&a.name) == shard)
                        .cloned()
                        .collect()
                } else {
                    spec.artifacts.clone()
                };
                ShardEngine::Synthetic(SyntheticSpec { artifacts })
            })
            .collect()),
        EngineSpec::Artifacts => {
            let names: Vec<String> = if config.artifacts.is_empty() {
                Manifest::load(&config.artifacts_dir)
                    .map_err(|e| anyhow!("engine startup failed: {e:#}"))?
                    .models()
                    .map(|a| a.name.clone())
                    .collect()
            } else {
                config.artifacts.clone()
            };
            Ok((0..n)
                .map(|shard| {
                    let names = if config.shard_policy == ShardPolicy::Affinity {
                        names
                            .iter()
                            .filter(|name| router.home(name.as_str()) == shard)
                            .cloned()
                            .collect()
                    } else {
                        names.clone()
                    };
                    ShardEngine::Artifacts { names }
                })
                .collect())
        }
    }
}

/// What travels through a shard's queue.  Swaps ride the same FIFO as
/// requests, so the queue order *is* the drain barrier.
enum ShardMsg {
    Req(Request),
    Swap(SwapMsg),
}

struct SwapMsg {
    engine: ShardEngine,
    /// Worker confirms (or refuses, keeping its old engine) here.
    ack: Sender<std::result::Result<(), String>>,
}

struct Shard {
    /// `None` once draining for shutdown: the worker exits after serving
    /// the backlog.
    tx: Mutex<Option<SyncSender<ShardMsg>>>,
    depth: Arc<AtomicIsize>,
    /// Set while an engine swap is in flight on this shard; submissions
    /// bounce with [`SubmitError::Draining`] instead of queuing behind
    /// the swap.
    draining: AtomicBool,
}

/// Metadata describing a swap for the metrics switch-event log.
#[derive(Debug, Clone, Default)]
pub struct SwitchInfo {
    /// Human-readable description of the outgoing deployment.
    pub from: String,
    /// Human-readable description of the incoming deployment.
    pub to: String,
    /// Modeled energy/item before and after, when known.
    pub before_mj: Option<f64>,
    pub after_mj: Option<f64>,
    /// Drift score that triggered the reconfiguration.
    pub drift: Option<f64>,
}

impl SwitchInfo {
    pub fn new(from: impl Into<String>, to: impl Into<String>) -> SwitchInfo {
        SwitchInfo {
            from: from.into(),
            to: to.into(),
            ..SwitchInfo::default()
        }
    }
}

/// Outcome of a [`Coordinator::swap_engines`] call.
#[derive(Debug)]
pub struct SwapReport {
    /// Shards that now run the new engine.
    pub swapped: usize,
    /// Shards whose new engine failed to build — they keep their old
    /// engine and continue serving (the abort edge of the state machine).
    pub failed: Vec<(usize, String)>,
    /// Requests bounced during this swap's drain windows.
    pub drain_rejected: u64,
}

impl SwapReport {
    pub fn all_swapped(&self) -> bool {
        self.failed.is_empty()
    }
}

/// Client handle; shareable across request-producer threads.
pub struct Coordinator {
    shards: Vec<Shard>,
    router: ShardRouter,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    draining: AtomicBool,
    queue_cap: usize,
    config: Arc<CoordinatorConfig>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Serialises engine swaps (concurrent swaps would interleave drain
    /// windows unpredictably).
    swap_lock: Mutex<()>,
}

impl Coordinator {
    /// Start the shard workers.  `start` waits for every shard's engine
    /// to load so callers get artifact errors eagerly.
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let n = if config.shards == 0 {
            default_shards()
        } else {
            config.shards
        };
        let queue_cap = config.queue_cap.max(1);
        let metrics = Arc::new(Metrics::default());
        let config = Arc::new(CoordinatorConfig {
            batch_max: config.batch_max.max(1),
            ..config
        });
        let router = ShardRouter::new(config.shard_policy, n);
        let engines = shard_engines(&config, &router)?;

        let mut shards = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut readies = Vec::with_capacity(n);
        for (shard_id, engine) in engines.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<ShardMsg>(queue_cap);
            let depth = Arc::new(AtomicIsize::new(0));
            let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
            let worker = std::thread::Builder::new()
                .name(format!("elastic-shard-{shard_id}"))
                .spawn({
                    let config = config.clone();
                    let depth = depth.clone();
                    let metrics = metrics.clone();
                    move || worker_loop(shard_id, &config, engine, rx, depth, metrics, ready_tx)
                })
                .map_err(|e| anyhow!("spawning shard {shard_id} worker thread: {e}"))?;
            shards.push(Shard {
                tx: Mutex::new(Some(tx)),
                depth,
                draining: AtomicBool::new(false),
            });
            workers.push(worker);
            readies.push(ready_rx);
        }

        let coordinator = Coordinator {
            router,
            metrics: metrics.clone(),
            next_id: AtomicU64::new(1),
            draining: AtomicBool::new(false),
            queue_cap,
            config,
            shards,
            workers: Mutex::new(workers),
            swap_lock: Mutex::new(()),
        };
        for (shard_id, ready) in readies.into_iter().enumerate() {
            let outcome = match ready.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(anyhow!("shard {shard_id} engine startup failed: {e}")),
                Err(_) => Err(anyhow!("shard {shard_id} engine thread died during startup")),
            };
            if let Err(e) = outcome {
                coordinator.shutdown();
                return Err(e);
            }
        }
        metrics.init_shards(coordinator.shards.iter().map(|s| s.depth.clone()).collect());
        Ok(coordinator)
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The configuration the coordinator was started with.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    /// Submit a request, waiting for queue space if the target shard is
    /// at capacity; returns the receiver for its response.
    pub fn submit(
        &self,
        artifact: &str,
        input: Vec<f32>,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        self.enqueue(artifact, input, true)
    }

    /// Submit without blocking: a full shard queue rejects with
    /// [`SubmitError::QueueFull`] (admission control for bursty load).
    pub fn try_submit(
        &self,
        artifact: &str,
        input: Vec<f32>,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        self.enqueue(artifact, input, false)
    }

    /// Emit one request-lifecycle span when a journal is attached.
    /// Terminal rejects carry id 0: the request never earned an id.
    fn span(&self, id: u64, stage: &str, artifact: &str, shard: Option<usize>) {
        if let Some(j) = &self.config.journal {
            let mut s = SpanEvent::new(id, stage, artifact);
            s.shard = shard;
            j.record(Event::Span(s));
        }
    }

    /// Emit one swap-phase event when a journal is attached.
    fn swap_event(
        &self,
        to: &str,
        phase: &str,
        shard: Option<usize>,
        drain_rejected: Option<u64>,
        detail: Option<String>,
    ) {
        if let Some(j) = &self.config.journal {
            let mut e = SwapEvent::new(phase, to);
            e.shard = shard;
            e.drain_rejected = drain_rejected;
            e.detail = detail;
            j.record(Event::Swap(e));
        }
    }

    fn enqueue(
        &self,
        artifact: &str,
        input: Vec<f32>,
        blocking: bool,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        if self.draining.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        // observe the offered load (rejected requests are still arrivals —
        // the fitter models the arrival process, not the service process)
        self.metrics.record_arrival(artifact);
        // gather queue depths only for depth-aware policies; the default
        // affinity path stays allocation-free
        let depths: Vec<usize> = if self.router.needs_depths() {
            self.shards
                .iter()
                .map(|s| s.depth.load(Ordering::Relaxed).max(0) as usize)
                .collect()
        } else {
            Vec::new()
        };
        let shard = self.router.pick(artifact, &depths);
        // the router's pick is always in range, but go through `get` so a
        // future router bug surfaces as a rejection, not a panic mid-serve
        let Some(target) = self.shards.get(shard) else {
            return Err(SubmitError::ShuttingDown);
        };
        if target.draining.load(Ordering::SeqCst) {
            self.metrics.record_drain_reject(shard);
            self.span(0, "drain-reject", artifact, Some(shard));
            return Err(SubmitError::Draining { shard });
        }
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            artifact: artifact.to_string(),
            input,
            enqueued: Instant::now(),
            reply,
        };
        // clone the sender out of the lock: a blocking send must not hold
        // the mutex, or it would stall shutdown and sibling producers
        let tx = match locked(&target.tx).as_ref() {
            Some(tx) => tx.clone(),
            None => return Err(SubmitError::ShuttingDown),
        };
        if blocking {
            // count the waiting producer as queue pressure
            target.depth.fetch_add(1, Ordering::Relaxed);
            if tx.send(ShardMsg::Req(req)).is_err() {
                target.depth.fetch_sub(1, Ordering::Relaxed);
                return Err(SubmitError::ShuttingDown);
            }
            self.metrics.record_submit(shard);
            // spans start at admission: a request that bounced never
            // earned an id, so chains stay complete for every accepted id
            self.span(id, "submit", artifact, Some(shard));
            self.span(id, "enqueue", artifact, Some(shard));
        } else {
            match tx.try_send(ShardMsg::Req(req)) {
                Ok(()) => {
                    target.depth.fetch_add(1, Ordering::Relaxed);
                    self.metrics.record_submit(shard);
                    self.span(id, "submit", artifact, Some(shard));
                    self.span(id, "enqueue", artifact, Some(shard));
                }
                Err(TrySendError::Full(_)) => {
                    self.metrics.record_reject(shard);
                    self.span(0, "reject", artifact, Some(shard));
                    return Err(SubmitError::QueueFull {
                        shard,
                        capacity: self.queue_cap,
                    });
                }
                Err(TrySendError::Disconnected(_)) => return Err(SubmitError::ShuttingDown),
            }
        }
        Ok(rx)
    }

    /// Submit and wait.
    ///
    /// Perf note: spin-before-park variants of this path and of the
    /// worker's dequeue were tried and *regressed* the round-trip 7x on
    /// this host — the spinners steal cycles from the engine threads.
    /// Plain blocking channels are the optimum here.
    pub fn infer(&self, artifact: &str, input: Vec<f32>) -> Result<Response> {
        self.submit(artifact, input)?
            .recv()
            .map_err(|_| anyhow!("engine shard died before replying"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain-and-switch: replace every shard's engine with `engine`
    /// without restarting the coordinator.
    ///
    /// Per shard, in order: mark the shard draining (new submissions
    /// bounce with [`SubmitError::Draining`]), send the swap in-band
    /// through the bounded queue (FIFO: the worker serves its whole
    /// admitted backlog first), wait for the worker's ack, resume
    /// admission.  Shards whose replacement engine fails to build keep
    /// their old engine and keep serving — this is the abort edge, and no
    /// switch event is recorded for a partial swap.
    ///
    /// Returns an error without touching any shard when the new spec
    /// cannot be resolved at all or the coordinator is shutting down.
    pub fn swap_engines(&self, engine: EngineSpec, info: SwitchInfo) -> Result<SwapReport> {
        let _guard = locked(&self.swap_lock);
        if self.draining.load(Ordering::SeqCst) {
            return Err(anyhow!("coordinator is shutting down"));
        }
        // resolve the per-shard engine groups eagerly: an unresolvable
        // spec must fail before any shard begins draining
        let mut config = (*self.config).clone();
        config.engine = engine;
        let engines = shard_engines(&config, &self.router)?;

        let drain_before = self.metrics.snapshot().total_drain_rejected();
        let mut failed = Vec::new();
        for (shard_id, (shard, shard_engine)) in self.shards.iter().zip(engines).enumerate() {
            shard.draining.store(true, Ordering::SeqCst);
            self.swap_event(&info.to, "drain-start", Some(shard_id), None, None);
            let tx = match locked(&shard.tx).as_ref() {
                Some(tx) => tx.clone(),
                None => {
                    shard.draining.store(false, Ordering::SeqCst);
                    let why = "shard is shutting down".to_string();
                    self.swap_event(&info.to, "aborted", Some(shard_id), None, Some(why.clone()));
                    failed.push((shard_id, why));
                    continue;
                }
            };
            let (ack_tx, ack_rx) = channel();
            let msg = ShardMsg::Swap(SwapMsg {
                engine: shard_engine,
                ack: ack_tx,
            });
            // lint: allow(lock-blocking) — the swap IS the drain barrier: holding
            // swap_lock across the shard hand-off is the serialization this fn exists
            // to provide, and submit/shutdown never take swap_lock
            if tx.send(msg).is_err() {
                let why = "shard queue disconnected".to_string();
                self.swap_event(&info.to, "aborted", Some(shard_id), None, Some(why.clone()));
                failed.push((shard_id, why));
            } else {
                // lint: allow(lock-blocking) — bounded wait: the ack arrives once the
                // in-flight batch drains, and a dead worker closes the channel, which
                // returns Err here instead of blocking forever
                match ack_rx.recv() {
                    Ok(Ok(())) => {
                        self.swap_event(&info.to, "engine-built", Some(shard_id), None, None);
                    }
                    Ok(Err(e)) => {
                        self.swap_event(&info.to, "aborted", Some(shard_id), None, Some(e.clone()));
                        failed.push((shard_id, e));
                    }
                    Err(_) => {
                        let why = "shard worker died during swap".to_string();
                        self.swap_event(&info.to, "aborted", Some(shard_id), None, Some(why.clone()));
                        failed.push((shard_id, why));
                    }
                }
            }
            shard.draining.store(false, Ordering::SeqCst);
        }

        let drain_rejected = self
            .metrics
            .snapshot()
            .total_drain_rejected()
            .saturating_sub(drain_before);
        let report = SwapReport {
            swapped: self.shards.len() - failed.len(),
            failed,
            drain_rejected,
        };
        if report.all_swapped() {
            self.swap_event(&info.to, "committed", None, Some(drain_rejected), None);
            self.metrics.record_switch(SwitchEvent {
                at_s: 0.0,
                from: info.from,
                to: info.to,
                before_mj: info.before_mj,
                after_mj: info.after_mj,
                drift: info.drift,
                drain_rejected,
            });
        }
        Ok(report)
    }

    /// Stop admitting work, drain every shard queue, and join the
    /// workers.  Every already-admitted request still receives its
    /// response (the bounded channels deliver their backlog before
    /// disconnecting).  Idempotent.
    pub fn shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            locked(&shard.tx).take();
        }
        let workers = std::mem::take(&mut *locked(&self.workers));
        for handle in workers {
            let _ = handle.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn build_engine(config: &CoordinatorConfig, engine: ShardEngine) -> Result<Engine> {
    match engine {
        ShardEngine::Artifacts { names } => {
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            Engine::load_exact(&config.artifacts_dir, &refs)
        }
        ShardEngine::Synthetic(spec) => Ok(Engine::synthetic(spec)),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shard_id: usize,
    config: &CoordinatorConfig,
    shard_engine: ShardEngine,
    rx: Receiver<ShardMsg>,
    depth: Arc<AtomicIsize>,
    metrics: Arc<Metrics>,
    ready: std::sync::mpsc::Sender<std::result::Result<(), String>>,
) {
    let mut engine = match build_engine(config, shard_engine) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };

    loop {
        // block for the first message, then gather a micro-batch; a swap
        // closes the batch early so it applies at a batch boundary
        let mut batch: Vec<Request> = Vec::new();
        let mut pending_swap: Option<SwapMsg> = None;
        match rx.recv() {
            Ok(ShardMsg::Req(r)) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                batch.push(r);
            }
            Ok(ShardMsg::Swap(s)) => pending_swap = Some(s),
            Err(_) => return, // queue drained + all handles dropped
        }
        if pending_swap.is_none() {
            if config.batch_window.is_zero() {
                while batch.len() < config.batch_max {
                    match rx.try_recv() {
                        Ok(ShardMsg::Req(r)) => {
                            depth.fetch_sub(1, Ordering::Relaxed);
                            batch.push(r);
                        }
                        Ok(ShardMsg::Swap(s)) => {
                            pending_swap = Some(s);
                            break;
                        }
                        Err(_) => break,
                    }
                }
            } else {
                let deadline = Instant::now() + config.batch_window;
                while batch.len() < config.batch_max {
                    let now = Instant::now();
                    let Some(remaining) = deadline.checked_duration_since(now) else {
                        break;
                    };
                    match rx.recv_timeout(remaining) {
                        Ok(ShardMsg::Req(r)) => {
                            depth.fetch_sub(1, Ordering::Relaxed);
                            batch.push(r);
                        }
                        Ok(ShardMsg::Swap(s)) => {
                            pending_swap = Some(s);
                            break;
                        }
                        Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                            break
                        }
                    }
                }
            }
        }
        let batch_len = batch.len();
        if !batch.is_empty() {
            metrics.record_batch(shard_id, batch_len, config.batch_max);
        }

        for req in batch {
            let picked_up = Instant::now();
            let queue_wait = picked_up.duration_since(req.enqueued).as_secs_f64();
            if let Some(j) = &config.journal {
                let mut s = SpanEvent::new(req.id, "exec", &req.artifact);
                s.shard = Some(shard_id);
                s.queue_wait_s = Some(queue_wait);
                s.batch = Some(batch_len);
                j.record(Event::Span(s));
            }
            let result = engine.infer(&req.artifact, &req.input);
            let exec = picked_up.elapsed().as_secs_f64();
            let ok = result.is_ok();
            if let Some(j) = &config.journal {
                let mut s = SpanEvent::new(req.id, "done", &req.artifact);
                s.shard = Some(shard_id);
                s.exec_s = Some(exec);
                s.ok = Some(ok);
                j.record(Event::Span(s));
            }
            metrics.record_shard(shard_id, &req.artifact, ok, queue_wait, exec);
            let _ = req.reply.send(Response {
                id: req.id,
                artifact: req.artifact,
                shard: shard_id,
                output: result.map_err(|e| e.to_string()),
                queue_wait_s: queue_wait,
                exec_s: exec,
            });
        }

        if let Some(swap) = pending_swap {
            // the backlog admitted before the swap has been served above
            // (FIFO order) — safe to replace the engine now
            match build_engine(config, swap.engine) {
                Ok(e) => {
                    engine = e;
                    let _ = swap.ack.send(Ok(()));
                }
                Err(e) => {
                    // keep the old engine and keep serving
                    let _ = swap.ack.send(Err(format!("{e:#}")));
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    fn synthetic_config(shards: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            shards,
            engine: EngineSpec::Synthetic(SyntheticSpec::uniform(4, 8, 2, 50)),
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn synthetic_round_trip() {
        let coord = Coordinator::start(synthetic_config(2)).unwrap();
        assert_eq!(coord.shard_count(), 2);
        let resp = coord.infer("syn.0", vec![0.5; 8]).unwrap();
        assert!(resp.is_ok());
        assert!(resp.shard < 2);
        assert!(resp.total_s() >= 0.0);
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.total_served(), 1);
        // the submit path feeds the arrival-trace ring
        assert_eq!(coord.metrics().arrival_trace("syn.0").len(), 1);
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let coord = Coordinator::start(synthetic_config(1)).unwrap();
        coord.shutdown();
        assert_eq!(
            coord.submit("syn.0", vec![0.0; 8]).unwrap_err(),
            SubmitError::ShuttingDown
        );
        coord.shutdown(); // idempotent
    }

    #[test]
    fn startup_failure_reports_shard() {
        let cfg = CoordinatorConfig {
            artifacts_dir: PathBuf::from("/definitely/missing"),
            shards: 2,
            ..CoordinatorConfig::default()
        };
        let err = Coordinator::start(cfg).unwrap_err().to_string();
        assert!(err.contains("startup failed"), "{err}");
    }

    #[test]
    fn swap_engines_mid_stream() {
        let coord = Coordinator::start(synthetic_config(2)).unwrap();
        assert!(coord.infer("syn.0", vec![0.5; 8]).unwrap().is_ok());

        let report = coord
            .swap_engines(
                EngineSpec::Synthetic(SyntheticSpec::uniform(4, 8, 2, 100)),
                SwitchInfo::new("old", "new"),
            )
            .unwrap();
        assert!(report.all_swapped(), "{:?}", report.failed);
        assert_eq!(report.swapped, 2);

        // serving continues on the new engine
        assert!(coord.infer("syn.0", vec![0.5; 8]).unwrap().is_ok());
        let events = coord.metrics().switch_events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].from, "old");
        assert_eq!(events[0].to, "new");
    }

    #[test]
    fn failed_swap_keeps_old_engine_and_records_no_switch() {
        // artifacts_dir doesn't exist, but the artifact list is explicit,
        // so resolution succeeds and the failure surfaces in the worker's
        // engine build — the abort edge
        let coord = Coordinator::start(CoordinatorConfig {
            shards: 1,
            artifacts_dir: PathBuf::from("/definitely/missing"),
            artifacts: vec!["ghost.a".to_string()],
            engine: EngineSpec::Synthetic(SyntheticSpec::uniform(4, 8, 2, 50)),
            ..CoordinatorConfig::default()
        })
        .unwrap();
        assert!(coord.infer("syn.0", vec![0.5; 8]).unwrap().is_ok());

        let report = coord
            .swap_engines(EngineSpec::Artifacts, SwitchInfo::new("old", "broken"))
            .unwrap();
        assert_eq!(report.swapped, 0);
        assert_eq!(report.failed.len(), 1);

        // old engine still serves; no switch event recorded
        assert!(coord.infer("syn.0", vec![0.5; 8]).unwrap().is_ok());
        assert!(coord.metrics().switch_events().is_empty());
    }
}
