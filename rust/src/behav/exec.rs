//! Bit-true behavioural execution of generated accelerators — the GHDL
//! substitute of §2.3.
//!
//! Every function mirrors the corresponding Pallas kernel
//! (`python/compile/kernels/*.py`) operation-for-operation on the shared
//! fixed-point contract.  For pure-integer activation variants the outputs
//! equal the compiled HLO bit-for-bit; Exact/softmax paths agree within
//! 1 LSB (f32 vs f64 transcendentals) — the cross-check tolerance the
//! integration tests apply.

use super::weights::{AttnWeights, CnnWeights, LstmWeights, MlpWeights, ModelWeights, Tensor2};
use crate::models::{self, Topology};
use crate::rtl::activation::ActVariant;
use crate::rtl::fixed_point::{sra_round, QFormat};

/// Activation configuration of a generated accelerator.
#[derive(Debug, Clone, Copy)]
pub struct ExecConfig {
    pub fmt: QFormat,
    /// Variant applied by FC/conv hidden layers and LSTM sigmoid gates.
    pub act: ActVariant,
    /// Variant for LSTM/conv tanh positions.
    pub tanh: ActVariant,
}

/// Typed execution failure: malformed artifacts surface as errors the
/// serving loop can answer per-request instead of crashing on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecError {
    /// Input vector length does not match the topology.
    InputLen { expected: usize, got: usize },
    /// The weight bundle does not belong to the requested topology.
    WeightsTopologyMismatch {
        topology: &'static str,
        weights: &'static str,
    },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::InputLen { expected, got } => {
                write!(f, "input length {got} != expected {expected}")
            }
            ExecError::WeightsTopologyMismatch { topology, weights } => {
                write!(f, "weights/topology mismatch: {weights} weights for {topology} model")
            }
        }
    }
}

impl std::error::Error for ExecError {}

fn weights_kind(w: &ModelWeights) -> &'static str {
    match w {
        ModelWeights::Mlp(_) => "mlp",
        ModelWeights::Lstm(_) => "lstm",
        ModelWeights::Cnn(_) => "cnn",
        ModelWeights::Attn(_) => "attn",
    }
}

fn qmat(t: &Tensor2, fmt: QFormat) -> Vec<i64> {
    t.data.iter().map(|&x| fmt.quantize(x)).collect()
}

fn qvec(v: &[f64], fmt: QFormat) -> Vec<i64> {
    v.iter().map(|&x| fmt.quantize(x)).collect()
}

/// Fixed-point FC: y = sat(sra(x @ w + (b << f), f)), optional activation.
/// `w` is row-major [n_in x n_out].
pub fn fc_int(
    xq: &[i64],
    wq: &[i64],
    bq: &[i64],
    n_in: usize,
    n_out: usize,
    fmt: QFormat,
    act: Option<ActVariant>,
) -> Vec<i64> {
    debug_assert_eq!(xq.len(), n_in);
    debug_assert_eq!(wq.len(), n_in * n_out);
    debug_assert_eq!(bq.len(), n_out);
    let mut out = Vec::with_capacity(n_out);
    for j in 0..n_out {
        let mut acc: i64 = 0;
        for i in 0..n_in {
            acc += xq[i] * wq[i * n_out + j];
        }
        acc += bq[j] << fmt.frac_bits;
        let mut y = fmt.saturate(sra_round(acc, fmt.frac_bits));
        if let Some(a) = act {
            y = a.eval(y, fmt);
        }
        out.push(y);
    }
    out
}

/// LSTM cell step; gate order [i, f, g, o] along the fused axis.
#[allow(clippy::too_many_arguments)]
pub fn lstm_cell(
    xq: &[i64],
    hq: &[i64],
    cq: &[i64],
    wxq: &[i64],
    whq: &[i64],
    bq: &[i64],
    n_in: usize,
    n_h: usize,
    fmt: QFormat,
    sig: ActVariant,
    tan: ActVariant,
) -> (Vec<i64>, Vec<i64>) {
    let n4 = 4 * n_h;
    let mut z = vec![0i64; n4];
    for j in 0..n4 {
        let mut acc: i64 = 0;
        for i in 0..n_in {
            acc += xq[i] * wxq[i * n4 + j];
        }
        for i in 0..n_h {
            acc += hq[i] * whq[i * n4 + j];
        }
        acc += bq[j] << fmt.frac_bits;
        z[j] = fmt.saturate(sra_round(acc, fmt.frac_bits));
    }
    let mut h_new = vec![0i64; n_h];
    let mut c_new = vec![0i64; n_h];
    for k in 0..n_h {
        let i_g = sig.eval(z[k], fmt);
        let f_g = sig.eval(z[n_h + k], fmt);
        let g_g = tan.eval(z[2 * n_h + k], fmt);
        let o_g = sig.eval(z[3 * n_h + k], fmt);
        let c2 = fmt.saturate(
            sra_round(f_g * cq[k], fmt.frac_bits) + sra_round(i_g * g_g, fmt.frac_bits),
        );
        let h2 = fmt.saturate(sra_round(o_g * tan.eval(c2, fmt), fmt.frac_bits));
        c_new[k] = c2;
        h_new[k] = h2;
    }
    (h_new, c_new)
}

/// Valid-padding conv1d; `x` is [t x c_in] row-major, `k` is
/// [kw*c_in x c_out] row-major (flattened [kw, c_in, c_out]).
#[allow(clippy::too_many_arguments)]
pub fn conv1d(
    xq: &[i64],
    kq: &[i64],
    bq: &[i64],
    t_in: usize,
    c_in: usize,
    kw: usize,
    c_out: usize,
    stride: usize,
    fmt: QFormat,
    act: Option<ActVariant>,
) -> Vec<i64> {
    let t_out = (t_in - kw) / stride + 1;
    let mut out = vec![0i64; t_out * c_out];
    for to in 0..t_out {
        for co in 0..c_out {
            let mut acc: i64 = 0;
            for w in 0..kw {
                for ci in 0..c_in {
                    let x = xq[(to * stride + w) * c_in + ci];
                    let k = kq[(w * c_in + ci) * c_out + co];
                    acc += x * k;
                }
            }
            acc += bq[co] << fmt.frac_bits;
            let mut y = fmt.saturate(sra_round(acc, fmt.frac_bits));
            if let Some(a) = act {
                y = a.eval(y, fmt);
            }
            out[to * c_out + co] = y;
        }
    }
    out
}

/// Mean over time with round-half-up constant division
/// (mirrors `conv.global_avg_pool_int`: `(s + t//2) // t`, floor division).
pub fn global_avg_pool(xq: &[i64], t: usize, c: usize) -> Vec<i64> {
    let mut out = vec![0i64; c];
    for j in 0..c {
        let s: i64 = (0..t).map(|i| xq[i * c + j]).sum();
        out[j] = (s + (t as i64) / 2).div_euclid(t as i64);
    }
    out
}

/// Mixed fixed/float attention (mirrors kernels/attention.py).
pub fn attention(
    qq: &[i64],
    kq: &[i64],
    vq: &[i64],
    t: usize,
    d: usize,
    fmt: QFormat,
) -> Vec<i64> {
    // scores = sat(sra(q @ k^T, f))
    let mut scores = vec![0i64; t * t];
    for a in 0..t {
        for b in 0..t {
            let mut acc: i64 = 0;
            for i in 0..d {
                acc += qq[a * d + i] * kq[b * d + i];
            }
            scores[a * t + b] = fmt.saturate(sra_round(acc, fmt.frac_bits));
        }
    }
    // softmax rows at high precision, scaled by 1/sqrt(d), requantised
    let inv_sqrt_d = 1.0 / (d as f64).sqrt();
    let mut w = vec![0i64; t * t];
    for a in 0..t {
        let row: Vec<f64> = (0..t)
            .map(|b| fmt.dequantize(scores[a * t + b]) * inv_sqrt_d)
            .collect();
        let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = row.iter().map(|&x| (x - m).exp()).collect();
        let sum: f64 = exps.iter().sum();
        for b in 0..t {
            w[a * t + b] = fmt.quantize(exps[b] / sum);
        }
    }
    // out = sat(sra(w @ v, f))
    let mut out = vec![0i64; t * d];
    for a in 0..t {
        for j in 0..d {
            let mut acc: i64 = 0;
            for b in 0..t {
                acc += w[a * t + b] * vq[b * d + j];
            }
            out[a * d + j] = fmt.saturate(sra_round(acc, fmt.frac_bits));
        }
    }
    out
}

/// Projection without bias: sat(sra(x @ w, f)) per row.
fn proj(xq: &[i64], wq: &[i64], t: usize, d_in: usize, d_out: usize, fmt: QFormat) -> Vec<i64> {
    let mut out = vec![0i64; t * d_out];
    for r in 0..t {
        for j in 0..d_out {
            let mut acc: i64 = 0;
            for i in 0..d_in {
                acc += xq[r * d_in + i] * wq[i * d_out + j];
            }
            out[r * d_out + j] = fmt.saturate(sra_round(acc, fmt.frac_bits));
        }
    }
    out
}

/// Execute a full model on a flat f64 input; returns the dequantised flat
/// output.  Mirrors `model.build_from_config` exactly.  Malformed inputs
/// (wrong length, weights from another topology) come back as `ExecError`
/// so a bad artifact cannot crash the serving loop.
pub fn run_model(
    topology: Topology,
    weights: &ModelWeights,
    cfg: &ExecConfig,
    input: &[f64],
) -> Result<Vec<f64>, ExecError> {
    if input.len() != topology.input_len() {
        return Err(ExecError::InputLen {
            expected: topology.input_len(),
            got: input.len(),
        });
    }
    let fmt = cfg.fmt;
    let xq = qvec(input, fmt);
    let out_q = match (topology, weights) {
        (Topology::MlpFluid, ModelWeights::Mlp(w)) => run_mlp(w, cfg, xq),
        (Topology::LstmHar, ModelWeights::Lstm(w)) => run_lstm(w, cfg, xq),
        (Topology::CnnEcg, ModelWeights::Cnn(w)) => run_cnn(w, cfg, xq),
        (Topology::AttnTiny, ModelWeights::Attn(w)) => run_attn(w, cfg, xq),
        _ => {
            return Err(ExecError::WeightsTopologyMismatch {
                topology: topology.name(),
                weights: weights_kind(weights),
            })
        }
    };
    Ok(out_q.iter().map(|&q| fmt.dequantize(q)).collect())
}

fn run_mlp(w: &MlpWeights, cfg: &ExecConfig, mut xq: Vec<i64>) -> Vec<i64> {
    let n = w.layers.len();
    for (i, (wt, b)) in w.layers.iter().enumerate() {
        let act = if i + 1 < n { Some(cfg.act) } else { None };
        xq = fc_int(
            &xq,
            &qmat(wt, cfg.fmt),
            &qvec(b, cfg.fmt),
            wt.rows,
            wt.cols,
            cfg.fmt,
            act,
        );
    }
    xq
}

fn run_lstm(w: &LstmWeights, cfg: &ExecConfig, xq: Vec<i64>) -> Vec<i64> {
    let (t, n_in, n_h) = (
        models::LSTM_T as usize,
        models::LSTM_IN as usize,
        models::LSTM_H as usize,
    );
    let wxq = qmat(&w.wx, cfg.fmt);
    let whq = qmat(&w.wh, cfg.fmt);
    let bq = qvec(&w.b, cfg.fmt);
    let mut h = vec![0i64; n_h];
    let mut c = vec![0i64; n_h];
    for step in 0..t {
        let x = &xq[step * n_in..(step + 1) * n_in];
        let (h2, c2) = lstm_cell(x, &h, &c, &wxq, &whq, &bq, n_in, n_h, cfg.fmt, cfg.act, cfg.tanh);
        h = h2;
        c = c2;
    }
    fc_int(
        &h,
        &qmat(&w.w_head, cfg.fmt),
        &qvec(&w.b_head, cfg.fmt),
        n_h,
        models::LSTM_CLASSES as usize,
        cfg.fmt,
        None,
    )
}

fn run_cnn(w: &CnnWeights, cfg: &ExecConfig, mut xq: Vec<i64>) -> Vec<i64> {
    let mut t = models::CNN_T as usize;
    for (spec, (k, b)) in models::CNN_SPEC.iter().zip(&w.convs) {
        let (c_in, c_out, kw, stride) =
            (spec.0 as usize, spec.1 as usize, spec.2 as usize, spec.3 as usize);
        // conv layers apply the primary activation variant (python's
        // build_cnn passes (cfg.act, cfg.act_impl) to every conv)
        xq = conv1d(
            &xq,
            &qmat(k, cfg.fmt),
            &qvec(b, cfg.fmt),
            t,
            c_in,
            kw,
            c_out,
            stride,
            cfg.fmt,
            Some(cfg.act),
        );
        t = (t - kw) / stride + 1;
    }
    let c_last = models::CNN_SPEC.last().unwrap().1 as usize;
    let pooled = global_avg_pool(&xq, t, c_last);
    fc_int(
        &pooled,
        &qmat(&w.w_head, cfg.fmt),
        &qvec(&w.b_head, cfg.fmt),
        c_last,
        models::CNN_CLASSES as usize,
        cfg.fmt,
        None,
    )
}

fn run_attn(w: &AttnWeights, cfg: &ExecConfig, xq: Vec<i64>) -> Vec<i64> {
    let (t, d) = (models::ATTN_T as usize, models::ATTN_D as usize);
    let q = proj(&xq, &qmat(&w.wq, cfg.fmt), t, d, d, cfg.fmt);
    let k = proj(&xq, &qmat(&w.wk, cfg.fmt), t, d, d, cfg.fmt);
    let v = proj(&xq, &qmat(&w.wv, cfg.fmt), t, d, d, cfg.fmt);
    let o = attention(&q, &k, &v, t, d, cfg.fmt);
    let pooled = global_avg_pool(&o, t, d);
    fc_int(
        &pooled,
        &qmat(&w.w_head, cfg.fmt),
        &qvec(&w.b_head, cfg.fmt),
        d,
        models::ATTN_CLASSES as usize,
        cfg.fmt,
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::activation::{ActImpl, ActKind};
    use crate::rtl::fixed_point::Q16_8;

    const F: QFormat = Q16_8;

    fn hard_cfg() -> ExecConfig {
        ExecConfig {
            fmt: F,
            act: ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard),
            tanh: ActVariant::new(ActKind::HardTanh, ActImpl::Hard),
        }
    }

    #[test]
    fn fc_identity() {
        // identity weights, zero bias
        let n = 4;
        let mut w = vec![0i64; n * n];
        for i in 0..n {
            w[i * n + i] = F.scale();
        }
        let x = vec![100, -50, 3, 0];
        let y = fc_int(&x, &w, &vec![0; n], n, n, F, None);
        assert_eq!(y, x);
    }

    #[test]
    fn fc_bias_only() {
        let x = vec![0i64; 3];
        let w = vec![0i64; 6];
        let b = vec![10, -20];
        assert_eq!(fc_int(&x, &w, &b, 3, 2, F, None), vec![10, -20]);
    }

    #[test]
    fn fc_saturates() {
        let n = 8;
        let x = vec![F.qmax(); n];
        let w = vec![F.scale(); n];
        let y = fc_int(&x, &w, &[0], n, 1, F, None);
        assert_eq!(y[0], F.qmax());
    }

    #[test]
    fn lstm_state_bounded() {
        let (n_in, n_h) = (3, 5);
        let wx = vec![F.scale() / 4; n_in * 4 * n_h];
        let wh = vec![-F.scale() / 8; n_h * 4 * n_h];
        let b = vec![0i64; 4 * n_h];
        let mut h = vec![0i64; n_h];
        let mut c = vec![0i64; n_h];
        for _ in 0..50 {
            let (h2, c2) = lstm_cell(
                &[F.scale(), -F.scale(), F.scale() / 2],
                &h,
                &c,
                &wx,
                &wh,
                &b,
                n_in,
                n_h,
                F,
                ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard),
                ActVariant::new(ActKind::HardTanh, ActImpl::Hard),
            );
            h = h2;
            c = c2;
        }
        assert!(h.iter().all(|&v| v.abs() <= F.scale()));
    }

    #[test]
    fn gap_floor_div_matches_python() {
        // python: (s + t//2) // t with floor semantics on negatives.
        // rows interleave as [c0, c1]: col0 = [-3,-3,-3], col1 = [1,1,1]
        let x = vec![-3, 1, -3, 1, -3, 1];
        let y = global_avg_pool(&x, 3, 2);
        // col0: s=-9, (-9+1)//3 = floor(-8/3) = -3 ; col1: s=3, (3+1)//3 = 1
        assert_eq!(y, vec![-3, 1]);
    }

    #[test]
    fn attention_uniform_keys() {
        let (t, d) = (4, 4);
        let q: Vec<i64> = (0..t * d).map(|i| (i as i64 % 7) * 10).collect();
        let k = vec![0i64; t * d];
        let v: Vec<i64> = (0..t * d).map(|i| i as i64 * 8).collect();
        let o = attention(&q, &k, &v, t, d, F);
        // uniform attention -> each row ~ column means of v
        for j in 0..d {
            let mean: i64 = (0..t).map(|r| v[r * d + j]).sum::<i64>() / t as i64;
            assert!((o[j] - mean).abs() <= 3, "col {j}: {} vs {}", o[j], mean);
        }
    }

    #[test]
    fn run_model_checks_input_len() {
        let w = ModelWeights::Mlp(super::super::weights::MlpWeights { layers: vec![] });
        let r = run_model(Topology::MlpFluid, &w, &hard_cfg(), &[0.0]);
        assert_eq!(r, Err(ExecError::InputLen { expected: 8, got: 1 }));
    }

    #[test]
    fn run_model_rejects_mismatched_weights() {
        // MLP weights presented as an LSTM artifact: an error, not a panic
        let w = ModelWeights::Mlp(super::super::weights::MlpWeights { layers: vec![] });
        let input = vec![0.0; Topology::LstmHar.input_len()];
        let r = run_model(Topology::LstmHar, &w, &hard_cfg(), &input);
        assert_eq!(
            r,
            Err(ExecError::WeightsTopologyMismatch {
                topology: "lstm_har",
                weights: "mlp",
            })
        );
        assert!(r.unwrap_err().to_string().contains("mismatch"));
    }
}
