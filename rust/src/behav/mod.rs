//! Behavioural simulation (GHDL substitute, §2.3): bit-true fixed-point
//! execution of generated accelerators against the exported weights, used
//! to (a) verify mathematical correctness against the compiled HLO and the
//! golden vectors, and (b) provide the cycle-count ground truth via the
//! RTL templates.

pub mod exec;
pub mod weights;

pub use exec::{run_model, ExecConfig, ExecError};
pub use weights::{load, ModelWeights};
