//! Loading the float64 weight export written by `python/compile/aot.py`
//! (`artifacts/weights/<model>.json`).  The behavioural simulator
//! quantises these with the shared round-half-up rule, giving the exact
//! int constants baked into the compiled HLO.

use crate::util::json::{parse_file, Json};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A 2-D tensor in row-major order.
#[derive(Debug, Clone)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Tensor2 {
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }
}

fn tensor2(j: &Json) -> Result<Tensor2> {
    let shape = j
        .get("shape")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("tensor missing shape"))?;
    let data = j
        .get("data")
        .map(|d| d.to_f64_vec())
        .ok_or_else(|| anyhow!("tensor missing data"))?;
    let dims: Vec<usize> = shape.iter().filter_map(|d| d.as_usize()).collect();
    let (rows, cols) = match dims.len() {
        1 => (1, dims[0]),
        2 => (dims[0], dims[1]),
        3 => (dims[0] * dims[1], dims[2]), // conv kernels [kw, c_in, c_out]
        n => return Err(anyhow!("unsupported tensor rank {n}")),
    };
    if rows * cols != data.len() {
        return Err(anyhow!("shape/data mismatch: {rows}x{cols} vs {}", data.len()));
    }
    Ok(Tensor2 { rows, cols, data })
}

fn vec1(j: &Json) -> Result<Vec<f64>> {
    Ok(tensor2(j)?.data)
}

/// MLP weights: per-layer (w [n_in x n_out], b [n_out]).
#[derive(Debug, Clone)]
pub struct MlpWeights {
    pub layers: Vec<(Tensor2, Vec<f64>)>,
}

/// LSTM weights (gate order [i|f|g|o] along columns).
#[derive(Debug, Clone)]
pub struct LstmWeights {
    pub wx: Tensor2,
    pub wh: Tensor2,
    pub b: Vec<f64>,
    pub w_head: Tensor2,
    pub b_head: Vec<f64>,
}

/// CNN weights: per-conv (k [kw*c_in x c_out], b [c_out]) + head.
#[derive(Debug, Clone)]
pub struct CnnWeights {
    pub convs: Vec<(Tensor2, Vec<f64>)>,
    pub w_head: Tensor2,
    pub b_head: Vec<f64>,
}

/// Attention-block weights.
#[derive(Debug, Clone)]
pub struct AttnWeights {
    pub wq: Tensor2,
    pub wk: Tensor2,
    pub wv: Tensor2,
    pub w_head: Tensor2,
    pub b_head: Vec<f64>,
}

#[derive(Debug, Clone)]
pub enum ModelWeights {
    Mlp(MlpWeights),
    Lstm(LstmWeights),
    Cnn(CnnWeights),
    Attn(AttnWeights),
}

/// Load `artifacts/weights/<model>.json`.
pub fn load(artifacts_dir: &Path, model: &str) -> Result<ModelWeights> {
    let path = artifacts_dir.join("weights").join(format!("{model}.json"));
    let j = parse_file(&path).with_context(|| format!("loading weights for {model}"))?;
    match model {
        "mlp_fluid" => {
            let arr = j.as_arr().ok_or_else(|| anyhow!("mlp weights not a list"))?;
            let mut layers = Vec::new();
            for l in arr {
                layers.push((
                    tensor2(l.get("w").ok_or_else(|| anyhow!("missing w"))?)?,
                    vec1(l.get("b").ok_or_else(|| anyhow!("missing b"))?)?,
                ));
            }
            Ok(ModelWeights::Mlp(MlpWeights { layers }))
        }
        "lstm_har" => Ok(ModelWeights::Lstm(LstmWeights {
            wx: tensor2(j.get("wx").ok_or_else(|| anyhow!("missing wx"))?)?,
            wh: tensor2(j.get("wh").ok_or_else(|| anyhow!("missing wh"))?)?,
            b: vec1(j.get("b").ok_or_else(|| anyhow!("missing b"))?)?,
            w_head: tensor2(j.get("w_head").ok_or_else(|| anyhow!("missing w_head"))?)?,
            b_head: vec1(j.get("b_head").ok_or_else(|| anyhow!("missing b_head"))?)?,
        })),
        "cnn_ecg" => {
            let convs_j = j
                .get("convs")
                .and_then(|c| c.as_arr())
                .ok_or_else(|| anyhow!("missing convs"))?;
            let mut convs = Vec::new();
            for c in convs_j {
                convs.push((
                    tensor2(c.get("k").ok_or_else(|| anyhow!("missing k"))?)?,
                    vec1(c.get("b").ok_or_else(|| anyhow!("missing b"))?)?,
                ));
            }
            Ok(ModelWeights::Cnn(CnnWeights {
                convs,
                w_head: tensor2(j.get("w_head").ok_or_else(|| anyhow!("missing w_head"))?)?,
                b_head: vec1(j.get("b_head").ok_or_else(|| anyhow!("missing b_head"))?)?,
            }))
        }
        "attn_tiny" => Ok(ModelWeights::Attn(AttnWeights {
            wq: tensor2(j.get("wq").ok_or_else(|| anyhow!("missing wq"))?)?,
            wk: tensor2(j.get("wk").ok_or_else(|| anyhow!("missing wk"))?)?,
            wv: tensor2(j.get("wv").ok_or_else(|| anyhow!("missing wv"))?)?,
            w_head: tensor2(j.get("w_head").ok_or_else(|| anyhow!("missing w_head"))?)?,
            b_head: vec1(j.get("b_head").ok_or_else(|| anyhow!("missing b_head"))?)?,
        })),
        other => Err(anyhow!("unknown model '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn tensor2_shapes() {
        let t = tensor2(&parse(r#"{"shape": [2, 3], "data": [1,2,3,4,5,6]}"#).unwrap()).unwrap();
        assert_eq!((t.rows, t.cols), (2, 3));
        assert_eq!(t.at(1, 2), 6.0);
        // rank-3 conv kernel flattens leading dims
        let t3 =
            tensor2(&parse(r#"{"shape": [2, 1, 3], "data": [1,2,3,4,5,6]}"#).unwrap()).unwrap();
        assert_eq!((t3.rows, t3.cols), (2, 3));
    }

    #[test]
    fn mismatched_shape_rejected() {
        assert!(tensor2(&parse(r#"{"shape": [2, 2], "data": [1]}"#).unwrap()).is_err());
    }
}
