//! Dimensional analysis over the energy arithmetic.
//!
//! A unit algebra over the base dimensions **time**, **power**, and
//! **item count** (frequency is time⁻¹, energy is power·time) with
//! decimal SI-scale tracking, so `J = W·s` holds and `mJ ≠ J`.  Units
//! are inferred from three sources, in decreasing order of trust:
//!
//! 1. **declared types** — struct fields and fn return types naming a
//!    `util::units` newtype (`Secs`, `Joules`, `Watts`, `Hertz`),
//!    harvested crate-wide into a [`UnitTable`];
//! 2. **newtype boundary calls** — `Secs::from_ms(x)` types its
//!    argument as ms and its result as base seconds, `.mj()` produces
//!    an mJ number, `.value()` passes the receiver's unit through;
//! 3. **the suffix convention** — `gap_ms`, `energy_mj`, `rate_hz`,
//!    `mj_per_item` on identifiers, fields, fn names, and wire keys.
//!
//! Units propagate bottom-up through the expression trees
//! (`analysis::expr`) of every fn body in parity + serving scope.
//! Three rules fire:
//!
//! * `unit-mixed-add` — add/sub/compare/assign of incompatible
//!   dimensions (`gap_ms + power_mw`);
//! * `unit-scale-mismatch` — same dimension, different SI scale
//!   (`total_mj + x_j`, `t_ms < deadline_s`);
//! * `unit-wire-suffix` — in wire-codec files, a key's unit suffix
//!   must match the encoded expression's inferred unit.
//!
//! Conservatism is the contract (like the call graph's unresolved
//! calls): an unknown unit stays unknown and makes **no** findings, a
//! mismatch never propagates a unit (no cascades), and a dimensionless
//! result (`s/s`, counts) drops out of checking entirely.

use super::expr::{self, BinOp, Expr, ExprKind};
use super::lexer::{Tok, TokKind};
use super::rules::{Finding, UNIT_MIXED_ADD, UNIT_SCALE_MISMATCH, UNIT_WIRE_SUFFIX};
use std::collections::BTreeMap;

/// Dimension vector: exponents of time, power, item count.
/// `Hz = time⁻¹`, `J = power·time`, `J/item = power·time·item⁻¹`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dim {
    pub time: i8,
    pub power: i8,
    pub item: i8,
}

impl Dim {
    pub const fn is_zero(self) -> bool {
        self.time == 0 && self.power == 0 && self.item == 0
    }
}

/// A dimension plus a decimal scale exponent relative to the SI base
/// (`ms` is time at scale −3, `MHz` is time⁻¹ at scale +6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Unit {
    pub dim: Dim,
    pub scale: i16,
}

const fn unit(time: i8, power: i8, item: i8, scale: i16) -> Unit {
    Unit {
        dim: Dim { time, power, item },
        scale,
    }
}

pub const SECS: Unit = unit(1, 0, 0, 0);
pub const JOULES: Unit = unit(1, 1, 0, 0);
pub const WATTS: Unit = unit(0, 1, 0, 0);
pub const HERTZ: Unit = unit(-1, 0, 0, 0);

impl Unit {
    pub fn mul(self, o: Unit) -> Unit {
        Unit {
            dim: Dim {
                time: self.dim.time + o.dim.time,
                power: self.dim.power + o.dim.power,
                item: self.dim.item + o.dim.item,
            },
            scale: self.scale + o.scale,
        }
    }

    pub fn div(self, o: Unit) -> Unit {
        Unit {
            dim: Dim {
                time: self.dim.time - o.dim.time,
                power: self.dim.power - o.dim.power,
                item: self.dim.item - o.dim.item,
            },
            scale: self.scale - o.scale,
        }
    }

    fn at_scale(self, scale: i16) -> Unit {
        Unit { dim: self.dim, scale }
    }

    /// Human form for findings: `mJ`, `ms`, `MHz`, `mJ/item`, or a
    /// generic `s^a·W^b` composite.
    pub fn render(self) -> String {
        let base = base_symbol(self.dim);
        match self.scale {
            -9 => format!("n{base}"),
            -6 => format!("u{base}"),
            -3 => format!("m{base}"),
            0 => base,
            3 => format!("k{base}"),
            6 => format!("M{base}"),
            9 => format!("G{base}"),
            s => format!("10^{s}·{base}"),
        }
    }
}

fn base_symbol(d: Dim) -> String {
    match (d.time, d.power, d.item) {
        (1, 0, 0) => "s".to_string(),
        (-1, 0, 0) => "Hz".to_string(),
        (0, 1, 0) => "W".to_string(),
        (1, 1, 0) => "J".to_string(),
        (1, 1, -1) => "J/item".to_string(),
        (1, 0, -1) => "s/item".to_string(),
        (0, 1, -1) => "W/item".to_string(),
        _ => {
            let mut parts: Vec<String> = Vec::new();
            for (sym, e) in [("s", d.time), ("W", d.power), ("item", d.item)] {
                if e == 1 {
                    parts.push(sym.to_string());
                } else if e != 0 {
                    parts.push(format!("{sym}^{e}"));
                }
            }
            if parts.is_empty() {
                "1".to_string()
            } else {
                parts.join("·")
            }
        }
    }
}

/// Unit suffix segment → unit (the `_ms` / `_mj` / `_mhz` convention).
fn suffix_unit(seg: &str) -> Option<Unit> {
    match seg {
        "s" | "sec" | "secs" => Some(SECS),
        "ms" => Some(SECS.at_scale(-3)),
        "us" => Some(SECS.at_scale(-6)),
        "ns" => Some(SECS.at_scale(-9)),
        "j" => Some(JOULES),
        "mj" => Some(JOULES.at_scale(-3)),
        "uj" => Some(JOULES.at_scale(-6)),
        "w" => Some(WATTS),
        "mw" => Some(WATTS.at_scale(-3)),
        "hz" => Some(HERTZ),
        "khz" => Some(HERTZ.at_scale(3)),
        "mhz" => Some(HERTZ.at_scale(6)),
        "ghz" => Some(HERTZ.at_scale(9)),
        _ => None,
    }
}

/// Per-item denominators the `_per_<x>` convention uses.
fn is_item_segment(seg: &str) -> bool {
    matches!(
        seg,
        "item" | "items" | "req" | "reqs" | "request" | "requests" | "op" | "ops" | "byte"
            | "bytes" | "sample" | "samples"
    )
}

/// Infer a unit from an identifier's suffix convention: the name must
/// have ≥ 2 `_`-separated segments (so a bare local `s` or `ms` is not
/// a unit), its first group must *end* in a unit suffix, and every
/// `per`-separated denominator group must be a single item word or unit
/// suffix.  `gap_ms` → ms, `energy_mj` → mJ, `mj_per_item` → mJ/item,
/// `rate_hz` → Hz; anything else → unknown.
pub fn unit_of_name(name: &str) -> Option<Unit> {
    let lower = name.to_ascii_lowercase();
    let segs: Vec<&str> = lower.split('_').filter(|s| !s.is_empty()).collect();
    if segs.len() < 2 {
        return None;
    }
    let mut groups: Vec<Vec<&str>> = vec![Vec::new()];
    for s in &segs {
        if *s == "per" {
            groups.push(Vec::new());
        } else if let Some(g) = groups.last_mut() {
            g.push(s);
        }
    }
    let mut it = groups.iter();
    let num = it.next()?;
    let mut u = suffix_unit(num.last()?)?;
    for den in it {
        let [seg] = den.as_slice() else { return None };
        if is_item_segment(seg) {
            u.dim.item -= 1;
        } else {
            u = u.div(suffix_unit(seg)?);
        }
    }
    if u.dim.is_zero() {
        None
    } else {
        Some(u)
    }
}

/// Declared type → unit, for the `util::units` newtypes (plus
/// `Duration`, whose only f64 boundary is `as_secs_f64`).
pub fn type_unit(ty: &str) -> Option<Unit> {
    match ty {
        "Secs" => Some(SECS),
        "Joules" => Some(JOULES),
        "Watts" => Some(WATTS),
        "Hertz" => Some(HERTZ),
        "Duration" => Some(SECS),
        _ => None,
    }
}

/// Crate-wide declared-type units: struct field names and fn names that
/// are declared with a unit newtype.  A name declared with *different*
/// unit types in different places is poisoned (`Some(None)` at lookup:
/// ambiguous, blocks the suffix fallback too).
#[derive(Debug, Default)]
pub struct UnitTable {
    pub fields: BTreeMap<String, Option<Unit>>,
    pub fns: BTreeMap<String, Option<Unit>>,
}

impl UnitTable {
    pub fn fields_typed(&self) -> usize {
        self.fields.values().filter(|u| u.is_some()).count()
    }

    pub fn fns_typed(&self) -> usize {
        self.fns.values().filter(|u| u.is_some()).count()
    }
}

fn record(map: &mut BTreeMap<String, Option<Unit>>, name: &str, u: Unit) {
    match map.get(name) {
        None => {
            map.insert(name.to_string(), Some(u));
        }
        Some(Some(prev)) if *prev != u => {
            map.insert(name.to_string(), None); // conflicting declarations
        }
        _ => {}
    }
}

/// Aggregate statistics for the `units` report section / `--units`.
#[derive(Debug, Default, Clone, Copy)]
pub struct UnitsSummary {
    /// Files the inference pass ran over (parity + serving src).
    pub files_checked: usize,
    pub fns_checked: usize,
    /// Expression nodes visited / nodes that resolved to a unit.
    pub exprs: usize,
    pub resolved: usize,
    /// Same-unit checks where both sides were known.
    pub checks: usize,
    pub findings: usize,
    /// Declared-type harvest sizes (crate-wide).
    pub fields_typed: usize,
    pub fns_typed: usize,
}

// ---------------------------------------------------------------------
// declaration harvest
// ---------------------------------------------------------------------

fn adjacent(code: &[Tok], a: usize) -> bool {
    match (code.get(a), code.get(a + 1)) {
        (Some(x), Some(y)) => x.end == y.start,
        _ => false,
    }
}

fn at_glued(code: &[Tok], k: usize, a: char, b: char) -> bool {
    code.get(k).is_some_and(|t| t.is_punct(a))
        && code.get(k + 1).is_some_and(|t| t.is_punct(b))
        && adjacent(code, k)
}

/// Index of the closer matching `code[open]`, or `hi` when unbalanced.
fn matching(code: &[Tok], open: usize, hi: usize, oc: char, cc: char) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < hi {
        let Some(t) = code.get(k) else { break };
        if t.is_punct(oc) {
            depth += 1;
        } else if t.is_punct(cc) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    hi
}

/// Skip a `<...>` generic list starting at `code[k] == '<'`; returns the
/// index past the matching `>`.  Bails at `{` / `;` / `(`.
fn skip_angles(code: &[Tok], mut k: usize, hi: usize) -> usize {
    let mut depth = 0i32;
    while k < hi {
        let Some(t) = code.get(k) else { break };
        if t.is_punct('<') {
            depth += 1;
        } else if t.is_punct('>') {
            let arrow = k >= 1 && code.get(k - 1).is_some_and(|p| p.is_punct('-'));
            if !arrow {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
        } else if t.is_punct('{') || t.is_punct(';') || t.is_punct('(') {
            return k;
        }
        k += 1;
    }
    hi
}

/// One `fn` item found in the token stream.
struct FnItem {
    /// Token range of the parameter list (inside the parens).
    params: (usize, usize),
    /// First identifier of the return type, when declared.
    ret: Option<String>,
    /// Token range of the body (inside the braces); `None` for trait
    /// method declarations.
    body: Option<(usize, usize)>,
    name: String,
}

fn scan_fns(code: &[Tok]) -> Vec<FnItem> {
    let mut out = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i < n {
        if !code.get(i).is_some_and(|t| t.is_ident("fn")) {
            i += 1;
            continue;
        }
        let Some(name_tok) = code.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 2;
            continue;
        }
        let name = name_tok.text.clone();
        let mut j = i + 2;
        if code.get(j).is_some_and(|t| t.is_punct('<')) {
            j = skip_angles(code, j, n);
        }
        if !code.get(j).is_some_and(|t| t.is_punct('(')) {
            i = j.max(i + 1);
            continue;
        }
        let close_p = matching(code, j, n, '(', ')');
        // return type: `-> First...` right after the params
        let mut ret = None;
        let mut k = close_p + 1;
        if at_glued(code, k, '-', '>') {
            let mut m = k + 2;
            while m < n {
                match code.get(m) {
                    Some(t) if t.kind == TokKind::Ident && t.text != "dyn" && t.text != "impl" => {
                        ret = Some(t.text.clone());
                        break;
                    }
                    Some(t)
                        if t.is_punct('&')
                            || t.is_punct('(')
                            || t.kind == TokKind::Lifetime
                            || t.is_ident("dyn")
                            || t.is_ident("impl")
                            || t.is_ident("mut") =>
                    {
                        m += 1;
                    }
                    _ => break,
                }
            }
        }
        // body: first `{` before a `;` (where-clauses pass through)
        let mut body = None;
        while k < n {
            let Some(t) = code.get(k) else { break };
            if t.is_punct(';') {
                break;
            }
            if t.is_punct('{') {
                let close_b = matching(code, k, n, '{', '}');
                body = Some((k + 1, close_b));
                break;
            }
            k += 1;
        }
        let next = match body {
            Some((_, close_b)) => close_b, // skip the body; nested fns are
            // visited by the outer parse
            None => k,
        };
        out.push(FnItem {
            params: (j + 1, close_p),
            ret,
            body,
            name,
        });
        i = next.max(i + 1);
    }
    out
}

/// Harvest declared-type units from one file's code tokens into the
/// crate-wide table: struct fields (`margin: Joules`) and fn return
/// types (`fn gap(&self) -> Secs`).  Runs over **all** src files.
pub fn harvest(code: &[Tok], table: &mut UnitTable) {
    // struct fields
    let n = code.len();
    let mut i = 0usize;
    while i < n {
        if code.get(i).is_some_and(|t| t.is_ident("struct")) {
            let mut j = i + 2; // past `struct Name`
            if code.get(j).is_some_and(|t| t.is_punct('<')) {
                j = skip_angles(code, j, n);
            }
            if code.get(j).is_some_and(|t| t.is_punct('{')) {
                let close = matching(code, j, n, '{', '}');
                harvest_fields(code, j + 1, close, table);
                i = close;
            }
        }
        i += 1;
    }
    // fn return types
    for f in scan_fns(code) {
        if let Some(u) = f.ret.as_deref().and_then(type_unit) {
            record(&mut table.fns, &f.name, u);
        }
    }
}

fn harvest_fields(code: &[Tok], lo: usize, close: usize, table: &mut UnitTable) {
    let mut depth = 0i32;
    let mut k = lo;
    while k < close {
        let Some(t) = code.get(k) else { break };
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') || t.is_punct('<') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') || t.is_punct('>') {
            depth -= 1;
        } else if depth == 0
            && t.kind == TokKind::Ident
            && code.get(k + 1).is_some_and(|c| c.is_punct(':'))
            && !at_glued(code, k + 1, ':', ':')
        {
            let name = t.text.clone();
            // first identifier of the type
            let mut m = k + 2;
            while m < close {
                match code.get(m) {
                    Some(tt) if tt.kind == TokKind::Ident => {
                        if let Some(u) = type_unit(&tt.text) {
                            record(&mut table.fields, &name, u);
                        }
                        break;
                    }
                    Some(tt) if tt.is_punct(',') => break,
                    Some(_) => m += 1,
                    None => break,
                }
            }
            k = m;
        }
        k += 1;
    }
}

// ---------------------------------------------------------------------
// inference
// ---------------------------------------------------------------------

struct Cx<'a> {
    file: &'a str,
    wire: bool,
    table: &'a UnitTable,
    env: BTreeMap<String, Option<Unit>>,
    findings: Vec<Finding>,
    stats: UnitsSummary,
}

impl Cx<'_> {
    fn push(&mut self, rule: &str, line: u32, message: String) {
        self.findings.push(Finding {
            rule: rule.to_string(),
            file: self.file.to_string(),
            line,
            message,
            suppressed: false,
            reason: None,
        });
    }

    /// The same-unit check: both sides known, dimensions then scales.
    fn check(&mut self, line: u32, what: &str, a: Unit, b: Unit) {
        self.stats.checks += 1;
        if a.dim != b.dim {
            self.push(
                UNIT_MIXED_ADD,
                line,
                format!(
                    "{what} combines {} with {} — incompatible dimensions",
                    a.render(),
                    b.render()
                ),
            );
        } else if a.scale != b.scale {
            let d = (a.scale - b.scale).abs();
            self.push(
                UNIT_SCALE_MISMATCH,
                line,
                format!(
                    "{what} combines {} with {} — same dimension, scales differ by 10^{d}",
                    a.render(),
                    b.render()
                ),
            );
        }
    }
}

fn field_unit(name: &str, cx: &Cx) -> Option<Unit> {
    match cx.table.fields.get(name) {
        Some(Some(u)) => Some(*u),
        Some(None) => None, // poisoned: conflicting declared types
        None => unit_of_name(name),
    }
}

fn fn_unit(name: &str, cx: &Cx) -> Option<Unit> {
    match cx.table.fns.get(name) {
        Some(Some(u)) => Some(*u),
        Some(None) => None,
        None => unit_of_name(name),
    }
}

/// `Type::from_ms`-style boundary constructors: expected argument unit.
fn boundary_arg(base: Unit, ctor: &str) -> Option<Unit> {
    let scaled = |s| Some(base.at_scale(s));
    match ctor {
        "from_ms" | "from_millis" | "from_mj" | "from_mw" => scaled(-3),
        "from_us" | "from_micros" | "from_uj" => scaled(-6),
        "from_nanos" => scaled(-9),
        "from_secs" | "from_secs_f64" => scaled(0),
        "from_mhz" => scaled(6),
        _ => None,
    }
}

fn call_unit(path: &[String], args: &[(Option<Unit>, u32)], cx: &mut Cx) -> Option<Unit> {
    let last = path.last()?;
    if path.len() == 2 && path.first().is_some_and(|p| p == "Json") && last == "Num" {
        // Json::Num(x): the wire-value wrapper passes the unit through
        return args.first().and_then(|(u, _)| *u);
    }
    if let Some(base) = type_unit(last) {
        // newtype constructor `Secs(x)`: x is a base-scale number
        if let Some((Some(a), aline)) = args.first() {
            cx.check(*aline, &format!("`{last}(..)` argument"), base, *a);
        }
        return Some(base);
    }
    if path.len() >= 2 {
        if let Some(base) = path.get(path.len() - 2).and_then(|t| type_unit(t)) {
            if let Some(expected) = boundary_arg(base, last) {
                if let Some((Some(a), aline)) = args.first() {
                    cx.check(*aline, &format!("`{last}(..)` argument"), expected, *a);
                }
                return Some(base); // newtypes normalize to base scale
            }
        }
    }
    fn_unit(last, cx)
}

fn method_unit(
    recv_u: Option<Unit>,
    name: &str,
    args: &[(Option<Unit>, u32)],
    cx: &mut Cx,
) -> Option<Unit> {
    match name {
        // value extraction / unit-preserving combinators
        "value" | "abs" | "clone" | "to_owned" | "copied" | "cloned" => recv_u,
        "max" | "min" | "clamp" => {
            if let Some(r) = recv_u {
                for (a, aline) in args {
                    if let Some(a) = a {
                        cx.check(*aline, &format!("`.{name}(..)` argument"), r, *a);
                    }
                }
            }
            recv_u
        }
        // newtype boundary extractors: the result is a number *in* that
        // scaled unit
        "mj" => Some(JOULES.at_scale(-3)),
        "uj" => Some(JOULES.at_scale(-6)),
        "ms" => Some(SECS.at_scale(-3)),
        "us" => Some(SECS.at_scale(-6)),
        "mw" => Some(WATTS.at_scale(-3)),
        "mhz" => Some(HERTZ.at_scale(6)),
        // std::time boundaries
        "as_secs_f64" | "as_secs" | "elapsed" => Some(SECS),
        "as_millis" => Some(SECS.at_scale(-3)),
        "as_micros" => Some(SECS.at_scale(-6)),
        "as_nanos" => Some(SECS.at_scale(-9)),
        _ => fn_unit(name, cx),
    }
}

fn path_unit(segs: &[String], cx: &Cx) -> Option<Unit> {
    match segs {
        [name] => {
            if let Some(u) = cx.env.get(name) {
                return *u;
            }
            if name == "self" || name == "Self" {
                return None;
            }
            unit_of_name(name)
        }
        [ty, _assoc] if type_unit(ty).is_some() => type_unit(ty), // Secs::ZERO
        _ => segs.last().and_then(|s| unit_of_name(s)),
    }
}

fn wire_key_like(key: &str) -> bool {
    !key.is_empty()
        && key
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn infer(e: &Expr, cx: &mut Cx) -> Option<Unit> {
    cx.stats.exprs += 1;
    let u = infer_inner(e, cx);
    if u.is_some() {
        cx.stats.resolved += 1;
    }
    u
}

fn infer_inner(e: &Expr, cx: &mut Cx) -> Option<Unit> {
    match &e.kind {
        ExprKind::Num(_) | ExprKind::Str(_) => None,
        ExprKind::Path(segs) => path_unit(segs, cx),
        ExprKind::Unary { rhs, .. } => infer(rhs, cx),
        ExprKind::Cast(inner) => infer(inner, cx),
        ExprKind::Binary { op, lhs, rhs } => {
            let a = infer(lhs, cx);
            let b = infer(rhs, cx);
            if op.requires_same_unit() {
                if let (Some(a), Some(b)) = (a, b) {
                    cx.check(e.line, &format!("`{}`", op.symbol()), a, b);
                    if !op.is_comparison() && !matches!(op, BinOp::Assign) && a == b {
                        return Some(a);
                    }
                }
                return None;
            }
            match op {
                BinOp::Mul => {
                    let u = a?.mul(b?);
                    if u.dim.is_zero() {
                        None
                    } else {
                        Some(u)
                    }
                }
                BinOp::Div => {
                    let u = a?.div(b?);
                    if u.dim.is_zero() {
                        None
                    } else {
                        Some(u)
                    }
                }
                _ => None,
            }
        }
        ExprKind::Call { path, args } => {
            let au: Vec<(Option<Unit>, u32)> =
                args.iter().map(|a| (infer(a, cx), a.line)).collect();
            call_unit(path, &au, cx)
        }
        ExprKind::Method { recv, name, args } => {
            let r = infer(recv, cx);
            let au: Vec<(Option<Unit>, u32)> =
                args.iter().map(|a| (infer(a, cx), a.line)).collect();
            method_unit(r, name, &au, cx)
        }
        ExprKind::Field { recv, name } => {
            infer(recv, cx);
            field_unit(name, cx)
        }
        ExprKind::Index { recv, args } => {
            let r = infer(recv, cx);
            for a in args {
                infer(a, cx);
            }
            r // an element of a suffixed collection carries the suffix
        }
        ExprKind::Tuple(kids) => {
            let units: Vec<Option<Unit>> = kids.iter().map(|k| infer(k, cx)).collect();
            if cx.wire && kids.len() == 2 {
                if let Some(ExprKind::Str(key)) = kids.first().map(|k| &k.kind) {
                    if wire_key_like(key) {
                        if let (Some(exp), Some(Some(got))) =
                            (unit_of_name(key), units.get(1).copied())
                        {
                            cx.stats.checks += 1;
                            if exp != got {
                                cx.push(
                                    UNIT_WIRE_SUFFIX,
                                    e.line,
                                    format!(
                                        "wire key \"{key}\" implies {} but the encoded value is {}",
                                        exp.render(),
                                        got.render()
                                    ),
                                );
                            }
                        }
                    }
                }
            }
            None
        }
        ExprKind::StructLit { fields, .. } => {
            for (name, val) in fields {
                let Some(val) = val else { continue }; // shorthand: same name
                let vu = infer(val, cx);
                if name == ".." {
                    continue;
                }
                if let (Some(fu), Some(vu)) = (field_unit(name, cx), vu) {
                    cx.check(val.line, &format!("field `{name}`"), fu, vu);
                }
            }
            None
        }
        ExprKind::Let { name, ty, init } => {
            let declared = ty.as_deref().and_then(type_unit);
            let target = declared.or_else(|| unit_of_name(name));
            let iu = init.as_ref().and_then(|i| infer(i, cx));
            if let (Some(t), Some(got), Some(i)) = (target, iu, init.as_ref()) {
                cx.check(i.line, &format!("binding `{name}`"), t, got);
            }
            cx.env.insert(name.clone(), target.or(iu));
            None
        }
        ExprKind::Block(kids) => {
            let mut last = None;
            for k in kids {
                last = infer(k, cx);
            }
            last // a block's unit is its tail expression's
        }
        ExprKind::Other(kids) => {
            for k in kids {
                infer(k, cx);
            }
            None
        }
    }
}

/// Bind fn parameters (`name: Type`) into the environment: declared
/// newtype unit first, suffix convention second.
fn bind_params(code: &[Tok], lo: usize, hi: usize, cx: &mut Cx) {
    let mut depth = 0i32;
    let mut k = lo;
    let mut last_ident: Option<String> = None;
    while k < hi {
        let Some(t) = code.get(k) else { break };
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') || t.is_punct('<') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') || t.is_punct('>') {
            depth -= 1;
        } else if depth == 0 {
            if t.kind == TokKind::Ident && t.text != "mut" && t.text != "self" {
                if last_ident.is_none() {
                    last_ident = Some(t.text.clone());
                }
            } else if t.is_punct(':')
                && !at_glued(code, k, ':', ':')
                && !code.get(k.wrapping_sub(1)).is_some_and(|p| p.is_punct(':'))
            {
                if let Some(name) = last_ident.take() {
                    // first identifier of the type
                    let mut m = k + 1;
                    let mut ty = None;
                    while m < hi {
                        match code.get(m) {
                            Some(tt) if tt.kind == TokKind::Ident => {
                                ty = Some(tt.text.clone());
                                break;
                            }
                            Some(tt)
                                if tt.is_punct('&')
                                    || tt.kind == TokKind::Lifetime
                                    || tt.is_ident("mut")
                                    || tt.is_ident("dyn")
                                    || tt.is_ident("impl") =>
                            {
                                m += 1;
                            }
                            _ => break,
                        }
                    }
                    let u = ty
                        .as_deref()
                        .and_then(type_unit)
                        .or_else(|| unit_of_name(&name));
                    cx.env.insert(name, u);
                }
            } else if t.is_punct(',') {
                last_ident = None;
            }
        }
        k += 1;
    }
}

/// Run the dimensional pass over one file's fn bodies.  The caller
/// gates on scope (parity + serving src files) and applies suppression
/// pragmas afterwards like any other per-file rule.
pub fn check_file(
    rel: &str,
    code: &[Tok],
    table: &UnitTable,
    wire: bool,
    stats: &mut UnitsSummary,
) -> Vec<Finding> {
    let mut cx = Cx {
        file: rel,
        wire,
        table,
        env: BTreeMap::new(),
        findings: Vec::new(),
        stats: UnitsSummary::default(),
    };
    for f in scan_fns(code) {
        let Some((blo, bhi)) = f.body else { continue };
        cx.env.clear();
        cx.stats.fns_checked += 1;
        bind_params(code, f.params.0, f.params.1, &mut cx);
        for e in expr::parse_stmts(code, blo, bhi) {
            infer(&e, &mut cx);
        }
    }
    cx.stats.files_checked = 1;
    cx.stats.findings = cx.findings.len();
    stats.files_checked += cx.stats.files_checked;
    stats.fns_checked += cx.stats.fns_checked;
    stats.exprs += cx.stats.exprs;
    stats.resolved += cx.stats.resolved;
    stats.checks += cx.stats.checks;
    stats.findings += cx.stats.findings;
    cx.findings
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::analysis::lexer::{code_tokens, tokenize};

    fn run(src: &str) -> Vec<Finding> {
        run_wire(src, false)
    }

    fn run_wire(src: &str, wire: bool) -> Vec<Finding> {
        let toks = tokenize(src);
        let code = code_tokens(&toks);
        let mut table = UnitTable::default();
        harvest(&code, &mut table);
        let mut stats = UnitsSummary::default();
        check_file("src/runtime/x.rs", &code, &table, wire, &mut stats)
    }

    #[test]
    fn suffix_inference() {
        assert_eq!(unit_of_name("gap_ms"), Some(SECS.at_scale(-3)));
        assert_eq!(unit_of_name("energy_mj"), Some(JOULES.at_scale(-3)));
        assert_eq!(unit_of_name("rate_hz"), Some(HERTZ));
        assert_eq!(unit_of_name("clock_mhz"), Some(HERTZ.at_scale(6)));
        let per_item = unit_of_name("mj_per_item").unwrap();
        assert_eq!(per_item.dim, Dim { time: 1, power: 1, item: -1 });
        assert_eq!(per_item.scale, -3);
        // too short / no suffix / dimensionless stay unknown
        assert_eq!(unit_of_name("ms"), None);
        assert_eq!(unit_of_name("count"), None);
        assert_eq!(unit_of_name("total_count"), None);
        assert_eq!(unit_of_name("s_per_s"), None);
    }

    #[test]
    fn algebra_watts_times_secs_is_joules() {
        // W·s = J at matching scales: no findings
        assert!(run("fn f(e_j: f64, p_w: f64, t_s: f64) -> f64 { e_j + p_w * t_s }").is_empty());
        // mW·s = mJ, added to J: scale mismatch
        let f = run("fn f(e_j: f64, p_mw: f64, t_s: f64) -> f64 { e_j + p_mw * t_s }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        assert!(f[0].message.contains("mJ"), "{}", f[0].message);
        // s · Hz is dimensionless: comparing it to anything is unchecked
        assert!(run("fn f(t_s: f64, r_hz: f64, n: f64) -> bool { t_s * r_hz > n }").is_empty());
    }

    #[test]
    fn mixed_add_fires_with_line() {
        let f = run("fn f(gap_ms: f64, power_mw: f64) -> f64 {\n    gap_ms + power_mw\n}");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_MIXED_ADD);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn scale_mismatch_on_compare_and_assign() {
        let f = run("fn f(t_ms: f64, deadline_s: f64) -> bool { t_ms < deadline_s }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        let f = run("fn f(mut t_s: f64, d_ms: f64) { t_s += d_ms; }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
    }

    #[test]
    fn boundary_calls_type_both_sides() {
        // from_ms argument must be an ms number
        let f = run("fn f(gap_s: f64) { let g = Secs::from_ms(gap_s); }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        // .value() of a declared Joules field is base J; .mj() is mJ
        let src = "struct C { margin: Joules }\n\
                   impl C { fn f(&self, x_mj: f64) -> f64 { x_mj + self.margin.value() } }";
        let f = run(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        let src = "struct C { margin: Joules }\n\
                   impl C { fn f(&self, x_mj: f64) -> f64 { x_mj + self.margin.mj() } }";
        assert!(run(src).is_empty());
    }

    #[test]
    fn declared_types_beat_suffixes_and_conflicts_poison() {
        // declared Secs wins over a (wrong) _ms suffix: comparing to
        // base seconds is clean
        let src = "struct C { gap_ms: Secs }\n\
                   fn f(c: &C, t_s: f64) -> bool { c.gap_ms.value() > t_s }";
        assert!(run(src).is_empty());
        // conflicting declarations poison the name entirely
        let src = "struct A { gap: Secs }\nstruct B { gap: Joules }\n\
                   fn f(a: &A, t_s: f64) -> f64 { a.gap.value() + t_s }";
        assert!(run(src).is_empty());
    }

    #[test]
    fn let_bindings_check_and_propagate() {
        let f = run("fn f(t: Secs) { let gap_ms = t.value(); }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        // propagation: bound unit flows into later expressions
        let f = run("fn f(t: Secs, e_mj: f64) { let gap = t.ms(); let x = e_mj + gap; }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_MIXED_ADD);
    }

    #[test]
    fn struct_literal_fields_are_checked() {
        let src = "fn f(d: Joules) -> Rec { Rec { before_mj: d.value(), n: 3 } }";
        let f = run(src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
        assert!(f[0].message.contains("before_mj"), "{}", f[0].message);
    }

    #[test]
    fn wire_suffix_checks_key_against_value() {
        let src = "struct R { gap: Secs }\n\
                   impl R { fn to_json(&self) -> Json {\n\
                   Json::obj(vec![(\"gap_ms\", Json::Num(self.gap.value()))])\n} }";
        let f = run_wire(src, true);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_WIRE_SUFFIX);
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("gap_ms"), "{}", f[0].message);
        // matching suffix is clean; non-wire files never run the check
        let ok = src.replace("gap_ms", "gap_s");
        assert!(run_wire(&ok, true).is_empty());
        assert!(run_wire(src, false).is_empty());
    }

    #[test]
    fn unknowns_make_no_findings() {
        // untyped names, literals, dimensionless ratios: all silent
        let src = "fn f(a: f64, b: f64, items: f64, t_s: f64, u_s: f64) -> f64 {\n\
                   let r = t_s / u_s; a + b * r + items + 1.0\n}";
        assert!(run(src).is_empty());
        // a mismatch does not cascade into downstream findings
        let f = run("fn f(a_mj: f64, b_j: f64, c_mj: f64) -> f64 { (a_mj + b_j) + c_mj }");
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn duration_boundaries() {
        assert!(run(
            "fn f(t_s: f64) -> f64 { t_s + started.elapsed().as_secs_f64() }"
        )
        .is_empty());
        let f = run("fn f(t_s: f64) -> bool { t_s > d.as_millis() }");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, UNIT_SCALE_MISMATCH);
    }

    #[test]
    fn stats_accumulate() {
        let toks = tokenize("fn f(t_ms: f64, u_ms: f64) -> f64 { t_ms + u_ms }");
        let code = code_tokens(&toks);
        let table = UnitTable::default();
        let mut stats = UnitsSummary::default();
        let f = check_file("src/runtime/x.rs", &code, &table, false, &mut stats);
        assert!(f.is_empty());
        assert_eq!(stats.files_checked, 1);
        assert_eq!(stats.fns_checked, 1);
        assert_eq!(stats.checks, 1);
        assert!(stats.resolved >= 2);
        assert!(stats.exprs >= 3);
    }
}
