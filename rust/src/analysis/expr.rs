//! Pratt expression parser over the lexer's token stream.
//!
//! Recovers binary-operator trees — with byte spans and anchor lines —
//! from fn bodies, so the dimensional-analysis pass (`analysis/units`)
//! can propagate units bottom-up through the energy arithmetic.
//!
//! The parser is deliberately **total**: any token sequence — macro
//! soup, match patterns, malformed generics — parses into *some* tree,
//! and every loop either consumes a token or returns.  Constructs the
//! grammar does not model become [`ExprKind::Other`] nodes whose
//! children are still parsed (and therefore still unit-checked); the
//! compiler owns syntax errors, so this parser only has to be right
//! about the expressions it claims to understand and honest (`Other`,
//! no unit) about the rest.  Multi-character operators arrive from the
//! lexer as adjacent single-char puncts (`>` `=` back to back) and are
//! glued by byte adjacency before precedence climbing.

use super::lexer::{Tok, TokKind};

/// Binary operators with Rust precedence.  Bit/shift/range operators
/// are parsed (so their operands are still visited) but carry no unit
/// semantics; compound bit-assignments are folded onto their bit op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Lt,
    Gt,
    Le,
    Ge,
    Eq,
    Ne,
    And,
    Or,
    BitAnd,
    BitOr,
    BitXor,
    Shl,
    Shr,
    Range,
    Assign,
    AddAssign,
    SubAssign,
    MulAssign,
    DivAssign,
    RemAssign,
}

impl BinOp {
    pub fn symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Lt => "<",
            BinOp::Gt => ">",
            BinOp::Le => "<=",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
            BinOp::BitAnd => "&",
            BinOp::BitOr => "|",
            BinOp::BitXor => "^",
            BinOp::Shl => "<<",
            BinOp::Shr => ">>",
            BinOp::Range => "..",
            BinOp::Assign => "=",
            BinOp::AddAssign => "+=",
            BinOp::SubAssign => "-=",
            BinOp::MulAssign => "*=",
            BinOp::DivAssign => "/=",
            BinOp::RemAssign => "%=",
        }
    }

    /// The add/sub/compare/assign family: both operands must share a
    /// dimension *and* scale (`x_mj + y_j` is the bug class).
    pub fn requires_same_unit(self) -> bool {
        matches!(
            self,
            BinOp::Add
                | BinOp::Sub
                | BinOp::Rem
                | BinOp::Lt
                | BinOp::Gt
                | BinOp::Le
                | BinOp::Ge
                | BinOp::Eq
                | BinOp::Ne
                | BinOp::Assign
                | BinOp::AddAssign
                | BinOp::SubAssign
                | BinOp::RemAssign
        )
    }

    /// Comparisons yield a bool, not a quantity.
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge | BinOp::Eq | BinOp::Ne
        )
    }
}

#[derive(Debug, Clone)]
pub enum ExprKind {
    /// Numeric literal; `None` when the lexeme does not parse (hex with
    /// odd suffixes, split exponents) — still a known-dimensionless atom.
    Num(Option<f64>),
    /// String literal content (wire keys live here).
    Str(String),
    /// `a::b::c` path, single segment for a plain identifier.
    Path(Vec<String>),
    Unary {
        op: char,
        rhs: Box<Expr>,
    },
    Binary {
        op: BinOp,
        lhs: Box<Expr>,
        rhs: Box<Expr>,
    },
    /// `x as T` — the unit passes through the cast.
    Cast(Box<Expr>),
    Call {
        path: Vec<String>,
        args: Vec<Expr>,
    },
    Method {
        recv: Box<Expr>,
        name: String,
        args: Vec<Expr>,
    },
    Field {
        recv: Box<Expr>,
        name: String,
    },
    Index {
        recv: Box<Expr>,
        args: Vec<Expr>,
    },
    /// `(a, b, …)` — a single parenthesised expression is returned
    /// directly (span widened over the parens), so `Tuple` is ≠ 1 long.
    Tuple(Vec<Expr>),
    StructLit {
        path: Vec<String>,
        /// `(name, value)`; shorthand fields carry `None`, the
        /// functional-update `..base` tail is stored under the name `..`.
        fields: Vec<(String, Option<Expr>)>,
    },
    Block(Vec<Expr>),
    /// `let <ident>[: <ty>] = <init>` — the binding the units pass
    /// checks and records.  Pattern lets degrade to `Other`.
    Let {
        name: String,
        /// First identifier of the ascribed type, when written.
        ty: Option<String>,
        init: Option<Box<Expr>>,
    },
    /// Anything else (control flow, patterns, macros, closures): the
    /// children are parsed and visited, the node itself has no unit.
    Other(Vec<Expr>),
}

#[derive(Debug, Clone)]
pub struct Expr {
    pub kind: ExprKind,
    /// Byte span over the source, delimiters included.
    pub span: (usize, usize),
    /// Anchor line for findings: the operator's line for `Binary`, the
    /// first token's line otherwise.
    pub line: u32,
}

impl Expr {
    fn new(kind: ExprKind, span: (usize, usize), line: u32) -> Expr {
        Expr { kind, span, line }
    }

    /// Immediate children, for generic traversal.
    pub fn children(&self) -> Vec<&Expr> {
        match &self.kind {
            ExprKind::Num(_) | ExprKind::Str(_) | ExprKind::Path(_) => Vec::new(),
            ExprKind::Unary { rhs, .. } => vec![rhs],
            ExprKind::Binary { lhs, rhs, .. } => vec![lhs, rhs],
            ExprKind::Cast(e) => vec![e],
            ExprKind::Call { args, .. } => args.iter().collect(),
            ExprKind::Method { recv, args, .. } => {
                let mut v: Vec<&Expr> = vec![recv];
                v.extend(args.iter());
                v
            }
            ExprKind::Field { recv, .. } => vec![recv],
            ExprKind::Index { recv, args } => {
                let mut v: Vec<&Expr> = vec![recv];
                v.extend(args.iter());
                v
            }
            ExprKind::Tuple(xs) | ExprKind::Block(xs) | ExprKind::Other(xs) => xs.iter().collect(),
            ExprKind::StructLit { fields, .. } => {
                fields.iter().filter_map(|(_, e)| e.as_ref()).collect()
            }
            ExprKind::Let { init, .. } => init.iter().map(|b| b.as_ref()).collect(),
        }
    }
}

/// Parse the token range `code[lo..hi)` as a statement sequence.
pub fn parse_stmts(code: &[Tok], lo: usize, hi: usize) -> Vec<Expr> {
    let hi = hi.min(code.len());
    let mut p = P { t: code, i: lo.min(hi), hi };
    p.stmts()
}

/// Parse a whole token slice (fixtures, property tests).
pub fn parse_all(code: &[Tok]) -> Vec<Expr> {
    parse_stmts(code, 0, code.len())
}

/// Fold integer/float arithmetic (`+ - *` and non-zero `/`) — the
/// property-test oracle target.  `None` on any non-arithmetic node.
pub fn eval(e: &Expr) -> Option<f64> {
    match &e.kind {
        ExprKind::Num(v) => *v,
        ExprKind::Unary { op: '-', rhs } => eval(rhs).map(|v| -v),
        ExprKind::Binary { op, lhs, rhs } => {
            let a = eval(lhs)?;
            let b = eval(rhs)?;
            match op {
                BinOp::Add => Some(a + b),
                BinOp::Sub => Some(a - b),
                BinOp::Mul => Some(a * b),
                BinOp::Div if b != 0.0 => Some(a / b),
                _ => None,
            }
        }
        _ => None,
    }
}

fn parse_num(text: &str) -> Option<f64> {
    let t = text.replace('_', "");
    for suf in [
        "f64", "f32", "usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16",
        "u8", "i8",
    ] {
        if let Some(p) = t.strip_suffix(suf) {
            return p.parse().ok();
        }
    }
    if let Some(h) = t.strip_prefix("0x") {
        return u64::from_str_radix(h, 16).ok().map(|v| v as f64);
    }
    if let Some(o) = t.strip_prefix("0o") {
        return u64::from_str_radix(o, 8).ok().map(|v| v as f64);
    }
    if let Some(b) = t.strip_prefix("0b") {
        return u64::from_str_radix(b, 2).ok().map(|v| v as f64);
    }
    t.parse().ok()
}

fn bp(op: BinOp) -> (u8, u8) {
    match op {
        BinOp::Mul | BinOp::Div | BinOp::Rem => (80, 81),
        BinOp::Add | BinOp::Sub => (70, 71),
        BinOp::Shl | BinOp::Shr => (60, 61),
        BinOp::BitAnd => (56, 57),
        BinOp::BitXor => (54, 55),
        BinOp::BitOr => (52, 53),
        BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge | BinOp::Eq | BinOp::Ne => (40, 41),
        BinOp::And => (30, 31),
        BinOp::Or => (25, 26),
        BinOp::Range => (20, 21),
        BinOp::Assign
        | BinOp::AddAssign
        | BinOp::SubAssign
        | BinOp::MulAssign
        | BinOp::DivAssign
        | BinOp::RemAssign => (10, 9),
    }
}

/// Glued operator table, longest first.  `None` marks `->` / `=>`,
/// which terminate the expression rather than continuing it.
const GLUED_OPS: &[(&str, Option<BinOp>)] = &[
    ("..=", Some(BinOp::Range)),
    ("<<=", Some(BinOp::Shl)),
    (">>=", Some(BinOp::Shr)),
    ("->", None),
    ("=>", None),
    ("==", Some(BinOp::Eq)),
    ("!=", Some(BinOp::Ne)),
    ("<=", Some(BinOp::Le)),
    (">=", Some(BinOp::Ge)),
    ("&&", Some(BinOp::And)),
    ("||", Some(BinOp::Or)),
    ("<<", Some(BinOp::Shl)),
    (">>", Some(BinOp::Shr)),
    ("+=", Some(BinOp::AddAssign)),
    ("-=", Some(BinOp::SubAssign)),
    ("*=", Some(BinOp::MulAssign)),
    ("/=", Some(BinOp::DivAssign)),
    ("%=", Some(BinOp::RemAssign)),
    ("&=", Some(BinOp::BitAnd)),
    ("|=", Some(BinOp::BitOr)),
    ("^=", Some(BinOp::BitXor)),
    ("..", Some(BinOp::Range)),
    ("+", Some(BinOp::Add)),
    ("-", Some(BinOp::Sub)),
    ("*", Some(BinOp::Mul)),
    ("/", Some(BinOp::Div)),
    ("%", Some(BinOp::Rem)),
    ("<", Some(BinOp::Lt)),
    (">", Some(BinOp::Gt)),
    ("=", Some(BinOp::Assign)),
    ("&", Some(BinOp::BitAnd)),
    ("|", Some(BinOp::BitOr)),
    ("^", Some(BinOp::BitXor)),
];

struct P<'a> {
    t: &'a [Tok],
    i: usize,
    hi: usize,
}

impl<'a> P<'a> {
    fn cur(&self) -> Option<&'a Tok> {
        if self.i < self.hi {
            self.t.get(self.i)
        } else {
            None
        }
    }

    fn at(&self, k: usize) -> Option<&'a Tok> {
        if k < self.hi {
            self.t.get(k)
        } else {
            None
        }
    }

    fn at_punct(&self, c: char) -> bool {
        self.cur().is_some_and(|t| t.is_punct(c))
    }

    /// Up to three adjacent punct chars starting at the cursor.
    fn glued(&self) -> String {
        let mut s = String::new();
        let mut prev_end = 0usize;
        let mut k = self.i;
        while k < self.hi && k < self.i + 3 {
            let Some(t) = self.at(k) else { break };
            if t.kind != TokKind::Punct || (k > self.i && t.start != prev_end) {
                break;
            }
            s.push_str(&t.text);
            prev_end = t.end;
            k += 1;
        }
        s
    }

    /// `(op, token_count)` if the cursor sits on an infix operator;
    /// `->` / `=>` and non-operator puncts return `None`.
    fn infix_op(&self) -> Option<(BinOp, usize)> {
        let s = self.glued();
        if s.is_empty() {
            return None;
        }
        for &(pat, op) in GLUED_OPS {
            if s.starts_with(pat) {
                return op.map(|o| (o, pat.len()));
            }
        }
        None
    }

    /// Cursor sits on a glued `::`.
    fn at_path_sep(&self) -> bool {
        self.glued().starts_with("::")
    }

    /// Token index of the closer matching `self.t[open]`, counting only
    /// this delimiter pair; `hi` when unbalanced.
    fn matching(&self, open: usize, oc: char, cc: char) -> usize {
        let mut depth = 0i32;
        let mut k = open;
        while k < self.hi {
            let Some(t) = self.at(k) else { break };
            if t.is_punct(oc) {
                depth += 1;
            } else if t.is_punct(cc) {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            k += 1;
        }
        self.hi
    }

    fn span_to(&self, start: usize, last_tok: usize) -> (usize, usize) {
        let end = self
            .t
            .get(last_tok.min(self.hi.saturating_sub(1)))
            .map_or(start, |t| t.end);
        (start, end.max(start))
    }

    /// Skip past a `<...>` generic-argument list starting at `<`; bails
    /// at `;` / `{` so a stray comparison cannot swallow the file.
    fn skip_angles(&mut self) {
        let mut depth = 0i32;
        while let Some(t) = self.cur() {
            if t.is_punct('<') {
                depth += 1;
            } else if t.is_punct('>') {
                let arrow = self.i >= 1 && self.t.get(self.i - 1).is_some_and(|p| p.is_punct('-'));
                if !arrow {
                    depth -= 1;
                    if depth == 0 {
                        self.i += 1;
                        return;
                    }
                }
            } else if t.is_punct('{') || t.is_punct(';') {
                return;
            }
            self.i += 1;
        }
    }

    /// Statement sequence until the range ends: expressions separated by
    /// `;` / `,` / stray closers; anything unparseable is skipped one
    /// token at a time.
    fn stmts(&mut self) -> Vec<Expr> {
        let mut out = Vec::new();
        while self.i < self.hi {
            let before = self.i;
            if let Some(e) = self.expr_bp(0, false) {
                out.push(e);
            }
            if self.i == before {
                self.i += 1;
            }
        }
        out
    }

    /// Comma-separated expression list inside a delimited region.
    fn list(&mut self) -> Vec<Expr> {
        self.stmts()
    }

    fn expr_bp(&mut self, min_bp: u8, no_struct: bool) -> Option<Expr> {
        let mut lhs = self.atom(no_struct)?;
        loop {
            if self.i >= self.hi {
                break;
            }
            // postfix: field / method / call / index / try / cast
            if self.at_punct('.') && !self.glued().starts_with("..") {
                let Some(next) = self.at(self.i + 1) else {
                    self.i += 1;
                    break;
                };
                if next.kind == TokKind::Ident && next.text != "await" {
                    let name = next.text.clone();
                    self.i += 2;
                    if self.at_path_sep() {
                        // turbofish: `.collect::<Vec<_>>()`
                        self.i += 2;
                        if self.at_punct('<') {
                            self.skip_angles();
                        }
                    }
                    if self.at_punct('(') {
                        let close = self.matching(self.i, '(', ')');
                        let args = self.sub(self.i + 1, close);
                        let span = self.span_to(lhs.span.0, close);
                        self.i = (close + 1).min(self.hi);
                        let line = lhs.line;
                        lhs = Expr::new(
                            ExprKind::Method { recv: Box::new(lhs), name, args },
                            span,
                            line,
                        );
                    } else {
                        let span = (lhs.span.0, next.end);
                        let line = lhs.line;
                        lhs = Expr::new(ExprKind::Field { recv: Box::new(lhs), name }, span, line);
                    }
                    continue;
                }
                // `.await` / `.0` tuple index: unit-opaque passthrough node
                let span = (lhs.span.0, next.end);
                let line = lhs.line;
                self.i += 2;
                lhs = Expr::new(ExprKind::Other(vec![lhs]), span, line);
                continue;
            }
            if self.at_punct('?') {
                if let Some(t) = self.cur() {
                    lhs.span.1 = lhs.span.1.max(t.end);
                }
                self.i += 1;
                continue;
            }
            if self.at_punct('(') {
                let close = self.matching(self.i, '(', ')');
                let args = self.sub(self.i + 1, close);
                let span = self.span_to(lhs.span.0, close);
                self.i = (close + 1).min(self.hi);
                let line = lhs.line;
                lhs = match lhs.kind {
                    ExprKind::Path(path) => Expr::new(ExprKind::Call { path, args }, span, line),
                    _ => {
                        let mut kids = vec![lhs];
                        kids.extend(args);
                        Expr::new(ExprKind::Other(kids), span, line)
                    }
                };
                continue;
            }
            if self.at_punct('[') {
                let close = self.matching(self.i, '[', ']');
                let args = self.sub(self.i + 1, close);
                let span = self.span_to(lhs.span.0, close);
                self.i = (close + 1).min(self.hi);
                let line = lhs.line;
                lhs = Expr::new(ExprKind::Index { recv: Box::new(lhs), args }, span, line);
                continue;
            }
            if self.cur().is_some_and(|t| t.is_ident("as")) {
                self.i += 1;
                let last = self.skip_type();
                let span = self.span_to(lhs.span.0, last);
                let line = lhs.line;
                lhs = Expr::new(ExprKind::Cast(Box::new(lhs)), span, line);
                continue;
            }
            // struct literal after a path atom
            if self.at_punct('{') && !no_struct {
                if let ExprKind::Path(path) = &lhs.kind {
                    let upper = path
                        .last()
                        .and_then(|s| s.chars().next())
                        .is_some_and(char::is_uppercase);
                    if upper {
                        let path = path.clone();
                        let close = self.matching(self.i, '{', '}');
                        let fields = self.struct_fields(self.i + 1, close);
                        let span = self.span_to(lhs.span.0, close);
                        self.i = (close + 1).min(self.hi);
                        let line = lhs.line;
                        lhs = Expr::new(ExprKind::StructLit { path, fields }, span, line);
                        continue;
                    }
                }
                break;
            }
            // macro invocation: `path!(...)` / `path![...]` / `path! {...}`
            if self.at_punct('!') && matches!(lhs.kind, ExprKind::Path(_)) {
                let delim = self.at(self.i + 1).map(|t| t.text.clone());
                let (oc, cc) = match delim.as_deref() {
                    Some("(") => ('(', ')'),
                    Some("[") => ('[', ']'),
                    Some("{") => ('{', '}'),
                    _ => break, // `a != b` and friends: not a macro
                };
                let close = self.matching(self.i + 1, oc, cc);
                let kids = self.sub(self.i + 2, close);
                let span = self.span_to(lhs.span.0, close);
                self.i = (close + 1).min(self.hi);
                let line = lhs.line;
                lhs = Expr::new(ExprKind::Other(kids), span, line);
                continue;
            }

            let Some((op, ntoks)) = self.infix_op() else { break };
            let (lbp, rbp) = bp(op);
            if lbp < min_bp {
                break;
            }
            let op_line = self.cur().map_or(lhs.line, |t| t.line);
            self.i += ntoks;
            let Some(rhs) = self.expr_bp(rbp, no_struct) else { break };
            let span = (lhs.span.0, rhs.span.1.max(lhs.span.1));
            lhs = Expr::new(
                ExprKind::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                span,
                op_line,
            );
        }
        Some(lhs)
    }

    /// Parse `code[lo..close)` with a fresh sub-parser (delimited region).
    fn sub(&self, lo: usize, close: usize) -> Vec<Expr> {
        let hi = close.min(self.hi);
        let mut p = P { t: self.t, i: lo.min(hi), hi };
        p.list()
    }

    /// Skip a type after `as` / in ascriptions; returns the last token
    /// index consumed (for spans).
    fn skip_type(&mut self) -> usize {
        let mut last = self.i.saturating_sub(1);
        while self.at_punct('&') || self.at_punct('*') {
            last = self.i;
            self.i += 1;
        }
        loop {
            match self.cur() {
                Some(t) if t.kind == TokKind::Ident && !KW_STMT.contains(&t.text.as_str()) => {
                    last = self.i;
                    self.i += 1;
                }
                _ => break,
            }
            if self.at_path_sep() {
                self.i += 2;
                continue;
            }
            if self.at_punct('<') {
                self.skip_angles();
                last = self.i.saturating_sub(1);
                if self.at_path_sep() {
                    self.i += 2;
                    continue;
                }
            }
            break;
        }
        last
    }

    fn struct_fields(&self, lo: usize, close: usize) -> Vec<(String, Option<Expr>)> {
        let hi = close.min(self.hi);
        let mut p = P { t: self.t, i: lo.min(hi), hi };
        let mut out = Vec::new();
        while p.i < p.hi {
            if p.at_punct(',') {
                p.i += 1;
                continue;
            }
            if p.glued().starts_with("..") {
                p.i += 2;
                let rest = p.expr_bp(0, false);
                out.push(("..".to_string(), rest));
                continue;
            }
            let Some(t) = p.cur() else { break };
            if t.kind == TokKind::Ident && !KW_STMT.contains(&t.text.as_str()) {
                let name = t.text.clone();
                p.i += 1;
                if p.at_punct(':') && !p.at_path_sep() {
                    p.i += 1;
                    let val = p.expr_bp(0, false);
                    out.push((name, val));
                } else {
                    out.push((name, None));
                }
            } else {
                p.i += 1;
            }
        }
        out
    }

    fn atom(&mut self, no_struct: bool) -> Option<Expr> {
        let t = self.cur()?;
        let (start, line) = (t.start, t.line);
        match t.kind {
            TokKind::Num => {
                self.i += 1;
                Some(Expr::new(ExprKind::Num(parse_num(&t.text)), (t.start, t.end), line))
            }
            TokKind::Str => {
                self.i += 1;
                Some(Expr::new(ExprKind::Str(t.text.clone()), (t.start, t.end), line))
            }
            TokKind::Char | TokKind::Lifetime => {
                self.i += 1;
                Some(Expr::new(ExprKind::Other(Vec::new()), (t.start, t.end), line))
            }
            TokKind::Comment => {
                // code_tokens strips comments; raw streams skip them
                self.i += 1;
                None
            }
            TokKind::Punct => self.punct_atom(t, start, line, no_struct),
            TokKind::Ident => self.ident_atom(t, start, line, no_struct),
        }
    }

    fn punct_atom(&mut self, t: &Tok, start: usize, line: u32, no_struct: bool) -> Option<Expr> {
        let c = t.text.chars().next().unwrap_or('\0');
        match c {
            '-' | '!' | '*' | '&' => {
                self.i += 1;
                // `&&x` (double reference) and `&mut x`
                if c == '&' && self.at_punct('&') {
                    self.i += 1;
                }
                if c == '&' && self.cur().is_some_and(|t| t.is_ident("mut")) {
                    self.i += 1;
                }
                let rhs = self.expr_bp(85, no_struct);
                match rhs {
                    Some(r) => {
                        let span = (start, r.span.1.max(t.end));
                        Some(Expr::new(ExprKind::Unary { op: c, rhs: Box::new(r) }, span, line))
                    }
                    None => Some(Expr::new(ExprKind::Other(Vec::new()), (start, t.end), line)),
                }
            }
            '(' => {
                let close = self.matching(self.i, '(', ')');
                let mut kids = self.sub(self.i + 1, close);
                let span = self.span_to(start, close);
                self.i = (close + 1).min(self.hi);
                if kids.len() == 1 {
                    let mut inner = kids.remove(0);
                    // widen over the parens; children stay nested
                    inner.span = (span.0.min(inner.span.0), span.1.max(inner.span.1));
                    Some(inner)
                } else {
                    Some(Expr::new(ExprKind::Tuple(kids), span, line))
                }
            }
            '[' => {
                let close = self.matching(self.i, '[', ']');
                let kids = self.sub(self.i + 1, close);
                let span = self.span_to(start, close);
                self.i = (close + 1).min(self.hi);
                Some(Expr::new(ExprKind::Other(kids), span, line))
            }
            '{' => Some(self.block(line)),
            '|' => {
                // closure: skip params to the matching `|`, parse the body
                self.i += 1;
                if self.at_punct('|') {
                    self.i += 1; // `||` zero-param closure
                } else {
                    while self.i < self.hi && !self.at_punct('|') {
                        self.i += 1;
                    }
                    if self.at_punct('|') {
                        self.i += 1;
                    }
                }
                if self.glued().starts_with("->") {
                    self.i += 2;
                    self.skip_type();
                }
                let body = self.expr_bp(0, no_struct);
                let (span, kids) = match body {
                    Some(b) => ((start, b.span.1), vec![b]),
                    None => ((start, t.end), Vec::new()),
                };
                Some(Expr::new(ExprKind::Other(kids), span, line))
            }
            '#' => {
                // attribute: skip `#[...]` / `#![...]`, then retry
                self.i += 1;
                if self.at_punct('!') {
                    self.i += 1;
                }
                if self.at_punct('[') {
                    let close = self.matching(self.i, '[', ']');
                    self.i = (close + 1).min(self.hi);
                    self.atom(no_struct)
                } else {
                    Some(Expr::new(ExprKind::Other(Vec::new()), (start, t.end), line))
                }
            }
            '.' if self.glued().starts_with("..") => {
                // prefix range `..x` / `..=x`
                self.i += if self.glued().starts_with("..=") { 3 } else { 2 };
                let rest = self.expr_bp(21, no_struct);
                let (span, kids) = match rest {
                    Some(r) => ((start, r.span.1), vec![r]),
                    None => ((start, t.end), Vec::new()),
                };
                Some(Expr::new(ExprKind::Other(kids), span, line))
            }
            _ => None, // `;` `,` `)` `]` `}` `:` … — caller advances
        }
    }

    fn ident_atom(&mut self, t: &Tok, start: usize, line: u32, no_struct: bool) -> Option<Expr> {
        match t.text.as_str() {
            "if" | "while" => self.cond_block(start, line, no_struct, t.text == "if"),
            "for" => {
                self.i += 1;
                // skip the pattern to `in`, bounded by the body opener
                while self.i < self.hi {
                    let Some(c) = self.cur() else { break };
                    if c.is_ident("in") || c.is_punct('{') || c.is_punct(';') {
                        break;
                    }
                    self.i += 1;
                }
                let mut kids = Vec::new();
                if self.cur().is_some_and(|c| c.is_ident("in")) {
                    self.i += 1;
                    if let Some(iter) = self.expr_bp(0, true) {
                        kids.push(iter);
                    }
                }
                if self.at_punct('{') {
                    kids.push(self.block(line));
                }
                let end = kids.last().map_or(t.end, |k| k.span.1);
                Some(Expr::new(ExprKind::Other(kids), (start, end), line))
            }
            "loop" => {
                self.i += 1;
                let kids = if self.at_punct('{') { vec![self.block(line)] } else { Vec::new() };
                let end = kids.last().map_or(t.end, |k| k.span.1);
                Some(Expr::new(ExprKind::Other(kids), (start, end), line))
            }
            "match" => {
                self.i += 1;
                let mut kids = Vec::new();
                if let Some(scrut) = self.expr_bp(0, true) {
                    kids.push(scrut);
                }
                if self.at_punct('{') {
                    // arms parse as generic statements: patterns become
                    // harmless unit-less exprs, `=>` terminates them
                    kids.push(self.block(line));
                }
                let end = kids.last().map_or(t.end, |k| k.span.1);
                Some(Expr::new(ExprKind::Other(kids), (start, end), line))
            }
            "let" => self.let_stmt(start, line),
            "return" | "break" => {
                self.i += 1;
                let kids: Vec<Expr> = self.expr_bp(0, no_struct).into_iter().collect();
                let end = kids.last().map_or(t.end, |k| k.span.1);
                Some(Expr::new(ExprKind::Other(kids), (start, end), line))
            }
            "continue" | "true" | "false" => {
                self.i += 1;
                Some(Expr::new(ExprKind::Other(Vec::new()), (start, t.end), line))
            }
            "move" | "unsafe" | "async" => {
                self.i += 1;
                self.atom(no_struct)
            }
            s if KW_STMT.contains(&s) => {
                // item keywords inside bodies (`fn`, `const`, `use`, …):
                // consume the keyword, let the statement loop resume
                self.i += 1;
                Some(Expr::new(ExprKind::Other(Vec::new()), (start, t.end), line))
            }
            _ => {
                // path: `a::b::c` with turbofish skipping
                let mut segs = vec![t.text.clone()];
                let mut end = t.end;
                self.i += 1;
                while self.at_path_sep() {
                    self.i += 2;
                    if self.at_punct('<') {
                        self.skip_angles();
                        continue;
                    }
                    match self.cur() {
                        Some(n) if n.kind == TokKind::Ident => {
                            segs.push(n.text.clone());
                            end = n.end;
                            self.i += 1;
                        }
                        _ => break,
                    }
                }
                Some(Expr::new(ExprKind::Path(segs), (start, end), line))
            }
        }
    }

    /// `if cond { … } else …` / `while cond { … }`.
    fn cond_block(&mut self, start: usize, line: u32, ns: bool, has_else: bool) -> Option<Expr> {
        self.i += 1;
        let mut kids = Vec::new();
        if let Some(cond) = self.expr_bp(0, true) {
            kids.push(cond);
        }
        if self.at_punct('{') {
            kids.push(self.block(line));
        }
        if has_else && self.cur().is_some_and(|c| c.is_ident("else")) {
            self.i += 1;
            if let Some(e) = self.atom(ns) {
                kids.push(e);
            }
        }
        let end = kids.last().map_or(start, |k| k.span.1);
        Some(Expr::new(ExprKind::Other(kids), (start, end.max(start)), line))
    }

    /// Block at the cursor's `{`.
    fn block(&mut self, line: u32) -> Expr {
        let open = self.i;
        let start = self.t.get(open).map_or(0, |t| t.start);
        let close = self.matching(open, '{', '}');
        let kids = self.sub(open + 1, close);
        let span = self.span_to(start, close);
        self.i = (close + 1).min(self.hi);
        Expr::new(ExprKind::Block(kids), span, line)
    }

    /// `let <ident>[: ty] = init` — or a pattern let, degraded to Other.
    fn let_stmt(&mut self, start: usize, line: u32) -> Option<Expr> {
        self.i += 1;
        if self.cur().is_some_and(|t| t.is_ident("mut")) {
            self.i += 1;
        }
        let simple = match (self.cur(), self.at(self.i + 1)) {
            (Some(n), Some(after))
                if n.kind == TokKind::Ident
                    && !KW_STMT.contains(&n.text.as_str())
                    && (after.is_punct('=') || (after.is_punct(':') && !{
                        // `::` would make this a path pattern
                        self.t
                            .get(self.i + 2)
                            .is_some_and(|c| c.is_punct(':') && c.start == after.end)
                    })) =>
            {
                Some((n.text.clone(), after.is_punct(':')))
            }
            _ => None,
        };
        if let Some((name, has_ty)) = simple {
            self.i += 1;
            let mut ty = None;
            if has_ty {
                self.i += 1; // `:`
                // first identifier of the ascribed type
                if let Some(tt) = self.cur() {
                    if tt.kind == TokKind::Ident {
                        ty = Some(tt.text.clone());
                    }
                }
                // skip to `=` / `;` at this statement level
                while self.i < self.hi {
                    let Some(c) = self.cur() else { break };
                    if c.is_punct('=') || c.is_punct(';') || c.is_punct('{') {
                        break;
                    }
                    self.i += 1;
                }
            }
            let mut init = None;
            let mut end = start;
            if self.at_punct('=') && self.infix_op() == Some((BinOp::Assign, 1)) {
                self.i += 1;
                if let Some(e) = self.expr_bp(0, false) {
                    end = e.span.1;
                    init = Some(Box::new(e));
                }
            }
            return Some(Expr::new(
                ExprKind::Let { name, ty, init },
                (start, end.max(start)),
                line,
            ));
        }
        // pattern let: parse the pattern and the initializer generically
        let mut kids = Vec::new();
        if let Some(pat) = self.expr_bp(11, false) {
            kids.push(pat);
        }
        if self.at_punct('=') && self.infix_op() == Some((BinOp::Assign, 1)) {
            self.i += 1;
            if let Some(e) = self.expr_bp(0, false) {
                kids.push(e);
            }
        }
        let end = kids.last().map_or(start, |k| k.span.1);
        Some(Expr::new(ExprKind::Other(kids), (start, end.max(start)), line))
    }
}

/// Item/binding keywords that never start a value expression.
const KW_STMT: &[&str] = &[
    "as", "box", "const", "crate", "dyn", "else", "enum", "extern", "fn", "impl", "in", "mod",
    "mut", "pub", "ref", "static", "struct", "super", "trait", "type", "use", "where", "yield",
];

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::analysis::lexer::{code_tokens, tokenize};

    fn parse1(src: &str) -> Expr {
        let toks = tokenize(src);
        let code = code_tokens(&toks);
        let mut all = parse_all(&code);
        assert!(!all.is_empty(), "no expr parsed from {src:?}");
        all.remove(0)
    }

    #[test]
    fn precedence_mul_binds_tighter_than_add() {
        let e = parse1("1 + 2 * 3");
        assert_eq!(eval(&e), Some(7.0));
        let e = parse1("(1 + 2) * 3");
        assert_eq!(eval(&e), Some(9.0));
        let e = parse1("2 * 3 - 10 / 5");
        assert_eq!(eval(&e), Some(4.0));
        let e = parse1("-4 + 6");
        assert_eq!(eval(&e), Some(2.0));
    }

    #[test]
    fn glued_operators_resolve_longest_first() {
        let e = parse1("a <= b");
        match &e.kind {
            ExprKind::Binary { op, .. } => assert_eq!(*op, BinOp::Le),
            k => panic!("{k:?}"),
        }
        let e = parse1("a += b");
        match &e.kind {
            ExprKind::Binary { op, .. } => assert_eq!(*op, BinOp::AddAssign),
            k => panic!("{k:?}"),
        }
        // `a != b` must not parse as a macro invocation
        let e = parse1("a != (b)");
        match &e.kind {
            ExprKind::Binary { op, .. } => assert_eq!(*op, BinOp::Ne),
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn method_chain_and_fields() {
        let e = parse1("self.cfg.margin.mj()");
        match &e.kind {
            ExprKind::Method { recv, name, args } => {
                assert_eq!(name, "mj");
                assert!(args.is_empty());
                match &recv.kind {
                    ExprKind::Field { name, .. } => assert_eq!(name, "margin"),
                    k => panic!("{k:?}"),
                }
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn call_paths_and_turbofish() {
        let e = parse1("Secs::from_ms(40.0)");
        match &e.kind {
            ExprKind::Call { path, args } => {
                assert_eq!(path, &["Secs", "from_ms"]);
                assert_eq!(args.len(), 1);
            }
            k => panic!("{k:?}"),
        }
        let e = parse1("xs.iter().collect::<Vec<_>>()");
        match &e.kind {
            ExprKind::Method { name, .. } => assert_eq!(name, "collect"),
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn struct_literal_fields_parse() {
        let e = parse1("Rec { before_mj: d.before.mj(), drift, ..base }");
        match &e.kind {
            ExprKind::StructLit { path, fields } => {
                assert_eq!(path, &["Rec"]);
                assert_eq!(fields.len(), 3);
                assert_eq!(fields[0].0, "before_mj");
                assert!(fields[0].1.is_some());
                assert_eq!(fields[1].0, "drift");
                assert!(fields[1].1.is_none());
                assert_eq!(fields[2].0, "..");
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn let_binding_with_type_and_init() {
        let e = parse1("let t: Secs = gap.max(Secs(1e-12));");
        match &e.kind {
            ExprKind::Let { name, ty, init } => {
                assert_eq!(name, "t");
                assert_eq!(ty.as_deref(), Some("Secs"));
                assert!(init.is_some());
            }
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn control_flow_children_are_visited() {
        let src = "if a_ms > b_s { x } else { y }";
        let e = parse1(src);
        let ExprKind::Other(kids) = &e.kind else {
            panic!("{:?}", e.kind)
        };
        assert!(matches!(kids[0].kind, ExprKind::Binary { op: BinOp::Gt, .. }));
    }

    fn assert_nested(e: &Expr, src_len: usize) {
        assert!(e.span.0 <= e.span.1 && e.span.1 <= src_len, "{:?}", e.span);
        for c in e.children() {
            assert!(
                c.span.0 >= e.span.0 && c.span.1 <= e.span.1,
                "child {:?} escapes parent {:?}",
                c.span,
                e.span
            );
            assert_nested(c, src_len);
        }
    }

    #[test]
    fn spans_are_in_bounds_and_nested() {
        let src = "fn f() { let x_mj = (a + b.c()) * d[2]; vec![x_mj, 1.0] }";
        let toks = tokenize(src);
        let code = code_tokens(&toks);
        for e in parse_all(&code) {
            assert_nested(&e, src.len());
        }
    }

    #[test]
    fn parse_is_total_on_junk() {
        for src in [
            "} ) ] ;;; ..= => -> :::: <<>>",
            "let let let = = =",
            "a.b.(((",
            "match { { { |",
            "#[x] #![y] 'a 'b \"unterminated",
        ] {
            let toks = tokenize(src);
            let code = code_tokens(&toks);
            let _ = parse_all(&code); // totality: must not panic or hang
        }
    }
}
