//! Lock-discipline analysis over `util::sync::locked` guard live-ranges.
//!
//! The repo's one blessed mutex entry point is `locked(&mutex)` (poison
//! recovery built in), which makes lexical guard tracking tractable: a
//! guard bound with `let g = locked(&x);` lives to the end of its
//! enclosing block, a temporary `locked(&x).field` lives to the end of
//! its statement.  Two rule families run over those live ranges:
//!
//! * **lock-order** — the graph-wide acquisition-order relation (direct
//!   lexical nesting plus transitive acquisitions through resolved call
//!   edges) must be consistent: if some path takes `a` then `b` and
//!   another takes `b` then `a`, the pair can deadlock under
//!   concurrency;
//! * **lock-blocking** — serving-scope code must not call a potentially
//!   unbounded blocking primitive (`send`/`recv`/`join`/`sleep`/…)
//!   while a guard is live; a worker stalled inside a critical section
//!   stalls every thread behind the lock.
//!
//! The lock identifier is lexical — the last field/binding name of the
//! `locked(...)` argument — so two fields named `inner` on different
//! structs alias into one lock id.  That is deliberately conservative
//! for ordering (a false edge can only demand *more* consistency) and is
//! kept honest by the repo's naming: lock fields carry distinct names.

use super::rules::{Finding, LOCK_BLOCKING, LOCK_ORDER};
use super::lexer::Tok;
use super::symbols::Sym;
use std::collections::{BTreeMap, BTreeSet};

/// Call names treated as potentially unbounded blocking primitives when
/// they appear (as a bare or method call) inside a guard's live range.
pub const BLOCKING_NAMES: &[&str] = &[
    "join",
    "recv",
    "recv_timeout",
    "send",
    "sleep",
    "spawn_worker",
    "wait",
];

/// One `locked(...)` acquisition inside a function body.
#[derive(Debug, Clone)]
pub struct LockAcq {
    /// Lexical lock id: last field/binding ident of the argument.
    pub lock: String,
    /// Code-token index of the `locked` ident.
    pub acq_idx: usize,
    pub acq_line: u32,
    /// Last code-token index at which the guard is live.
    pub live_end: usize,
    /// `let g = locked(...);` (block-scoped) vs a temporary
    /// (statement-scoped).
    pub bound: bool,
    /// The argument expression, for diagnostics.
    pub expr: String,
}

/// Index of the `;` ending the statement containing token `i` (at
/// relative depth 0), or the close of the enclosing block, or `hi`.
fn find_statement_end(code: &[Tok], mut i: usize, hi: usize) -> usize {
    let mut depth = 0i32;
    while i <= hi {
        let Some(t) = code.get(i) else { break };
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return i;
            }
        } else if t.is_punct(';') && depth == 0 {
            return i;
        }
        i += 1;
    }
    hi
}

/// Index of the `}` closing the innermost block containing `start`,
/// or `hi`.
fn enclosing_block_end(code: &[Tok], start: usize, hi: usize) -> usize {
    let mut depth = 0i32;
    let mut j = start;
    while j <= hi {
        let Some(t) = code.get(j) else { break };
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            if depth == 0 {
                return j;
            }
            depth -= 1;
        }
        j += 1;
    }
    hi
}

/// Extract every `locked(...)` acquisition in `sym`'s body with its
/// guard live-range.
pub fn extract_locks(code: &[Tok], sym: &Sym) -> Vec<LockAcq> {
    let (lo, hi) = sym.body;
    let mut out = Vec::new();
    let mut i = lo;
    while i <= hi {
        let hit = code.get(i).is_some_and(|t| t.is_ident("locked"))
            && i + 1 <= hi
            && code.get(i + 1).is_some_and(|t| t.is_punct('('));
        if !hit {
            i += 1;
            continue;
        }
        // collect the argument expression to the matching ')'
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut arg: Vec<String> = Vec::new();
        while j <= hi {
            let Some(t) = code.get(j) else { break };
            if t.is_punct('(') {
                depth += 1;
            } else if t.is_punct(')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if depth >= 1 {
                arg.push(t.text.clone());
            }
            j += 1;
        }
        let close = j;
        let lock_id = arg
            .iter()
            .rev()
            .find(|a| !matches!(a.as_str(), "&" | "mut" | "*" | "." | "self" | "(" | ")"))
            .cloned()
            .unwrap_or_else(|| arg.concat());
        // bound guard: `= locked(...);` with a non-`_` binding
        let mut bound = i >= 1
            && code.get(i - 1).is_some_and(|t| t.is_punct('='))
            && close + 1 <= hi
            && code.get(close + 1).is_some_and(|t| t.is_punct(';'));
        if bound && i >= 2 && code.get(i - 2).is_some_and(|t| t.is_ident("_")) {
            bound = false;
        }
        let live_end = if bound {
            enclosing_block_end(code, close + 2, hi)
        } else {
            find_statement_end(code, close + 1, hi)
        };
        out.push(LockAcq {
            lock: lock_id,
            acq_idx: i,
            acq_line: code.get(i).map(|t| t.line).unwrap_or(0),
            live_end,
            bound,
            expr: arg.concat(),
        });
        i = close + 1;
    }
    out
}

/// Transitive acquisition sets: for each function, the lock ids it (or
/// anything it transitively calls through resolved edges) may acquire.
fn compute_acq_sets(
    locks: &BTreeMap<String, Vec<LockAcq>>,
    edges: &BTreeMap<String, Vec<(String, u32)>>,
) -> BTreeMap<String, BTreeSet<String>> {
    let mut acq: BTreeMap<String, BTreeSet<String>> = locks
        .iter()
        .map(|(p, lks)| (p.clone(), lks.iter().map(|l| l.lock.clone()).collect()))
        .collect();
    loop {
        let mut changed = false;
        for (caller, outs) in edges {
            for (callee, _) in outs {
                let add: BTreeSet<String> = acq.get(callee).cloned().unwrap_or_default();
                if add.is_empty() {
                    continue;
                }
                let cur = acq.entry(caller.clone()).or_default();
                let before = cur.len();
                cur.extend(add);
                if cur.len() != before {
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    acq
}

fn via_suffix(via: Option<&String>) -> String {
    via.map(|v| format!(" (via `{v}`)")).unwrap_or_default()
}

/// Run both lock rules over the whole graph.  Returns the findings
/// (suppression already resolved through `covered`) and the observed
/// acquisition-order table `(first, second, site count)` for the report.
pub fn lock_findings(
    all_syms: &BTreeMap<String, Sym>,
    locks: &BTreeMap<String, Vec<LockAcq>>,
    edges: &BTreeMap<String, Vec<(String, u32)>>,
    serving_files: &BTreeSet<String>,
    covered: &dyn Fn(&str, &str, u32) -> Option<String>,
) -> (Vec<Finding>, Vec<(String, String, usize)>) {
    let acq_sets = compute_acq_sets(locks, edges);
    // (first, second) -> acquisition sites (file, line, via-callee)
    #[allow(clippy::type_complexity)]
    let mut order: BTreeMap<(String, String), Vec<(String, u32, Option<String>)>> = BTreeMap::new();
    let mut findings: Vec<Finding> = Vec::new();

    for (p, s) in all_syms {
        let Some(lks) = locks.get(p) else { continue };
        let serving = serving_files.contains(&s.file);
        for (li, lk) in lks.iter().enumerate() {
            // direct lexical nesting: another acquisition inside the
            // guard's live range
            for (lj, lk2) in lks.iter().enumerate() {
                if li == lj {
                    continue;
                }
                if lk.acq_idx < lk2.acq_idx && lk2.acq_idx <= lk.live_end {
                    order
                        .entry((lk.lock.clone(), lk2.lock.clone()))
                        .or_default()
                        .push((s.file.clone(), lk2.acq_line, None));
                }
            }
            for rc in &s.raw_calls {
                if !(lk.acq_idx < rc.idx && rc.idx <= lk.live_end) {
                    continue;
                }
                let bare = rc.name.rsplit("::").next().unwrap_or("");
                if serving && BLOCKING_NAMES.contains(&bare) {
                    let reason = covered(LOCK_BLOCKING, &s.file, rc.line);
                    findings.push(Finding {
                        rule: LOCK_BLOCKING.to_string(),
                        file: s.file.clone(),
                        line: rc.line,
                        message: format!(
                            "`{bare}()` may block while lock '{}' (acquired at line {}) \
                             is held in `{p}` — a stalled critical section stalls every \
                             thread behind the lock",
                            lk.lock, lk.acq_line
                        ),
                        suppressed: reason.is_some(),
                        reason,
                    });
                }
                // transitive acquisitions through resolved call edges at
                // this call site
                if let Some(outs) = edges.get(p) {
                    for (callee, cl) in outs {
                        if *cl != rc.line {
                            continue;
                        }
                        for l2 in acq_sets.get(callee).into_iter().flatten() {
                            if l2 != &lk.lock {
                                order
                                    .entry((lk.lock.clone(), l2.clone()))
                                    .or_default()
                                    .push((s.file.clone(), rc.line, Some(callee.clone())));
                            }
                        }
                    }
                }
            }
        }
    }

    let mut table: Vec<(String, String, usize)> = Vec::new();
    for ((a, b), sites) in &order {
        table.push((a.clone(), b.clone(), sites.len()));
        if a >= b {
            continue;
        }
        let Some(rev_sites) = order.get(&(b.clone(), a.clone())) else {
            continue;
        };
        let Some(site) = sites.first() else { continue };
        let rev_desc = rev_sites
            .first()
            .map(|r| format!("{}:{}{}", r.0, r.1, via_suffix(r.2.as_ref())))
            .unwrap_or_else(|| "?".to_string());
        let reason = covered(LOCK_ORDER, &site.0, site.1);
        findings.push(Finding {
            rule: LOCK_ORDER.to_string(),
            file: site.0.clone(),
            line: site.1,
            message: format!(
                "inconsistent lock order: '{a}' then '{b}' at {}:{}{}, but '{b}' then \
                 '{a}' at {} — these paths can deadlock",
                site.0,
                site.1,
                via_suffix(site.2.as_ref()),
                rev_desc
            ),
            suppressed: reason.is_some(),
            reason,
        });
    }
    (findings, table)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::lexer::{code_tokens, tokenize};
    use super::super::symbols::extract_symbols;
    use super::*;

    fn locks_of(src: &str) -> Vec<LockAcq> {
        let code = code_tokens(&tokenize(src));
        let (syms, _) = extract_symbols("src/m.rs", &code);
        assert_eq!(syms.len(), 1, "{syms:?}");
        extract_locks(&code, &syms[0])
    }

    #[test]
    fn bound_guard_lives_to_block_end() {
        let src = "fn f(s: &S) -> u32 { let g = locked(&s.state); g.count += 1; g.count }";
        let lks = locks_of(src);
        assert_eq!(lks.len(), 1);
        assert!(lks[0].bound);
        assert_eq!(lks[0].lock, "state");
        // lives to the fn's closing brace
        let code = code_tokens(&tokenize(src));
        assert!(code[lks[0].live_end].is_punct('}'));
    }

    #[test]
    fn temp_guard_lives_to_statement_end() {
        let src = "fn f(s: &S) { locked(&s.state).count += 1; let x = 7; let _ = x; }";
        let lks = locks_of(src);
        assert_eq!(lks.len(), 1);
        assert!(!lks[0].bound);
        let code = code_tokens(&tokenize(src));
        assert!(code[lks[0].live_end].is_punct(';'));
        // the next statement is outside the live range
        let seven = code.iter().position(|t| t.text == "7").unwrap();
        assert!(seven > lks[0].live_end);
    }

    #[test]
    fn underscore_binding_treated_as_temp() {
        // `let _ = locked(..)` drops the guard immediately; treat as temp
        let src = "fn f(s: &S) { let _ = locked(&s.state); let y = 2; let _ = y; }";
        let lks = locks_of(src);
        assert_eq!(lks.len(), 1);
        assert!(!lks[0].bound);
    }

    #[test]
    fn lock_id_is_last_field_segment() {
        let src = "fn f(s: &S, i: usize) { let g = locked(&s.shards.queue); let _x = g; }";
        let lks = locks_of(src);
        assert_eq!(lks[0].lock, "queue");
    }

    #[test]
    fn inconsistent_nesting_order_is_flagged() {
        let ab = "fn ab(s: &S) { let g = locked(&s.alpha); let h = locked(&s.beta); \
                  let _ = (g, h); }";
        let ba = "fn ba(s: &S) { let g = locked(&s.beta); let h = locked(&s.alpha); \
                  let _ = (g, h); }";
        let mut all_syms = BTreeMap::new();
        let mut locks = BTreeMap::new();
        for (rel, src) in [("src/runtime/a.rs", ab), ("src/runtime/b.rs", ba)] {
            let code = code_tokens(&tokenize(src));
            let (syms, _) = extract_symbols(rel, &code);
            for s in syms {
                locks.insert(s.path.clone(), extract_locks(&code, &s));
                all_syms.insert(s.path.clone(), s);
            }
        }
        let edges = BTreeMap::new();
        let serving: BTreeSet<String> =
            ["src/runtime/a.rs", "src/runtime/b.rs"].iter().map(|s| s.to_string()).collect();
        let none = |_: &str, _: &str, _: u32| None;
        let (findings, table) = lock_findings(&all_syms, &locks, &edges, &serving, &none);
        assert!(findings.iter().any(|f| f.rule == LOCK_ORDER
            && f.message.contains("'alpha'")
            && f.message.contains("'beta'")), "{findings:?}");
        assert!(table.iter().any(|(a, b, _)| a == "alpha" && b == "beta"));
        assert!(table.iter().any(|(a, b, _)| a == "beta" && b == "alpha"));
    }

    #[test]
    fn blocking_call_in_live_range_flagged_in_serving_scope_only() {
        let src = "fn f(s: &S, tx: &Sender<u32>) { let g = locked(&s.state); \
                   tx.send(1); let _ = g; }";
        let code = code_tokens(&tokenize(src));
        let (syms, _) = extract_symbols("src/runtime/w.rs", &code);
        let mut all_syms = BTreeMap::new();
        let mut locks = BTreeMap::new();
        for mut s in syms {
            super::super::symbols::analyze_bodies(&code, std::slice::from_mut(&mut s), true);
            locks.insert(s.path.clone(), extract_locks(&code, &s));
            all_syms.insert(s.path.clone(), s);
        }
        let edges = BTreeMap::new();
        let none = |_: &str, _: &str, _: u32| None;
        let serving: BTreeSet<String> = ["src/runtime/w.rs".to_string()].into_iter().collect();
        let (findings, _) = lock_findings(&all_syms, &locks, &edges, &serving, &none);
        assert!(findings.iter().any(|f| f.rule == LOCK_BLOCKING && f.message.contains("send")),
            "{findings:?}");
        // same file treated as non-serving: no finding
        let not_serving = BTreeSet::new();
        let (findings, _) = lock_findings(&all_syms, &locks, &edges, &not_serving, &none);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
