//! Rule engine: per-file token-pattern rules, suppression pragmas, and
//! findings.
//!
//! Three rule families (see DESIGN.md §Static analysis):
//!
//! * determinism (`det-*`) — parity-scoped modules must not iterate hash
//!   containers, read wall clocks, or fold floats in unordered
//!   iteration order;
//! * panic surface (`panic-*`) — serving-scoped modules must not
//!   `unwrap`/`expect`/`panic!` or index slices directly;
//! * observability (`obs-*`) — serving-scoped modules must not write
//!   ad-hoc stdio (`println!`/`eprintln!`/`dbg!`); diagnostics go
//!   through the structured journal (`crate::obs`), and the one stdout
//!   use that *is* a wire protocol (the dist worker's result line)
//!   carries a reasoned pragma;
//! * pragma meta (`pragma-*`) — every suppression must name a known rule
//!   and carry a written reason; these run everywhere and are not
//!   themselves suppressible.
//!
//! The wire-hygiene family (`wire-*`) is cross-file and lives in
//! `analysis::wire`.
//!
//! Suppression grammar: `// lint: allow(<rule>) — <reason>` (an ASCII
//! `-` works too).  The pragma covers its own line and the next code
//! line, so it works both trailing a statement and on the line above.
//! `// lint: wire(<key>)` trailing a struct field declares the field's
//! wire key when it differs from the field name (`pre` encoded as
//! `tau_pre`).

use super::classify::Scope;
use super::lexer::{Tok, TokKind};
use std::collections::BTreeSet;

pub const DET_HASH_ITER: &str = "det-hash-iter";
pub const DET_UNORDERED_FOLD: &str = "det-unordered-fold";
pub const DET_WALL_CLOCK: &str = "det-wall-clock";
pub const DET_ENTROPY_RNG: &str = "det-entropy-rng";
pub const PANIC_UNWRAP: &str = "panic-unwrap";
pub const PANIC_EXPECT: &str = "panic-expect";
pub const PANIC_MACRO: &str = "panic-macro";
pub const PANIC_SLICE_INDEX: &str = "panic-slice-index";
pub const WIRE_SCHEMA_TAG: &str = "wire-schema-tag";
pub const WIRE_FIELD_COVERAGE: &str = "wire-field-coverage";
pub const WIRE_KEY_PARITY: &str = "wire-key-parity";
pub const PANIC_REACH: &str = "panic-reach";
pub const OBS_PRINT: &str = "obs-print";
pub const LOCK_ORDER: &str = "lock-order";
pub const LOCK_BLOCKING: &str = "lock-blocking";
pub const UNIT_MIXED_ADD: &str = "unit-mixed-add";
pub const UNIT_SCALE_MISMATCH: &str = "unit-scale-mismatch";
pub const UNIT_WIRE_SUFFIX: &str = "unit-wire-suffix";
pub const PRAGMA_MISSING_REASON: &str = "pragma-missing-reason";
pub const PRAGMA_UNKNOWN_RULE: &str = "pragma-unknown-rule";

/// Every rule id the pass can emit (and therefore that `allow(...)` may
/// name).
pub const KNOWN_RULES: &[&str] = &[
    DET_HASH_ITER,
    DET_UNORDERED_FOLD,
    DET_WALL_CLOCK,
    DET_ENTROPY_RNG,
    PANIC_UNWRAP,
    PANIC_EXPECT,
    PANIC_MACRO,
    PANIC_SLICE_INDEX,
    WIRE_SCHEMA_TAG,
    WIRE_FIELD_COVERAGE,
    WIRE_KEY_PARITY,
    PANIC_REACH,
    OBS_PRINT,
    LOCK_ORDER,
    LOCK_BLOCKING,
    UNIT_MIXED_ADD,
    UNIT_SCALE_MISMATCH,
    UNIT_WIRE_SUFFIX,
    PRAGMA_MISSING_REASON,
    PRAGMA_UNKNOWN_RULE,
];

/// One lint finding, suppressed or not.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub message: String,
    pub suppressed: bool,
    /// The pragma's written reason, when suppressed.
    pub reason: Option<String>,
}

/// A parsed `lint: allow(...)` pragma.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub reason: String,
    pub line: u32,
    /// Lines this pragma covers: its own line and the next code line.
    pub covers: Vec<u32>,
}

/// A parsed `lint: wire(<key>)` field-alias pragma.
#[derive(Debug, Clone)]
pub struct WireAlias {
    pub key: String,
    pub line: u32,
}

/// Per-file pragma scan result.
#[derive(Debug, Default)]
pub struct Pragmas {
    pub allows: Vec<Allow>,
    pub aliases: Vec<WireAlias>,
    /// Meta findings (unknown rule / missing reason) — never suppressible.
    pub meta: Vec<Finding>,
}

/// Strip comment decoration (`//`, `///`, `//!`, `/*`, `*/`) and return
/// the trimmed payload.
fn comment_payload(text: &str) -> &str {
    let t = text
        .trim_start_matches('/')
        .trim_start_matches('*')
        .trim_start_matches('!')
        .trim_start_matches('/');
    t.trim_end_matches('/').trim_end_matches('*').trim()
}

/// Parse all `lint:` pragmas in a file's token stream.  `code_lines` must
/// be the ascending set of lines holding at least one non-comment token.
pub fn scan_pragmas(file: &str, toks: &[Tok], code_lines: &BTreeSet<u32>) -> Pragmas {
    let mut out = Pragmas::default();
    for t in toks.iter().filter(|t| t.kind == TokKind::Comment) {
        let payload = comment_payload(&t.text);
        let Some(rest) = payload.strip_prefix("lint:") else {
            continue;
        };
        let rest = rest.trim();
        if let Some(arg) = directive_arg(rest, "allow") {
            let rule = arg.0.trim().to_string();
            let reason = arg
                .1
                .trim_start()
                .trim_start_matches(['—', '–', '-'])
                .trim()
                .to_string();
            if !KNOWN_RULES.contains(&rule.as_str()) {
                out.meta.push(Finding {
                    rule: PRAGMA_UNKNOWN_RULE.to_string(),
                    file: file.to_string(),
                    line: t.line,
                    message: format!("allow names unknown rule '{rule}'"),
                    suppressed: false,
                    reason: None,
                });
                continue;
            }
            if reason.is_empty() {
                out.meta.push(Finding {
                    rule: PRAGMA_MISSING_REASON.to_string(),
                    file: file.to_string(),
                    line: t.line,
                    message: format!(
                        "allow({rule}) has no reason — write `// lint: allow({rule}) — <why>`"
                    ),
                    suppressed: false,
                    reason: None,
                });
                continue;
            }
            let mut covers = vec![t.line];
            if let Some(&next) = code_lines.range(t.line + 1..).next() {
                covers.push(next);
            }
            out.allows.push(Allow {
                rule,
                reason,
                line: t.line,
                covers,
            });
        } else if let Some(arg) = directive_arg(rest, "wire") {
            let key = arg.0.trim().to_string();
            if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_') {
                out.meta.push(Finding {
                    rule: PRAGMA_UNKNOWN_RULE.to_string(),
                    file: file.to_string(),
                    line: t.line,
                    message: format!("wire(...) key '{key}' is not an identifier"),
                    suppressed: false,
                    reason: None,
                });
                continue;
            }
            out.aliases.push(WireAlias { key, line: t.line });
        } else {
            out.meta.push(Finding {
                rule: PRAGMA_UNKNOWN_RULE.to_string(),
                file: file.to_string(),
                line: t.line,
                message: format!("unrecognised lint directive '{rest}'"),
                suppressed: false,
                reason: None,
            });
        }
    }
    out
}

/// If `rest` starts with `name(...)`, return (argument, remainder after
/// the closing paren).
fn directive_arg<'a>(rest: &'a str, name: &str) -> Option<(&'a str, &'a str)> {
    let r = rest.strip_prefix(name)?;
    let r = r.trim_start();
    let r = r.strip_prefix('(')?;
    let close = r.find(')')?;
    Some((&r[..close], &r[close + 1..]))
}

/// Keywords that may directly precede `[` without it being an index
/// expression (slice patterns, array types after `->`, …).  Also the
/// not-a-type / not-a-callee filter for the symbol extractor.
pub const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut",
    "pub", "ref", "return", "static", "struct", "super", "trait", "type", "unsafe", "use",
    "where", "while", "yield",
];

const HASH_ITER_METHODS: &[&str] = &[
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "into_keys", "into_values",
    "drain",
];

const UNORDERED_FOLDS: &[&str] = &["sum", "fold", "product"];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

const PRINT_MACROS: &[&str] = &["println", "eprintln", "print", "eprint", "dbg"];

const ENTROPY_IDENTS: &[&str] = &["thread_rng", "from_entropy", "OsRng", "getrandom"];

/// Token-index ranges (over the code-token stream) occupied by
/// `#[cfg(test)] mod … { … }` bodies; det/panic rules skip them.
pub fn test_ranges(code: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i + 6 < n {
        let is_cfg_test = code[i].is_punct('#')
            && code[i + 1].is_punct('[')
            && code[i + 2].is_ident("cfg")
            && code[i + 3].is_punct('(')
            && code[i + 4].is_ident("test")
            && code[i + 5].is_punct(')')
            && code[i + 6].is_punct(']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        // skip further attributes between #[cfg(test)] and the item
        while j + 1 < n && code[j].is_punct('#') && code[j + 1].is_punct('[') {
            let mut depth = 0usize;
            j += 1;
            while j < n {
                if code[j].is_punct('[') {
                    depth += 1;
                } else if code[j].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if j < n && code[j].is_ident("mod") {
            // mod <name> { … } — brace-match the body
            let mut k = j + 1;
            while k < n && !code[k].is_punct('{') {
                k += 1;
            }
            let start = k;
            let mut depth = 0usize;
            while k < n {
                if code[k].is_punct('{') {
                    depth += 1;
                } else if code[k].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            ranges.push((start, k.min(n.saturating_sub(1))));
            i = k + 1;
        } else {
            i = j;
        }
    }
    ranges
}

fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| idx >= a && idx <= b)
}

/// Identifiers bound to a `HashMap`/`HashSet` anywhere in the file
/// (type ascriptions, struct fields, fn params, `= HashMap::new()`).
/// File-local and name-based — deliberately over-approximate: a hash
/// container reached through a differently-named binding escapes, but
/// every direct iteration in the file is caught.
fn hash_bound_idents(code: &[Tok]) -> BTreeSet<String> {
    let mut vars = BTreeSet::new();
    for (i, t) in code.iter().enumerate() {
        if !(t.is_ident("HashMap") || t.is_ident("HashSet")) {
            continue;
        }
        // walk back over a `path::to::` prefix
        let mut j = i;
        while j >= 2 && code[j - 1].is_punct(':') && code[j - 2].is_punct(':') {
            j -= 2;
            if j >= 1 && code[j - 1].kind == TokKind::Ident {
                j -= 1;
            }
        }
        if j == 0 {
            continue;
        }
        let prev = &code[j - 1];
        if prev.is_punct(':') && j >= 2 && !code[j - 2].is_punct(':') {
            if code[j - 2].kind == TokKind::Ident {
                vars.insert(code[j - 2].text.clone());
            }
        } else if prev.is_punct('=') && j >= 2 && code[j - 2].kind == TokKind::Ident {
            vars.insert(code[j - 2].text.clone());
        }
    }
    vars
}

/// Run the determinism + panic-surface rules over one file's code
/// tokens.  `scope` gates which families fire; meta rules are handled by
/// `scan_pragmas`.
pub fn run_code_rules(file: &str, code: &[Tok], scope: Scope) -> Vec<Finding> {
    let mut out = Vec::new();
    if !scope.src || !(scope.parity || scope.serving) {
        return out;
    }
    let skip = test_ranges(code);
    let hash_vars = if scope.parity {
        hash_bound_idents(code)
    } else {
        BTreeSet::new()
    };
    let n = code.len();
    let mut push = |rule: &str, line: u32, message: String| {
        out.push(Finding {
            rule: rule.to_string(),
            file: file.to_string(),
            line,
            message,
            suppressed: false,
            reason: None,
        });
    };

    for i in 0..n {
        if in_ranges(i, &skip) {
            continue;
        }
        let t = &code[i];

        if scope.parity {
            // Instant::now / SystemTime::now
            if (t.is_ident("Instant") || t.is_ident("SystemTime"))
                && i + 3 < n
                && code[i + 1].is_punct(':')
                && code[i + 2].is_punct(':')
                && code[i + 3].is_ident("now")
            {
                push(
                    DET_WALL_CLOCK,
                    t.line,
                    format!(
                        "{}::now() in a parity-critical module — wall-clock reads \
                         break replay determinism",
                        t.text
                    ),
                );
            }
            if t.kind == TokKind::Ident && ENTROPY_IDENTS.contains(&t.text.as_str()) {
                push(
                    DET_ENTROPY_RNG,
                    t.line,
                    format!(
                        "entropy-seeded RNG `{}` in a parity-critical module — use the \
                         seeded splitmix in util::rng",
                        t.text
                    ),
                );
            }
            // <hashvar>.iter()/keys()/… and `for _ in [&]hashvar {`
            if t.kind == TokKind::Ident
                && hash_vars.contains(&t.text)
                && i + 3 < n
                && code[i + 1].is_punct('.')
                && code[i + 2].kind == TokKind::Ident
                && HASH_ITER_METHODS.contains(&code[i + 2].text.as_str())
                && code[i + 3].is_punct('(')
            {
                let folded = chain_reaches_fold(code, i + 3);
                let (rule, what) = if folded {
                    (DET_UNORDERED_FOLD, "float reduction over hash-order iteration")
                } else {
                    (DET_HASH_ITER, "iteration over a hash container")
                };
                push(
                    rule,
                    t.line,
                    format!(
                        "{what} (`{}.{}()`) — hash order varies per process; collect \
                         and sort, or use an ordered container",
                        t.text,
                        code[i + 2].text
                    ),
                );
            }
            if t.is_ident("in") && i + 2 < n {
                let mut j = i + 1;
                while j < n && (code[j].is_punct('&') || code[j].is_ident("mut")) {
                    j += 1;
                }
                if j + 1 < n
                    && code[j].kind == TokKind::Ident
                    && hash_vars.contains(&code[j].text)
                    && code[j + 1].is_punct('{')
                {
                    push(
                        DET_HASH_ITER,
                        code[j].line,
                        format!(
                            "`for … in {}` iterates a hash container — hash order varies \
                             per process",
                            code[j].text
                        ),
                    );
                }
            }
        }

        if scope.serving {
            if t.is_punct('.') && i + 2 < n && code[i + 2].is_punct('(') {
                if code[i + 1].is_ident("unwrap") {
                    push(
                        PANIC_UNWRAP,
                        code[i + 1].line,
                        "`.unwrap()` on the serving/worker path — recover or return an \
                         error (see util::sync::locked for mutexes)"
                            .to_string(),
                    );
                } else if code[i + 1].is_ident("expect") {
                    push(
                        PANIC_EXPECT,
                        code[i + 1].line,
                        "`.expect()` on the serving/worker path — recover or return an \
                         error"
                            .to_string(),
                    );
                }
            }
            if t.kind == TokKind::Ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && i + 1 < n
                && code[i + 1].is_punct('!')
            {
                push(
                    PANIC_MACRO,
                    t.line,
                    format!("`{}!` on the serving/worker path — return an error instead", t.text),
                );
            }
            if t.kind == TokKind::Ident
                && PRINT_MACROS.contains(&t.text.as_str())
                && i + 1 < n
                && code[i + 1].is_punct('!')
            {
                push(
                    OBS_PRINT,
                    t.line,
                    format!(
                        "`{}!` on the serving/worker path — emit a structured journal \
                         event (crate::obs) instead of ad-hoc stdio",
                        t.text
                    ),
                );
            }
            if t.is_punct('[') && i >= 1 {
                let prev = &code[i - 1];
                let indexes = match prev.kind {
                    TokKind::Ident => !KEYWORDS.contains(&prev.text.as_str()),
                    TokKind::Punct => prev.is_punct(')') || prev.is_punct(']'),
                    _ => false,
                };
                if indexes {
                    push(
                        PANIC_SLICE_INDEX,
                        t.line,
                        "direct slice/array index on the serving/worker path — use \
                         .get() or justify the bound with a pragma"
                            .to_string(),
                    );
                }
            }
        }
    }
    out
}

/// From an opening `(` at `open`, does the method chain continue into a
/// `sum`/`fold`/`product` call before the statement ends?
fn chain_reaches_fold(code: &[Tok], open: usize) -> bool {
    let n = code.len();
    let mut i = open;
    let mut depth: i32 = 0;
    // bounded forward scan: the rest of the chain expression
    let limit = (open + 200).min(n);
    while i < limit {
        let t = &code[i];
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return false; // closed an enclosing scope — chain over
            }
        } else if depth == 0 && (t.is_punct(';') || t.is_punct(',')) {
            return false;
        } else if depth == 0
            && t.is_punct('.')
            && i + 2 < n
            && code[i + 1].kind == TokKind::Ident
            && UNORDERED_FOLDS.contains(&code[i + 1].text.as_str())
            && code[i + 2].is_punct('(')
        {
            return true;
        }
        i += 1;
    }
    false
}

/// Mark findings covered by an `allow` pragma as suppressed, attaching
/// the written reason.  Meta findings (`pragma-*`) are never suppressed.
pub fn apply_suppressions(findings: &mut [Finding], allows: &[Allow]) {
    for f in findings.iter_mut() {
        if f.rule.starts_with("pragma-") {
            continue;
        }
        if let Some(a) = allows
            .iter()
            .find(|a| a.rule == f.rule && a.covers.contains(&f.line))
        {
            f.suppressed = true;
            f.reason = Some(a.reason.clone());
        }
    }
}

/// Ascending set of lines carrying at least one non-comment token.
pub fn code_line_set(code: &[Tok]) -> BTreeSet<u32> {
    code.iter().map(|t| t.line).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::lexer::{code_tokens, tokenize};
    use super::*;

    fn run(relpath: &str, src: &str) -> Vec<Finding> {
        let toks = tokenize(src);
        let code = code_tokens(&toks);
        let scope = super::super::classify::classify(relpath);
        let mut f = run_code_rules(relpath, &code, scope);
        let p = scan_pragmas(relpath, &toks, &code_line_set(&code));
        apply_suppressions(&mut f, &p.allows);
        f.extend(p.meta);
        f
    }

    fn unsuppressed<'a>(f: &'a [Finding]) -> Vec<&'a Finding> {
        f.iter().filter(|x| !x.suppressed).collect()
    }

    #[test]
    fn hash_iteration_flagged_in_parity_scope_only() {
        let src = "fn f() { let m: HashMap<String, u32> = HashMap::new(); \
                   for v in m.values() { let _ = v; } }";
        let f = run("src/generator/eval.rs", src);
        assert!(f.iter().any(|x| x.rule == DET_HASH_ITER), "{f:?}");
        let f = run("src/power/model.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn hash_fold_classified_as_unordered_fold() {
        let src = "fn f(m: HashMap<u32, f64>) -> f64 { m.values().sum() }";
        let f = run("src/sim/des.rs", src);
        assert!(f.iter().any(|x| x.rule == DET_UNORDERED_FOLD), "{f:?}");
    }

    #[test]
    fn vec_iteration_not_flagged() {
        let src = "fn f(v: Vec<f64>) -> f64 { v.iter().sum() }";
        let f = run("src/sim/des.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn wall_clock_and_entropy_flagged() {
        let src = "fn f() { let t = Instant::now(); let r = thread_rng(); }";
        let f = run("src/generator/search/greedy.rs", src);
        assert!(f.iter().any(|x| x.rule == DET_WALL_CLOCK));
        assert!(f.iter().any(|x| x.rule == DET_ENTROPY_RNG));
    }

    #[test]
    fn panic_family_fires_in_serving_scope() {
        let src = "fn f(v: &[u32], o: Option<u32>) -> u32 { \
                   let a = o.unwrap(); let b = o.expect(\"x\"); \
                   if a > b { panic!(\"boom\") } v[0] }";
        let f = run("src/coordinator/server.rs", src);
        let rules: Vec<&str> = f.iter().map(|x| x.rule.as_str()).collect();
        assert!(rules.contains(&PANIC_UNWRAP));
        assert!(rules.contains(&PANIC_EXPECT));
        assert!(rules.contains(&PANIC_MACRO));
        assert!(rules.contains(&PANIC_SLICE_INDEX));
    }

    #[test]
    fn print_macros_flagged_in_serving_scope_only() {
        let src = "fn f(x: u32) { println!(\"{x}\"); eprintln!(\"{x}\"); let _ = dbg!(x); }";
        let f = run("src/coordinator/router.rs", src);
        let hits = f.iter().filter(|x| x.rule == OBS_PRINT).count();
        assert_eq!(hits, 3, "{f:?}");
        // unscoped crate source may print (the CLI does)
        let f = run("src/power/model.rs", src);
        assert!(f.is_empty(), "{f:?}");
        // a reasoned pragma suppresses (the dist worker's wire line)
        let src = "fn f(x: u32) { \
                   // lint: allow(obs-print) — stdout is the wire protocol\n\
                   println!(\"{x}\"); }";
        let f = run("src/generator/dist/worker.rs", src);
        assert!(unsuppressed(&f).is_empty(), "{f:?}");
    }

    #[test]
    fn unwrap_or_else_and_vec_macro_not_flagged() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { \
                   let g = m.lock().unwrap_or_else(|e| e.into_inner()); \
                   let v = vec![1, 2]; let [a, b] = [0u32, 1]; *g + v.len() as u32 + a + b }";
        let f = run("src/coordinator/metrics.rs", src);
        assert!(unsuppressed(&f).is_empty(), "{f:?}");
    }

    #[test]
    fn hazards_in_comments_and_strings_do_not_fire() {
        let src = "fn f() -> u32 { // calls x.unwrap() and panic!()\n\
                   let s = \"y.unwrap() panic! v[0]\"; s.len() as u32 }";
        let f = run("src/coordinator/router.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn cfg_test_mod_is_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { \
                   let o: Option<u32> = Some(1); o.unwrap(); }\n}\n";
        let f = run("src/coordinator/server.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_with_reason_suppresses_same_line_and_next_line() {
        let trailing = "fn f(o: Option<u32>) -> u32 { o.unwrap() } \
                        // lint: allow(panic-unwrap) — checked by caller";
        let f = run("src/runtime/engine.rs", trailing);
        assert_eq!(unsuppressed(&f).len(), 0, "{f:?}");
        assert!(f.iter().any(|x| x.suppressed && x.reason.as_deref() == Some("checked by caller")));

        let above = "// lint: allow(panic-unwrap) — checked by caller\n\
                     fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let f = run("src/runtime/engine.rs", above);
        assert_eq!(unsuppressed(&f).len(), 0, "{f:?}");
    }

    #[test]
    fn pragma_without_reason_is_a_finding_and_does_not_suppress() {
        let src = "// lint: allow(panic-unwrap)\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let f = run("src/runtime/engine.rs", src);
        let rules: Vec<&str> = unsuppressed(&f).iter().map(|x| x.rule.as_str()).collect();
        assert!(rules.contains(&PRAGMA_MISSING_REASON), "{f:?}");
        assert!(rules.contains(&PANIC_UNWRAP), "{f:?}");
    }

    #[test]
    fn unknown_rule_in_pragma_is_a_finding() {
        let src = "// lint: allow(no-such-rule) — whatever\nfn f() {}";
        let f = run("src/runtime/engine.rs", src);
        assert!(f.iter().any(|x| x.rule == PRAGMA_UNKNOWN_RULE));
    }

    #[test]
    fn pragma_does_not_cover_two_lines_down() {
        let src = "// lint: allow(panic-unwrap) — only covers next line\n\
                   fn g() {}\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let f = run("src/runtime/engine.rs", src);
        assert_eq!(unsuppressed(&f).len(), 1, "{f:?}");
    }

    #[test]
    fn meta_rules_apply_in_tests_dir_but_code_rules_do_not() {
        let src = "// lint: allow(panic-unwrap)\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let f = run("tests/integration_x.rs", src);
        let rules: Vec<&str> = f.iter().map(|x| x.rule.as_str()).collect();
        assert!(rules.contains(&PRAGMA_MISSING_REASON));
        assert!(!rules.contains(&PANIC_UNWRAP));
    }

    #[test]
    fn attribute_and_type_brackets_not_flagged() {
        let src = "#[derive(Debug)]\nstruct S { xs: [f64; 4] }\n\
                   fn f(s: &S) -> f64 { s.xs.iter().copied().fold(0.0, f64::max) }";
        let f = run("src/coordinator/request.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
