//! Wire-hygiene rules: every struct with a JSON codec in a `dist/wire.rs`
//! file must carry the schema tag and keep its field set covered by both
//! the encoder and the decoder.
//!
//! The checks are cross-file: a codec lives in `wire.rs` (`impl Name {
//! fn to_json / fn from_json }`) while the struct itself may be defined
//! elsewhere (`ShardResult` lives in `worker.rs`), so struct definitions
//! are collected over the whole scanned tree first.
//!
//! Key extraction is deliberately shape-based: a string literal counts as
//! a wire key when it is identifier-like (`[A-Za-z_][A-Za-z0-9_]*`) and
//! sits directly after `(` or `,` — the position of every key in the
//! repo's helper-call idiom (`uint(j, "shard")`, `("shard", Json::Num)`)
//! — while human-readable error messages contain spaces and never match.
//! A field whose wire key differs from its name declares the mapping
//! with a trailing `// lint: wire(<key>)` pragma.

use super::lexer::{Tok, TokKind};
use super::rules::{Finding, WireAlias, WIRE_FIELD_COVERAGE, WIRE_KEY_PARITY, WIRE_SCHEMA_TAG};
use std::collections::{BTreeMap, BTreeSet};

/// One struct field as seen by the wire checker.
#[derive(Debug, Clone)]
pub struct Field {
    pub name: String,
    pub line: u32,
    /// Wire key when it differs from the field name (`lint: wire(...)`).
    pub alias: Option<String>,
}

/// A `struct Name { … }` definition.
#[derive(Debug, Clone)]
pub struct StructDef {
    pub name: String,
    pub file: String,
    pub line: u32,
    pub fields: Vec<Field>,
}

fn ident_like(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_alphanumeric() || c == '_')
}

/// Collect brace-struct definitions from one file's code tokens.
/// Tuple and unit structs are skipped — nothing wire-encoded is one.
pub fn collect_structs(file: &str, code: &[Tok], aliases: &[WireAlias]) -> Vec<StructDef> {
    let mut out = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i + 1 < n {
        if !(code[i].is_ident("struct") && code[i + 1].kind == TokKind::Ident) {
            i += 1;
            continue;
        }
        let name = code[i + 1].text.clone();
        let line = code[i + 1].line;
        // skip generics / bounds to the body opener or a `;`/`(`
        let mut j = i + 2;
        let mut angle: i32 = 0;
        while j < n {
            let t = &code[j];
            if t.is_punct('<') {
                angle += 1;
            } else if t.is_punct('>') {
                angle -= 1;
            } else if angle == 0 && (t.is_punct('{') || t.is_punct('(') || t.is_punct(';')) {
                break;
            }
            j += 1;
        }
        if j >= n || !code[j].is_punct('{') {
            i = j.max(i + 2);
            continue;
        }
        let fields = parse_fields(code, j);
        out.push(StructDef {
            name,
            file: file.to_string(),
            line,
            fields: fields
                .into_iter()
                .map(|(name, line)| Field {
                    alias: aliases.iter().find(|a| a.line == line).map(|a| a.key.clone()),
                    name,
                    line,
                })
                .collect(),
        });
        i = j + 1;
    }
    out
}

/// Parse `name:` field starts inside a struct body opening at `code[open]
/// == '{'`.  Depth-tracks `(){}[]<>` so commas inside generic types do
/// not start a new field.
fn parse_fields(code: &[Tok], open: usize) -> Vec<(String, u32)> {
    let n = code.len();
    let mut fields = Vec::new();
    let mut brace: i32 = 1;
    let mut paren: i32 = 0;
    let mut bracket: i32 = 0;
    let mut angle: i32 = 0;
    let mut expecting = true;
    let mut i = open + 1;
    while i < n && brace > 0 {
        let t = &code[i];
        if t.is_punct('{') {
            brace += 1;
        } else if t.is_punct('}') {
            brace -= 1;
        } else if t.is_punct('(') {
            paren += 1;
        } else if t.is_punct(')') {
            paren -= 1;
        } else if t.is_punct('[') {
            bracket += 1;
        } else if t.is_punct(']') {
            bracket -= 1;
        } else if t.is_punct('<') {
            angle += 1;
        } else if t.is_punct('>') {
            angle -= 1;
        }
        let top = brace == 1 && paren == 0 && bracket == 0 && angle == 0;
        if top && t.is_punct(',') {
            expecting = true;
            i += 1;
            continue;
        }
        if top && expecting {
            if t.is_punct('#') && i + 1 < n && code[i + 1].is_punct('[') {
                // skip an attribute
                let mut depth = 0i32;
                i += 1;
                while i < n {
                    if code[i].is_punct('[') {
                        depth += 1;
                    } else if code[i].is_punct(']') {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    i += 1;
                }
                i += 1;
                continue;
            }
            if t.is_ident("pub") {
                if i + 1 < n && code[i + 1].is_punct('(') {
                    // pub(crate) / pub(super)
                    let mut depth = 0i32;
                    i += 1;
                    while i < n {
                        if code[i].is_punct('(') {
                            depth += 1;
                        } else if code[i].is_punct(')') {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        i += 1;
                    }
                }
                i += 1;
                continue;
            }
            if t.kind == TokKind::Ident
                && i + 1 < n
                && code[i + 1].is_punct(':')
                && !(i + 2 < n && code[i + 2].is_punct(':'))
            {
                fields.push((t.text.clone(), t.line));
                expecting = false;
            }
        }
        i += 1;
    }
    fields
}

/// One codec: an impl block containing both `fn to_json` and
/// `fn from_json`.
struct Codec {
    struct_name: String,
    line: u32,
    encode_keys: BTreeSet<String>,
    decode_keys: BTreeSet<String>,
    decode_idents: BTreeSet<String>,
}

fn brace_match(code: &[Tok], open: usize) -> usize {
    let n = code.len();
    let mut depth = 0i32;
    let mut i = open;
    while i < n {
        if code[i].is_punct('{') {
            depth += 1;
        } else if code[i].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    n.saturating_sub(1)
}

/// Identifier-like string literals sitting after `(` or `,` in a token
/// range — the wire-key position.
fn keys_in(code: &[Tok], from: usize, to: usize) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    for i in from..=to.min(code.len().saturating_sub(1)) {
        if code[i].kind == TokKind::Str
            && ident_like(&code[i].text)
            && i >= 1
            && (code[i - 1].is_punct('(') || code[i - 1].is_punct(','))
        {
            keys.insert(code[i].text.clone());
        }
    }
    keys
}

fn idents_in(code: &[Tok], from: usize, to: usize) -> BTreeSet<String> {
    let mut ids = BTreeSet::new();
    for t in code.iter().take(to.min(code.len().saturating_sub(1)) + 1).skip(from) {
        if t.kind == TokKind::Ident {
            ids.insert(t.text.clone());
        }
    }
    ids
}

fn find_codecs(code: &[Tok]) -> Vec<Codec> {
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < n {
        if !code[i].is_ident("impl") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        // skip impl generics
        if j < n && code[j].is_punct('<') {
            let mut angle = 0i32;
            while j < n {
                if code[j].is_punct('<') {
                    angle += 1;
                } else if code[j].is_punct('>') {
                    angle -= 1;
                    if angle == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if j >= n || code[j].kind != TokKind::Ident {
            i = j;
            continue;
        }
        let mut struct_name = code[j].text.clone();
        let impl_line = code[j].line;
        // `impl Trait for Name` — the implementing type names the codec
        let mut k = j + 1;
        while k < n && !(code[k].is_punct('{') || code[k].is_ident("for")) {
            k += 1;
        }
        if k < n && code[k].is_ident("for") && k + 1 < n && code[k + 1].kind == TokKind::Ident {
            struct_name = code[k + 1].text.clone();
            k += 2;
            while k < n && !code[k].is_punct('{') {
                k += 1;
            }
        }
        if k >= n {
            break;
        }
        let body_end = brace_match(code, k);

        let mut encode: Option<(usize, usize)> = None;
        let mut decode: Option<(usize, usize)> = None;
        let mut p = k + 1;
        while p < body_end {
            if code[p].is_ident("fn") && p + 1 < n && code[p + 1].kind == TokKind::Ident {
                let fname = code[p + 1].text.clone();
                let mut q = p + 2;
                while q < body_end && !code[q].is_punct('{') {
                    q += 1;
                }
                let fend = brace_match(code, q);
                if fname == "to_json" {
                    encode = Some((q, fend));
                } else if fname == "from_json" {
                    decode = Some((q, fend));
                }
                p = fend + 1;
            } else {
                p += 1;
            }
        }
        if let (Some((es, ee)), Some((ds, de))) = (encode, decode) {
            out.push(Codec {
                struct_name,
                line: impl_line,
                encode_keys: keys_in(code, es, ee),
                decode_keys: keys_in(code, ds, de),
                decode_idents: idents_in(code, ds, de),
            });
        }
        i = body_end + 1;
    }
    out
}

/// Run the wire-hygiene rules over one wire file, given the tree-wide
/// struct definitions.
pub fn check_wire_file(
    file: &str,
    code: &[Tok],
    structs: &BTreeMap<String, StructDef>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut push = |rule: &str, line: u32, message: String| {
        out.push(Finding {
            rule: rule.to_string(),
            file: file.to_string(),
            line,
            message,
            suppressed: false,
            reason: None,
        });
    };

    for codec in find_codecs(code) {
        let name = &codec.struct_name;
        if !codec.encode_keys.contains("schema") {
            push(
                WIRE_SCHEMA_TAG,
                codec.line,
                format!("{name}::to_json does not emit the 'schema' tag"),
            );
        }
        if !codec.decode_idents.contains("check_schema") {
            push(
                WIRE_SCHEMA_TAG,
                codec.line,
                format!("{name}::from_json does not call check_schema"),
            );
        }

        match structs.get(name) {
            None => push(
                WIRE_FIELD_COVERAGE,
                codec.line,
                format!("codec for '{name}' but no struct definition in the scanned tree"),
            ),
            Some(def) => {
                for f in &def.fields {
                    let key = f.alias.clone().unwrap_or_else(|| f.name.clone());
                    if !codec.encode_keys.contains(&key) {
                        push(
                            WIRE_FIELD_COVERAGE,
                            codec.line,
                            format!(
                                "field {name}.{} (wire key '{key}', defined {}:{}) is not \
                                 emitted by to_json",
                                f.name, def.file, f.line
                            ),
                        );
                    }
                    if !codec.decode_keys.contains(&key) {
                        push(
                            WIRE_FIELD_COVERAGE,
                            codec.line,
                            format!(
                                "field {name}.{} (wire key '{key}', defined {}:{}) is not \
                                 read by from_json",
                                f.name, def.file, f.line
                            ),
                        );
                    }
                }
            }
        }

        let mut enc = codec.encode_keys.clone();
        let mut dec = codec.decode_keys.clone();
        enc.remove("schema");
        dec.remove("schema");
        if enc != dec {
            let only_enc: Vec<&str> =
                enc.difference(&dec).map(|s| s.as_str()).collect();
            let only_dec: Vec<&str> =
                dec.difference(&enc).map(|s| s.as_str()).collect();
            push(
                WIRE_KEY_PARITY,
                codec.line,
                format!(
                    "{name} encode/decode key sets differ — encode-only: [{}], \
                     decode-only: [{}]",
                    only_enc.join(", "),
                    only_dec.join(", ")
                ),
            );
        }
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::lexer::{code_tokens, tokenize};
    use super::super::rules::scan_pragmas;
    use super::*;

    fn structs_of(file: &str, src: &str) -> BTreeMap<String, StructDef> {
        let toks = tokenize(src);
        let code = code_tokens(&toks);
        let lines = super::super::rules::code_line_set(&code);
        let pragmas = scan_pragmas(file, &toks, &lines);
        collect_structs(file, &code, &pragmas.aliases)
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect()
    }

    const GOOD: &str = r#"
        pub struct Msg {
            pub alpha: usize,
            pub beta: Option<ModelScales>,
            pub raw: RankAgreement, // lint: wire(tau_raw)
        }
        impl Msg {
            pub fn to_json(&self) -> Json {
                Json::obj(vec![
                    ("schema", Json::Str(SCHEMA.to_string())),
                    ("alpha", Json::Num(self.alpha as f64)),
                    ("beta", encode_scales(&self.beta)),
                    ("tau_raw", encode_agreement(&self.raw)),
                ])
            }
            pub fn from_json(j: &Json) -> anyhow::Result<Msg> {
                check_schema(j, SCHEMA)?;
                Ok(Msg {
                    alpha: uint(j, "alpha")?,
                    beta: decode_scales(j, "beta")?,
                    raw: decode_agreement(j, "tau_raw")?,
                })
            }
        }
    "#;

    #[test]
    fn clean_codec_passes() {
        let src_map = structs_of("src/generator/dist/wire.rs", GOOD);
        let toks = tokenize(GOOD);
        let code = code_tokens(&toks);
        let f = check_wire_file("src/generator/dist/wire.rs", &code, &src_map);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn missing_decode_key_and_parity_flagged() {
        // encoder emits gamma, decoder never reads it
        let src = r#"
            pub struct Msg { pub gamma: usize }
            impl Msg {
                fn to_json(&self) -> Json {
                    Json::obj(vec![
                        ("schema", Json::Str(S.to_string())),
                        ("gamma", Json::Num(self.gamma as f64)),
                    ])
                }
                fn from_json(j: &Json) -> anyhow::Result<Msg> {
                    check_schema(j, S)?;
                    Ok(Msg { gamma: 0 })
                }
            }
        "#;
        let src_map = structs_of("src/generator/dist/wire.rs", src);
        let code = code_tokens(&tokenize(src));
        let f = check_wire_file("src/generator/dist/wire.rs", &code, &src_map);
        let rules: Vec<&str> = f.iter().map(|x| x.rule.as_str()).collect();
        assert!(rules.contains(&WIRE_FIELD_COVERAGE), "{f:?}");
        assert!(rules.contains(&WIRE_KEY_PARITY), "{f:?}");
    }

    #[test]
    fn missing_schema_tag_flagged() {
        let src = r#"
            pub struct Msg { pub x: usize }
            impl Msg {
                fn to_json(&self) -> Json {
                    Json::obj(vec![("x", Json::Num(self.x as f64))])
                }
                fn from_json(j: &Json) -> anyhow::Result<Msg> {
                    Ok(Msg { x: uint(j, "x")? })
                }
            }
        "#;
        let src_map = structs_of("src/generator/dist/wire.rs", src);
        let code = code_tokens(&tokenize(src));
        let f = check_wire_file("src/generator/dist/wire.rs", &code, &src_map);
        let schema_findings =
            f.iter().filter(|x| x.rule == WIRE_SCHEMA_TAG).count();
        assert_eq!(schema_findings, 2, "{f:?}"); // no tag emitted, no check
    }

    #[test]
    fn new_field_without_codec_update_is_flagged() {
        // the regression the rule exists for: a field added to the struct
        // but not to either side of the codec
        let src = r#"
            pub struct Msg { pub x: usize, pub added: bool }
            impl Msg {
                fn to_json(&self) -> Json {
                    Json::obj(vec![
                        ("schema", Json::Str(S.to_string())),
                        ("x", Json::Num(self.x as f64)),
                    ])
                }
                fn from_json(j: &Json) -> anyhow::Result<Msg> {
                    check_schema(j, S)?;
                    Ok(Msg { x: uint(j, "x")?, added: false })
                }
            }
        "#;
        let src_map = structs_of("src/generator/dist/wire.rs", src);
        let code = code_tokens(&tokenize(src));
        let f = check_wire_file("src/generator/dist/wire.rs", &code, &src_map);
        let coverage: Vec<&Finding> =
            f.iter().filter(|x| x.rule == WIRE_FIELD_COVERAGE).collect();
        assert_eq!(coverage.len(), 2, "{f:?}"); // missing from both sides
        assert!(coverage[0].message.contains("added"));
    }

    #[test]
    fn error_message_strings_are_not_keys() {
        let toks = tokenize(
            r#"fn from_json(j: &Json) { uint(j, "shard")?; anyhow!("missing 'front' array"); }"#,
        );
        let code = code_tokens(&toks);
        let keys = keys_in(&code, 0, code.len() - 1);
        assert!(keys.contains("shard"));
        assert_eq!(keys.len(), 1, "{keys:?}");
    }

    #[test]
    fn struct_fields_parse_through_generics_and_attrs() {
        let src = r#"
            #[derive(Debug, Clone)]
            pub struct S {
                #[allow(dead_code)]
                pub map: HashMap<String, Vec<(u32, f64)>>,
                pub plain: bool,
                inner: Option<Box<S>>,
            }
        "#;
        let m = structs_of("src/generator/dist/wire.rs", src);
        let s = &m["S"];
        let names: Vec<&str> = s.fields.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["map", "plain", "inner"]);
    }
}
