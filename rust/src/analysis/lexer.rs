//! Minimal Rust lexer for the repo linter.
//!
//! Produces a flat token stream — identifiers, numbers, string/char
//! literals, lifetimes, single-char punctuation, and comments — with
//! 1-based line numbers and byte spans.  The point is not to parse Rust
//! but to strip comments and string literals *correctly* (nested block
//! comments, raw strings with `#` guards, byte strings, char-vs-lifetime
//! after `'`) so the rule engine can match token patterns without false
//! positives from hazards that only appear inside text.
//!
//! Span contract (checked by a property test in
//! `tests/prop_invariants.rs`): token spans are ascending,
//! non-overlapping byte ranges into the source, and every byte between
//! consecutive spans is whitespace.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    /// String literal of any flavour; `text` holds the *content* (no
    /// quotes, prefixes, or raw-string guards).  The span covers the
    /// whole lexeme, delimiters included.
    Str,
    Char,
    Lifetime,
    /// One punctuation character per token (`::` is two `:` tokens).
    Punct,
    /// Line or block comment; `text` holds the full lexeme including the
    /// `//` / `/* */` delimiters.  Block comments record their start line.
    Comment,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    /// Byte offset of the lexeme's first byte in the source.
    pub start: usize,
    /// Byte offset one past the lexeme's last byte.
    pub end: usize,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`.  Never fails: unterminated literals consume to EOF,
/// which is the forgiving behaviour a linter wants (the compiler owns
/// syntax errors).
pub fn tokenize(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    // char index -> byte offset (offs[n] == src.len())
    let mut offs: Vec<usize> = Vec::with_capacity(n + 1);
    let mut o = 0usize;
    for &c in &b {
        offs.push(o);
        o += c.len_utf8();
    }
    offs.push(o);
    let byte = |ci: usize| offs.get(ci.min(n)).copied().unwrap_or(o);

    let mut toks: Vec<Tok> = Vec::new();
    let mut push = |kind: TokKind, text: String, line: u32, s: usize, e: usize| {
        toks.push(Tok {
            kind,
            text,
            line,
            start: byte(s),
            end: byte(e),
        });
    };
    let mut i = 0usize;
    let mut line: u32 = 1;

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // comments
        if c == '/' && i + 1 < n && (b[i + 1] == '/' || b[i + 1] == '*') {
            if b[i + 1] == '/' {
                let start = i;
                while i < n && b[i] != '\n' {
                    i += 1;
                }
                push(TokKind::Comment, b[start..i].iter().collect(), line, start, i);
            } else {
                let start = i;
                let start_line = line;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                push(
                    TokKind::Comment,
                    b[start..i].iter().collect(),
                    start_line,
                    start,
                    i,
                );
            }
            continue;
        }

        // raw strings / raw idents: r"..", r#".."#, r#ident
        if c == 'r' {
            let mut j = i + 1;
            let mut guards = 0usize;
            while j < n && b[j] == '#' {
                guards += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                let start_line = line;
                let (content, next) = scan_raw_string(&b, j, guards, &mut line);
                push(TokKind::Str, content, start_line, i, next);
                i = next;
                continue;
            }
            if guards == 1 && j < n && is_ident_start(b[j]) {
                // raw identifier r#type — token text keeps the bare name,
                // the span covers the r# prefix
                let start = j;
                let mut k = j;
                while k < n && is_ident_continue(b[k]) {
                    k += 1;
                }
                push(TokKind::Ident, b[start..k].iter().collect(), line, i, k);
                i = k;
                continue;
            }
            // plain ident starting with 'r' — fall through
        }

        // byte strings / byte chars: b".."  br#".."#  b'x'
        if c == 'b' && i + 1 < n {
            if b[i + 1] == '"' {
                let start_line = line;
                let (content, next) = scan_string(&b, i + 1, &mut line);
                push(TokKind::Str, content, start_line, i, next);
                i = next;
                continue;
            }
            if b[i + 1] == '\'' {
                let next = scan_char(&b, i + 1);
                push(TokKind::Char, b[i..next].iter().collect(), line, i, next);
                i = next;
                continue;
            }
            if b[i + 1] == 'r' {
                let mut j = i + 2;
                let mut guards = 0usize;
                while j < n && b[j] == '#' {
                    guards += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    let start_line = line;
                    let (content, next) = scan_raw_string(&b, j, guards, &mut line);
                    push(TokKind::Str, content, start_line, i, next);
                    i = next;
                    continue;
                }
            }
            // plain ident starting with 'b' — fall through
        }

        if c == '"' {
            let start_line = line;
            let (content, next) = scan_string(&b, i, &mut line);
            push(TokKind::Str, content, start_line, i, next);
            i = next;
            continue;
        }

        // char literal vs lifetime
        if c == '\'' {
            let is_char = if i + 1 < n && b[i + 1] == '\\' {
                true // escape: always a char literal
            } else {
                // 'X' (any single char, including '{' or ' ') is a char;
                // 'ident not closed by a quote is a lifetime
                i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\''
            };
            if is_char {
                let next = scan_char(&b, i);
                push(TokKind::Char, b[i..next].iter().collect(), line, i, next);
                i = next;
                continue;
            }
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let start = i;
                let mut k = i + 1;
                while k < n && is_ident_continue(b[k]) {
                    k += 1;
                }
                push(
                    TokKind::Lifetime,
                    b[start..k].iter().collect(),
                    line,
                    start,
                    k,
                );
                i = k;
                continue;
            }
            push(TokKind::Punct, "'".to_string(), line, i, i + 1);
            i += 1;
            continue;
        }

        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            push(TokKind::Ident, b[start..i].iter().collect(), line, start, i);
            continue;
        }

        if c.is_ascii_digit() {
            let start = i;
            while i < n && (is_ident_continue(b[i]) || (b[i] == '.' && i + 1 < n && b[i + 1].is_ascii_digit() && !b[start..i].iter().any(|&x| x == '.'))) {
                i += 1;
            }
            push(TokKind::Num, b[start..i].iter().collect(), line, start, i);
            continue;
        }

        push(TokKind::Punct, c.to_string(), line, i, i + 1);
        i += 1;
    }
    toks
}

/// Scan a `"…"` literal starting at `b[i] == '"'`; returns (content,
/// index past the closing quote).
fn scan_string(b: &[char], mut i: usize, line: &mut u32) -> (String, usize) {
    let n = b.len();
    i += 1;
    let start = i;
    while i < n {
        match b[i] {
            '\\' => i += 2,
            '"' => return (b[start..i].iter().collect(), i + 1),
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (b[start..i.min(n)].iter().collect(), n)
}

/// Scan a raw string whose opening quote is at `b[q] == '"'` with
/// `guards` trailing `#`s required to close; returns (content, index past
/// the closing delimiter).
fn scan_raw_string(b: &[char], q: usize, guards: usize, line: &mut u32) -> (String, usize) {
    let n = b.len();
    let mut i = q + 1;
    let start = i;
    while i < n {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if b[i] == '"' {
            let mut k = 0usize;
            while k < guards && i + 1 + k < n && b[i + 1 + k] == '#' {
                k += 1;
            }
            if k == guards {
                return (b[start..i].iter().collect(), i + 1 + guards);
            }
        }
        i += 1;
    }
    (b[start..i.min(n)].iter().collect(), n)
}

/// Scan a char literal starting at `b[i] == '\''`; returns index past the
/// closing quote.  Lenient: a malformed literal consumes at most the
/// escape and one closing-quote attempt, and an unterminated literal at
/// EOF stops at `n` (every increment is bounds-guarded so the returned
/// index never exceeds the buffer).
fn scan_char(b: &[char], mut i: usize) -> usize {
    let n = b.len();
    i += 1; // opening quote
    if i < n && b[i] == '\\' {
        i += 1;
        if i < n && b[i] == 'u' && i + 1 < n && b[i + 1] == '{' {
            i += 2;
            while i < n && b[i] != '}' {
                i += 1;
            }
            if i < n {
                i += 1;
            }
        } else if i < n {
            i += 1;
        }
    } else if i < n {
        i += 1;
    }
    if i < n && b[i] == '\'' {
        i += 1;
    }
    i
}

/// The code view: all tokens except comments, preserving order and lines.
pub fn code_tokens(toks: &[Tok]) -> Vec<Tok> {
    toks.iter().filter(|t| t.kind != TokKind::Comment).cloned().collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_are_single_tokens() {
        let t = kinds("a // x.unwrap()\nb /* panic! /* nested */ still */ c");
        assert_eq!(t[0], (TokKind::Ident, "a".into()));
        assert_eq!(t[1].0, TokKind::Comment);
        assert!(t[1].1.contains("unwrap"));
        assert_eq!(t[2], (TokKind::Ident, "b".into()));
        assert_eq!(t[3].0, TokKind::Comment);
        assert!(t[3].1.contains("nested"));
        assert_eq!(t[4], (TokKind::Ident, "c".into()));
    }

    #[test]
    fn strings_swallow_hazards() {
        let t = kinds(r##"let s = "x.unwrap()"; let r = r#"panic!()"# ;"##);
        assert!(t.iter().all(|(k, tx)| *k != TokKind::Ident || (tx != "unwrap" && tx != "panic")));
        assert!(t.iter().any(|(k, tx)| *k == TokKind::Str && tx.contains("unwrap")));
    }

    #[test]
    fn raw_string_guards_respected() {
        let src = "r##\"inner \"# quote\"## after";
        let t = kinds(src);
        assert_eq!(t[0].0, TokKind::Str);
        assert!(t[0].1.contains("\"#"));
        assert_eq!(t[1], (TokKind::Ident, "after".into()));
    }

    #[test]
    fn char_vs_lifetime() {
        let t = kinds("x: &'a str; let c = 'x'; let n = '\\n'; let b = '{';");
        assert!(t.iter().any(|(k, tx)| *k == TokKind::Lifetime && tx == "'a"));
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Char).count(), 3);
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        let t = kinds("x.0.unwrap(); 1.5e3; 0..10");
        assert!(t.iter().any(|(k, tx)| *k == TokKind::Ident && tx == "unwrap"));
        assert!(t.iter().any(|(k, tx)| *k == TokKind::Num && tx == "1.5e3"));
        // range stays three tokens: 0, '.', '.', 10
        assert!(t.iter().any(|(k, tx)| *k == TokKind::Num && tx == "10"));
    }

    #[test]
    fn lines_are_tracked_through_multiline_literals() {
        let src = "a\n\"two\nlines\"\nb";
        let toks = tokenize(src);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 4);
    }

    #[test]
    fn byte_literals() {
        let t = kinds("b\"bytes\" b'x' br#\"raw\"#");
        assert_eq!(t[0], (TokKind::Str, "bytes".into()));
        assert_eq!(t[1].0, TokKind::Char);
        assert_eq!(t[2], (TokKind::Str, "raw".into()));
    }

    #[test]
    fn raw_ident() {
        let t = kinds("r#type x");
        assert_eq!(t[0], (TokKind::Ident, "type".into()));
        assert_eq!(t[1], (TokKind::Ident, "x".into()));
    }

    #[test]
    fn spans_tile_the_source() {
        let src = "fn f() { let s = \"a b\"; /* c */ x.y[0] } // tail";
        let toks = tokenize(src);
        let mut prev_end = 0usize;
        for t in &toks {
            assert!(t.start >= prev_end, "{t:?} overlaps previous token");
            assert!(t.end > t.start, "{t:?} has an empty span");
            let gap = &src[prev_end..t.start];
            assert!(gap.chars().all(char::is_whitespace), "gap {gap:?} not whitespace");
            prev_end = t.end;
        }
        assert!(src[prev_end..].chars().all(char::is_whitespace));
    }

    #[test]
    fn unterminated_escape_at_eof_does_not_panic() {
        // regression: '\  and '\u{  used to walk the scan index past the
        // buffer and panic on the slice
        for src in ["'\\", "'\\u{12", "b'\\", "r#\"x", "\"abc", "'"] {
            let toks = tokenize(src);
            assert!(toks.iter().all(|t| t.end <= src.len()), "{src:?}: {toks:?}");
        }
    }

    #[test]
    fn string_span_includes_delimiters() {
        let src = "r#\"abc\"#";
        let toks = tokenize(src);
        assert_eq!(toks[0].text, "abc");
        assert_eq!((toks[0].start, toks[0].end), (0, src.len()));
    }
}
