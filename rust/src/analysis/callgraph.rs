//! Intra-crate call graph and panic-reachability.
//!
//! Built on the symbol table (`analysis::symbols`): call sites resolve
//! to crate paths through the file's `use` map and module-path
//! heuristics, then may-panic facts propagate backwards over the edges
//! to a fixpoint.  A serving-scope entry from which a panic site is
//! reachable is a `panic-reach` finding carrying the full shortest call
//! chain.
//!
//! Resolution is deliberately conservative — a name that does not
//! resolve to a crate symbol produces *no* edge rather than a guessed
//! one (see DESIGN.md §Interprocedural analysis):
//!
//! * free calls try, in order: same module, the enclosing impl type,
//!   the file's `use` map, the crate root;
//! * path calls resolve `crate::`/`self::`/`Self::`/`super::` prefixes
//!   and first-segment `use` aliases;
//! * method calls (`.name(`) have no receiver type; they resolve only
//!   when `name` is unique crate-wide among impl methods and is neither
//!   a well-known std method nor a `macro_rules!`-generated name.
//!
//! Suppression is cut-based: a `// lint: allow(panic-reach) — <why>`
//! pragma on an entry's declaration, on a call site, or on the panic
//! site itself cuts every chain through that point.  An entry whose
//! every chain is cut reports a *suppressed* finding (the inventory
//! stays visible); one uncut chain is an unsuppressed finding.

use super::classify::Scope;
use super::lexer::{Tok, TokKind};
use super::lock::{self, LockAcq};
use super::rules::{Allow, Finding, PANIC_REACH};
use super::symbols::{analyze_bodies, extract_symbols, module_path_of, CallKind, Sym};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Receiver-less method names that never resolve to crate symbols even
/// when the name happens to be unique in-crate: well-known std/core
/// methods whose call sites vastly outnumber any same-named inherent
/// method.  Curated from the repo's actual unresolved-name census.
const METHOD_DENYLIST: &[&str] = &[
    "abs", "all", "and_then", "any", "arg", "args", "as_deref", "as_mut", "as_ref", "as_str",
    "binary_search", "binary_search_by", "bytes", "ceil", "chars", "checked_add", "checked_sub",
    "chunks", "clear", "clone", "cloned", "cmp", "collect", "concat", "contains", "contains_key",
    "copied", "count", "dedup", "display", "drain", "ends_with", "entry", "enumerate", "eq",
    "err", "exists", "extend", "fetch_add", "fetch_sub", "filter", "find", "finish", "first",
    "flat_map", "flatten", "floor", "flush", "fmt", "fold", "from", "from_bits", "get",
    "get_mut", "hash", "insert", "into", "into_iter", "into_keys", "into_values", "is_dir",
    "is_empty", "is_err", "is_file", "is_finite", "is_nan", "is_none", "is_ok", "is_some",
    "iter", "iter_mut", "join", "keys", "kill", "last", "len", "load", "lock", "map",
    "map_err", "max", "min", "ne", "next", "ok", "ok_or", "ok_or_else", "or_default",
    "or_else", "or_insert", "or_insert_with", "output", "parse", "partial_cmp", "path", "pop",
    "position", "powf", "powi", "product", "push", "range", "read", "read_line",
    "read_to_string", "recv", "recv_timeout", "remove", "replace", "resize", "retain", "rev",
    "round", "send", "sort", "sort_by", "sort_by_key", "spawn", "split", "splitn", "sqrt",
    "starts_with", "status", "store", "sum", "swap", "take", "to_bits", "to_owned",
    "to_string", "trim", "truncate", "try_into", "try_lock", "unwrap", "unwrap_or",
    "unwrap_or_default", "unwrap_or_else", "values", "values_mut", "wait", "windows",
    "with_capacity", "wrapping_add", "write", "write_all", "zip", "default", "new", "expect",
];

/// One file's contribution to the graph pass (borrowed from the
/// per-file preparation the linter already does).
pub struct FileCtx<'a> {
    pub rel: &'a str,
    pub code: &'a [Tok],
    pub scope: Scope,
    pub allows: &'a [Allow],
}

/// Aggregate graph statistics for the report and `--graph` output.
#[derive(Debug, Clone, Default)]
pub struct GraphSummary {
    /// Non-test `fn` items extracted crate-wide.
    pub symbols: usize,
    /// Resolved call edges.
    pub edges: usize,
    /// Edges resolved through crate-unique method names.
    pub method_edges: usize,
    /// Free/path call sites that resolved to no crate symbol (no edge).
    pub unresolved_calls: usize,
    /// Functions with a direct panic site.
    pub base_panic_fns: usize,
    /// Functions from which a panic site is reachable.
    pub may_panic_fns: usize,
    /// Serving-scope entry points examined.
    pub serving_entries: usize,
    /// Serving entries that can reach a panic (each carries a
    /// `panic-reach` finding, suppressed or not).
    pub panic_frontier: Vec<String>,
    /// Observed lock acquisition order: (first, second, site count).
    pub lock_order: Vec<(String, String, usize)>,
}

/// Alias -> absolute crate path, from a file's `use` declarations.
pub type UseMap = BTreeMap<String, Vec<String>>;

/// Parse every `use` declaration in a file (brace groups, `as` renames;
/// globs are ignored — a glob import simply resolves nothing).
pub fn extract_use_map(rel: &str, code: &[Tok]) -> UseMap {
    let mp = module_path_of(rel).unwrap_or_default();
    let mut out = UseMap::new();
    let n = code.len();
    let mut i = 0usize;
    while i < n {
        if !code.get(i).is_some_and(|t| t.is_ident("use")) {
            i += 1;
            continue;
        }
        let mut end = i + 1;
        while end < n && !code.get(end).is_some_and(|t| t.is_punct(';')) {
            end += 1;
        }
        parse_use_tree(code, i + 1, end, &[], &mut out, &mp);
        i = end + 1;
    }
    out
}

fn parse_use_tree(
    code: &[Tok],
    lo: usize,
    hi: usize,
    prefix: &[String],
    out: &mut UseMap,
    mp: &[String],
) {
    let mut segs: Vec<String> = prefix.to_vec();
    let mut i = lo;
    while i < hi {
        let Some(t) = code.get(i) else { break };
        if t.kind == TokKind::Ident {
            let name = t.text.clone();
            if i + 2 < hi
                && code.get(i + 1).is_some_and(|t| t.is_punct(':'))
                && code.get(i + 2).is_some_and(|t| t.is_punct(':'))
            {
                segs.push(name);
                i += 3;
                continue;
            }
            // terminal segment, optionally `as <alias>`
            let alias = if i + 2 < hi
                && code.get(i + 1).is_some_and(|t| t.is_ident("as"))
                && code.get(i + 2).is_some_and(|t| t.kind == TokKind::Ident)
            {
                let a = code.get(i + 2).map(|t| t.text.clone()).unwrap_or_default();
                i += 3;
                a
            } else {
                i += 1;
                name.clone()
            };
            let mut full = segs.clone();
            full.push(name);
            out.insert(alias, resolve_prefix(&full, mp));
            while i < hi && !code.get(i).is_some_and(|t| t.is_punct(',')) {
                i += 1;
            }
            i += 1;
            continue;
        }
        if t.is_punct('{') {
            // match the close, then recurse per comma-split child
            let mut close = i;
            let mut depth = 0i32;
            let mut k = i;
            while k < hi {
                let Some(tk) = code.get(k) else { break };
                if tk.is_punct('{') {
                    depth += 1;
                } else if tk.is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        close = k;
                        break;
                    }
                }
                k += 1;
            }
            let mut start = i + 1;
            let mut d = 0i32;
            let mut k = i + 1;
            while k <= close {
                let Some(tk) = code.get(k) else { break };
                if tk.is_punct('{') {
                    d += 1;
                } else if tk.is_punct('}') {
                    if d == 0 && k == close {
                        if k > start {
                            parse_use_tree(code, start, k, &segs, out, mp);
                        }
                        break;
                    }
                    d -= 1;
                } else if tk.is_punct(',') && d == 0 {
                    if k > start {
                        parse_use_tree(code, start, k, &segs, out, mp);
                    }
                    start = k + 1;
                }
                k += 1;
            }
            i = close + 1;
            continue;
        }
        i += 1; // `*` glob and stray punctuation: ignored
    }
}

/// Absolutize a use-path: `crate::` strips, `self::` prepends the
/// module path, `super::` pops it; anything else is taken as written
/// (external crates resolve to nothing later).
fn resolve_prefix(segs: &[String], mp: &[String]) -> Vec<String> {
    match segs.first().map(String::as_str) {
        Some("crate") => segs.get(1..).unwrap_or_default().to_vec(),
        Some("self") => {
            let mut v = mp.to_vec();
            v.extend(segs.get(1..).unwrap_or_default().iter().cloned());
            v
        }
        Some("super") => {
            let mut parts = mp.to_vec();
            let mut rest = segs;
            while rest.first().is_some_and(|s| s == "super") {
                parts.pop();
                rest = rest.get(1..).unwrap_or_default();
            }
            parts.extend(rest.iter().cloned());
            parts
        }
        _ => segs.to_vec(),
    }
}

/// Resolve one free/path call to a crate symbol path, or None.
fn resolve_call(
    segs: &[&str],
    mp: &[String],
    impl_ty: Option<&str>,
    usemap: &UseMap,
    known: &BTreeSet<String>,
) -> Option<String> {
    let lookup = |parts: &[String]| -> Option<String> {
        let key = parts.join("::");
        known.contains(&key).then_some(key)
    };
    let join = |base: &[String], rest: &[&str]| -> Vec<String> {
        base.iter()
            .cloned()
            .chain(rest.iter().map(|s| s.to_string()))
            .collect()
    };
    if let [name] = segs {
        if let Some(hit) = lookup(&join(mp, &[name])) {
            return Some(hit);
        }
        if let Some(ty) = impl_ty {
            if let Some(hit) = lookup(&join(mp, &[ty, name])) {
                return Some(hit);
            }
        }
        if let Some(base) = usemap.get(*name) {
            if let Some(hit) = lookup(base) {
                return Some(hit);
            }
        }
        return lookup(&[name.to_string()]);
    }
    let first = *segs.first()?;
    let rest = segs.get(1..).unwrap_or_default();
    let path: Vec<String> = match first {
        "crate" => rest.iter().map(|s| s.to_string()).collect(),
        "self" => join(mp, rest),
        "Self" => {
            let ty = impl_ty?;
            let mut v = mp.to_vec();
            v.push(ty.to_string());
            v.extend(rest.iter().map(|s| s.to_string()));
            v
        }
        "super" => {
            let mut parts = mp.to_vec();
            let mut r = segs;
            while r.first() == Some(&"super") {
                parts.pop();
                r = r.get(1..).unwrap_or_default();
            }
            parts.extend(r.iter().map(|s| s.to_string()));
            parts
        }
        _ => {
            if let Some(base) = usemap.get(first) {
                join(base, rest)
            } else {
                if let Some(hit) = lookup(&join(mp, segs)) {
                    return Some(hit);
                }
                segs.iter().map(|s| s.to_string()).collect()
            }
        }
    };
    lookup(&path)
}

/// The shortest entry-to-panic call chain found by BFS.
struct Chain {
    /// Human-readable: `a -> b -> c  (.unwrap() at file:line)`.
    desc: String,
    /// (caller path, call-site line) per traversed edge, entry first.
    hops: Vec<(String, u32)>,
    /// (file, line) of the panic site reached.
    site: (String, u32),
}

/// BFS from `entry` to the nearest panic site.  With `respect_cuts`,
/// pragma-covered entry declarations, call sites, and panic sites are
/// skipped — a None result then means every chain is cut.
fn bfs_chain(
    entry: &str,
    edges: &BTreeMap<String, Vec<(String, u32)>>,
    all_syms: &BTreeMap<String, Sym>,
    covered: &dyn Fn(&str, &str, u32) -> Option<String>,
    respect_cuts: bool,
) -> Option<Chain> {
    let entry_sym = all_syms.get(entry)?;
    if respect_cuts && covered(PANIC_REACH, &entry_sym.file, entry_sym.decl_line).is_some() {
        return None;
    }
    let mut parent: BTreeMap<String, Option<(String, u32)>> = BTreeMap::new();
    parent.insert(entry.to_string(), None);
    let mut q: VecDeque<String> = VecDeque::new();
    q.push_back(entry.to_string());
    while let Some(f) = q.pop_front() {
        let Some(s) = all_syms.get(&f) else { continue };
        let mut sites = s.panic_sites.clone();
        sites.sort_by_key(|p| p.line);
        for ps in &sites {
            if respect_cuts && covered(PANIC_REACH, &s.file, ps.line).is_some() {
                continue;
            }
            let mut names: Vec<String> = Vec::new();
            let mut hops: Vec<(String, u32)> = Vec::new();
            let mut g = f.clone();
            loop {
                names.push(g.clone());
                match parent.get(&g).cloned().flatten() {
                    Some((pg, line)) => {
                        hops.push((pg.clone(), line));
                        g = pg;
                    }
                    None => break,
                }
            }
            names.reverse();
            hops.reverse();
            return Some(Chain {
                desc: format!(
                    "{}  ({} at {}:{})",
                    names.join(" -> "),
                    ps.what,
                    s.file,
                    ps.line
                ),
                hops,
                site: (s.file.clone(), ps.line),
            });
        }
        // deduped, (line, callee)-ordered frontier for a deterministic
        // shortest chain
        let outs: BTreeSet<(u32, String)> = edges
            .get(&f)
            .map(|v| v.iter().map(|(c, l)| (*l, c.clone())).collect())
            .unwrap_or_default();
        for (line, callee) in outs {
            if parent.contains_key(&callee) {
                continue;
            }
            if respect_cuts && covered(PANIC_REACH, &s.file, line).is_some() {
                continue;
            }
            parent.insert(callee.clone(), Some((f.clone(), line)));
            q.push_back(callee);
        }
    }
    None
}

/// The reason of the first pragma cut along an all-cuts chain (entry
/// declaration, then call sites in order, then the panic site).  A
/// cut must exist on the chain: BFS-with-cuts found no uncut path, so
/// the shortest unrestricted path carries at least one.
fn first_cut_reason(
    entry: &Sym,
    chain: &Chain,
    all_syms: &BTreeMap<String, Sym>,
    covered: &dyn Fn(&str, &str, u32) -> Option<String>,
) -> String {
    if let Some(r) = covered(PANIC_REACH, &entry.file, entry.decl_line) {
        return r;
    }
    for (caller, line) in &chain.hops {
        if let Some(cs) = all_syms.get(caller) {
            if let Some(r) = covered(PANIC_REACH, &cs.file, *line) {
                return r;
            }
        }
    }
    if let Some(r) = covered(PANIC_REACH, &chain.site.0, chain.site.1) {
        return r;
    }
    "cut by an edge pragma".to_string()
}

/// Run the whole interprocedural pass: extract symbols, build the call
/// graph, propagate panic facts, and emit `panic-reach` plus the lock
/// findings.  Suppression state is resolved here (cut-based), so the
/// returned findings bypass the per-file pragma application.
pub fn graph_pass(files: &[FileCtx]) -> (Vec<Finding>, GraphSummary) {
    // per-file extraction
    let mut all_syms: BTreeMap<String, Sym> = BTreeMap::new();
    let mut locks: BTreeMap<String, Vec<LockAcq>> = BTreeMap::new();
    let mut macro_fns: BTreeSet<String> = BTreeSet::new();
    let mut usemaps: BTreeMap<String, UseMap> = BTreeMap::new();
    let mut serving_files: BTreeSet<String> = BTreeSet::new();
    let mut per_file_syms: Vec<(usize, Vec<Sym>)> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        if !f.scope.src {
            continue;
        }
        if f.scope.serving {
            serving_files.insert(f.rel.to_string());
        }
        let (mut syms, mfns) = extract_symbols(f.rel, f.code);
        macro_fns.extend(mfns);
        analyze_bodies(f.code, &mut syms, f.scope.serving);
        usemaps.insert(f.rel.to_string(), extract_use_map(f.rel, f.code));
        for s in &syms {
            // keep-first on duplicate paths (e.g. the same op implemented
            // for two trait impls) — first declaration wins, matching the
            // deterministic file walk order
            if !s.is_test && !all_syms.contains_key(&s.path) {
                locks.insert(s.path.clone(), lock::extract_locks(f.code, s));
                all_syms.insert(s.path.clone(), s.clone());
            }
        }
        per_file_syms.push((fi, syms));
    }

    // crate-unique method-name index (impl methods only)
    let mut method_index: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (p, s) in &all_syms {
        if s.impl_ty.is_some() {
            method_index.entry(s.name.as_str()).or_default().push(p.as_str());
        }
    }

    // resolve call sites into edges
    let known: BTreeSet<String> = all_syms.keys().cloned().collect();
    let mut edges: BTreeMap<String, Vec<(String, u32)>> = BTreeMap::new();
    let mut method_edges = 0usize;
    let mut unresolved = 0usize;
    let empty = UseMap::new();
    for (fi, syms) in &per_file_syms {
        let Some(f) = files.get(*fi) else { continue };
        let mp = module_path_of(f.rel).unwrap_or_default();
        let usemap = usemaps.get(f.rel).unwrap_or(&empty);
        for s in syms {
            if s.is_test {
                continue;
            }
            for rc in &s.raw_calls {
                let target = match rc.kind {
                    CallKind::Method => {
                        if METHOD_DENYLIST.contains(&rc.name.as_str())
                            || macro_fns.contains(&rc.name)
                        {
                            continue;
                        }
                        match method_index.get(rc.name.as_str()) {
                            Some(c) if c.len() == 1 => {
                                method_edges += 1;
                                c.first().map(|t| t.to_string()).unwrap_or_default()
                            }
                            _ => continue,
                        }
                    }
                    CallKind::Free | CallKind::Path => {
                        let segs: Vec<&str> = rc.name.split("::").collect();
                        match resolve_call(&segs, &mp, s.impl_ty.as_deref(), usemap, &known) {
                            Some(t) => t,
                            None => {
                                unresolved += 1;
                                continue;
                            }
                        }
                    }
                };
                // self-recursion adds no facts
                if target != s.path {
                    edges.entry(s.path.clone()).or_default().push((target, rc.line));
                }
            }
        }
    }

    // propagate may-panic backwards to a fixpoint
    let base: BTreeSet<String> = all_syms
        .iter()
        .filter(|(_, s)| !s.panic_sites.is_empty())
        .map(|(p, _)| p.clone())
        .collect();
    let mut rev: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (caller, outs) in &edges {
        for (callee, _) in outs {
            rev.entry(callee.as_str()).or_default().insert(caller.as_str());
        }
    }
    let mut may_panic: BTreeSet<String> = base.clone();
    let mut work: Vec<String> = base.iter().cloned().collect();
    while let Some(f) = work.pop() {
        for &caller in rev.get(f.as_str()).into_iter().flatten() {
            if !may_panic.contains(caller) {
                may_panic.insert(caller.to_string());
                work.push(caller.to_string());
            }
        }
    }

    // pragma cuts, by file
    let allow_index: BTreeMap<&str, &[Allow]> =
        files.iter().map(|f| (f.rel, f.allows)).collect();
    let covered = move |rule: &str, file: &str, line: u32| -> Option<String> {
        allow_index
            .get(file)?
            .iter()
            .find(|a| a.rule == rule && a.covers.contains(&line))
            .map(|a| a.reason.clone())
    };

    // panic-reach findings per serving entry
    let entries: Vec<&String> = all_syms
        .iter()
        .filter(|(_, s)| serving_files.contains(&s.file))
        .map(|(p, _)| p)
        .collect();
    let mut findings: Vec<Finding> = Vec::new();
    let mut frontier: Vec<String> = Vec::new();
    for e in &entries {
        if !may_panic.contains(*e) {
            continue;
        }
        let Some(sym) = all_syms.get(*e) else { continue };
        frontier.push((*e).clone());
        if let Some(chain) = bfs_chain(e, &edges, &all_syms, &covered, true) {
            findings.push(Finding {
                rule: PANIC_REACH.to_string(),
                file: sym.file.clone(),
                line: sym.decl_line,
                message: format!("serving entry `{e}` can reach a panic: {}", chain.desc),
                suppressed: false,
                reason: None,
            });
        } else if let Some(chain) = bfs_chain(e, &edges, &all_syms, &covered, false) {
            let reason = first_cut_reason(sym, &chain, &all_syms, &covered);
            findings.push(Finding {
                rule: PANIC_REACH.to_string(),
                file: sym.file.clone(),
                line: sym.decl_line,
                message: format!("serving entry `{e}` can reach a panic: {}", chain.desc),
                suppressed: true,
                reason: Some(reason),
            });
        }
    }

    // lock discipline over the same graph
    let (lock_finds, lock_order) =
        lock::lock_findings(&all_syms, &locks, &edges, &serving_files, &covered);
    findings.extend(lock_finds);

    let summary = GraphSummary {
        symbols: all_syms.len(),
        edges: edges.values().map(Vec::len).sum(),
        method_edges,
        unresolved_calls: unresolved,
        base_panic_fns: base.len(),
        may_panic_fns: may_panic.len(),
        serving_entries: entries.len(),
        panic_frontier: frontier,
        lock_order,
    };
    (findings, summary)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::classify::classify;
    use super::super::lexer::{code_tokens, tokenize};
    use super::super::rules::{code_line_set, scan_pragmas};
    use super::*;

    struct Owned {
        rel: String,
        code: Vec<Tok>,
        scope: Scope,
        allows: Vec<Allow>,
    }

    fn prepare(files: &[(&str, &str)]) -> Vec<Owned> {
        files
            .iter()
            .map(|(rel, text)| {
                let toks = tokenize(text);
                let code = code_tokens(&toks);
                let allows = scan_pragmas(rel, &toks, &code_line_set(&code)).allows;
                Owned {
                    rel: rel.to_string(),
                    code,
                    scope: classify(rel),
                    allows,
                }
            })
            .collect()
    }

    fn pass(files: &[(&str, &str)]) -> (Vec<Finding>, GraphSummary) {
        let owned = prepare(files);
        let ctxs: Vec<FileCtx> = owned
            .iter()
            .map(|o| FileCtx {
                rel: &o.rel,
                code: &o.code,
                scope: o.scope,
                allows: &o.allows,
            })
            .collect();
        graph_pass(&ctxs)
    }

    const HELPER: &str = "pub fn boom(o: Option<u32>) -> u32 { o.unwrap() }";

    #[test]
    fn use_map_groups_renames_and_prefixes() {
        let src = "use crate::util::{json::Json, rng as randomness};\n\
                   use super::sibling::thing;\n\
                   use std::collections::BTreeMap;";
        let code = code_tokens(&tokenize(src));
        let um = extract_use_map("src/a/b.rs", &code);
        assert_eq!(um["Json"], vec!["util", "json", "Json"]);
        assert_eq!(um["randomness"], vec!["util", "rng"]);
        assert_eq!(um["thing"], vec!["a", "sibling", "thing"]);
        assert_eq!(um["BTreeMap"], vec!["std", "collections", "BTreeMap"]);
    }

    #[test]
    fn panic_reaches_serving_entry_through_use_import() {
        let entry = "use crate::util::helper::boom;\n\
                     pub fn serve(o: Option<u32>) -> u32 { boom(o) }";
        let (findings, summary) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/util/helper.rs", HELPER)]);
        let pr: Vec<&Finding> =
            findings.iter().filter(|f| f.rule == PANIC_REACH).collect();
        assert_eq!(pr.len(), 1, "{findings:?}");
        assert!(!pr[0].suppressed);
        assert_eq!(pr[0].file, "src/coordinator/entry.rs");
        assert!(
            pr[0].message.contains(
                "coordinator::entry::serve -> util::helper::boom  \
                 (.unwrap() at src/util/helper.rs:1)"
            ),
            "{}",
            pr[0].message
        );
        assert_eq!(summary.panic_frontier, vec!["coordinator::entry::serve"]);
        assert!(summary.base_panic_fns == 1 && summary.may_panic_fns == 2);
    }

    #[test]
    fn pragma_on_panic_site_cuts_the_chain_into_a_suppressed_finding() {
        let helper = "pub fn boom(o: Option<u32>) -> u32 {\n\
                      // lint: allow(panic-reach) — caller validates upstream\n\
                      o.unwrap()\n}";
        let entry = "use crate::util::helper::boom;\n\
                     pub fn serve(o: Option<u32>) -> u32 { boom(o) }";
        let (findings, _) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/util/helper.rs", helper)]);
        let pr: Vec<&Finding> =
            findings.iter().filter(|f| f.rule == PANIC_REACH).collect();
        assert_eq!(pr.len(), 1, "{findings:?}");
        assert!(pr[0].suppressed);
        assert_eq!(pr[0].reason.as_deref(), Some("caller validates upstream"));
    }

    #[test]
    fn unresolved_names_make_no_edges() {
        let entry = "pub fn serve(o: Option<u32>) -> u32 { external_crate_fn(o) }";
        let (findings, summary) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/util/helper.rs", HELPER)]);
        assert!(findings.iter().all(|f| f.rule != PANIC_REACH), "{findings:?}");
        assert_eq!(summary.unresolved_calls, 1);
        assert_eq!(summary.edges, 0);
    }

    #[test]
    fn unique_method_name_resolves_ambiguous_or_denylisted_does_not() {
        let lib = "pub struct W(u32);\n\
                   impl W { pub fn tick_once(&self) -> u32 { self.0.checked_sub(1).unwrap() } }";
        let entry = "pub fn serve(w: &crate::W) -> u32 { w.tick_once() }";
        let (findings, summary) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/lib.rs", lib)]);
        assert!(
            findings.iter().any(|f| f.rule == PANIC_REACH && f.message.contains("W::tick_once")),
            "{findings:?}"
        );
        assert_eq!(summary.method_edges, 1);

        // same method name on two types: ambiguous, no edge
        let lib2 = "pub struct A(u32); pub struct B(u32);\n\
                    impl A { pub fn tick_once(&self) -> u32 { self.0.checked_sub(1).unwrap() } }\n\
                    impl B { pub fn tick_once(&self) -> u32 { self.0 } }";
        let (findings, summary) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/lib.rs", lib2)]);
        assert!(findings.iter().all(|f| f.rule != PANIC_REACH), "{findings:?}");
        assert_eq!(summary.method_edges, 0);
    }

    #[test]
    fn macro_generated_method_names_stay_ambiguous() {
        let lib = "macro_rules! gen { () => { pub fn probe(&self) -> u32 { 0 } }; }\n\
                   pub struct W(u32);\n\
                   impl W { pub fn probe(&self) -> u32 { self.0.checked_sub(1).unwrap() } }";
        let entry = "pub fn serve(w: &crate::W) -> u32 { w.probe() }";
        let (findings, _) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/lib.rs", lib)]);
        assert!(findings.iter().all(|f| f.rule != PANIC_REACH), "{findings:?}");
    }

    #[test]
    fn test_fns_are_neither_entries_nor_panic_sources() {
        let helper = "pub fn safe(o: Option<u32>) -> u32 { o.unwrap_or(0) }\n\
                      #[cfg(test)]\nmod tests { pub fn boom(o: Option<u32>) -> u32 { o.unwrap() } }";
        let entry = "use crate::util::helper::safe;\n\
                     pub fn serve(o: Option<u32>) -> u32 { safe(o) }\n\
                     #[cfg(test)]\nmod tests { fn t() { super::serve(None); } }";
        let (findings, summary) =
            pass(&[("src/coordinator/entry.rs", entry), ("src/util/helper.rs", helper)]);
        assert!(findings.iter().all(|f| f.rule != PANIC_REACH), "{findings:?}");
        assert_eq!(summary.base_panic_fns, 0);
        assert_eq!(summary.serving_entries, 1);
    }

    #[test]
    fn self_and_super_path_calls_resolve() {
        let helper = "pub fn boom(o: Option<u32>) -> u32 { o.unwrap() }";
        let entry = "pub fn serve(o: Option<u32>) -> u32 { crate::coordinator::helper::boom(o) }";
        let (findings, _) = pass(&[
            ("src/coordinator/entry.rs", entry),
            ("src/coordinator/helper.rs", helper),
        ]);
        // coordinator::helper::boom is serving scope — no base facts there,
        // so no finding; but the edge must exist (visible via may_panic=0)
        assert!(findings.iter().all(|f| f.rule != PANIC_REACH), "{findings:?}");

        let entry2 = "pub fn serve(o: Option<u32>) -> u32 { super::util::helper::boom(o) }";
        let (findings, _) = pass(&[
            ("src/coordinator/entry.rs", entry2),
            ("src/coordinator/util/helper.rs", HELPER),
        ]);
        // super:: from coordinator::entry pops to coordinator:: — then
        // util::helper::boom under it... which is serving scope again, so
        // still no base fact.  Use a non-serving sibling instead:
        let _ = findings;
        let entry3 = "pub fn serve(o: Option<u32>) -> u32 { crate::util::helper::boom(o) }";
        let (findings, _) = pass(&[
            ("src/coordinator/entry.rs", entry3),
            ("src/util/helper.rs", HELPER),
        ]);
        assert!(
            findings.iter().any(|f| f.rule == PANIC_REACH && !f.suppressed),
            "{findings:?}"
        );
    }
}
