//! Module-path classifier: maps a crate-relative file path to the rule
//! scopes that apply there.
//!
//! The scopes encode repo contracts, not style preferences:
//!
//! * **parity** — modules under the bit-parity contract (distributed
//!   sweeps must merge bit-identical to single-process): `generator/`,
//!   `sim/`, `strategy/`, and `workload/fit.rs`.  Determinism rules run
//!   here.
//! * **serving** — the request path and the worker/driver processes that
//!   must degrade with errors instead of panicking mid-drain:
//!   `coordinator/`, `runtime/`, `generator/dist/`, and `obs/` (the
//!   journal records from inside the serving path, so a panicking or
//!   printing recorder is a serving defect).  Panic-surface and
//!   observability rules run here.
//! * **wire** — files defining a host-portable codec (`wire.rs` under
//!   `dist/` or `obs/`).  Wire-hygiene rules run here.
//!
//! `tests/` and `benches/` are walked too, but only the pragma meta
//! rules apply (a stale or reason-less suppression is a defect anywhere).

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Scope {
    /// Determinism rules apply (bit-parity contract).
    pub parity: bool,
    /// Panic-surface rules apply (serving/worker path).
    pub serving: bool,
    /// Wire-hygiene rules apply (codec file).
    pub wire: bool,
    /// File is crate source (`src/`) rather than tests/benches; code
    /// rules only ever apply to crate source.
    pub src: bool,
}

/// Classify a path relative to the crate root, e.g.
/// `src/generator/dist/driver.rs`.  Accepts `\` separators.
pub fn classify(relpath: &str) -> Scope {
    let p = relpath.replace('\\', "/");
    let src = p.starts_with("src/");
    let parity = p.starts_with("src/generator/")
        || p.starts_with("src/sim/")
        || p.starts_with("src/strategy/")
        || p == "src/workload/fit.rs";
    let serving = p.starts_with("src/coordinator/")
        || p.starts_with("src/runtime/")
        || p.starts_with("src/generator/dist/")
        || p.starts_with("src/obs/");
    let wire = (p.contains("/dist/") || p.starts_with("src/obs/")) && p.ends_with("wire.rs");
    Scope {
        parity,
        serving,
        wire,
        src,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scopes_match_repo_contracts() {
        let s = classify("src/generator/dist/driver.rs");
        assert!(s.parity && s.serving && s.src && !s.wire);
        let s = classify("src/generator/dist/wire.rs");
        assert!(s.wire && s.serving && s.parity);
        let s = classify("src/coordinator/metrics.rs");
        assert!(s.serving && !s.parity);
        let s = classify("src/workload/fit.rs");
        assert!(s.parity && !s.serving);
        let s = classify("src/obs/journal.rs");
        assert!(s.serving && !s.parity && !s.wire);
        let s = classify("src/obs/wire.rs");
        assert!(s.serving && s.wire && !s.parity);
        let s = classify("src/workload/mod.rs");
        assert!(!s.parity && !s.serving);
        let s = classify("src/analysis/rules.rs");
        assert!(!s.parity && !s.serving && s.src);
        let s = classify("tests/integration_lint.rs");
        assert!(!s.src && !s.parity && !s.serving);
    }
}
