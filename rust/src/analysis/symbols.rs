//! Function-item extraction for the interprocedural rules.
//!
//! Walks a file's code-token stream as a recursive item parse — `mod`
//! blocks push module segments, `impl`/`trait` blocks record the
//! self-type, `fn` items record their crate path, declaration line, and
//! body token range — without recursing into function bodies (nested
//! closures and items stay attributed to the enclosing `fn`, which is
//! exactly the granularity the call graph wants).
//!
//! Two deliberate conservatisms (see DESIGN.md §Interprocedural
//! analysis):
//!
//! * `macro_rules!` bodies are *not* turned into symbols (a macro's `fn`
//!   skeleton is not a callable item), but every `fn NAME` inside one is
//!   harvested into the `macro_fns` set so macro-generated method names
//!   stay ambiguous during method resolution;
//! * `#[cfg(test)] mod` bodies are parsed but their symbols carry
//!   `is_test` — test-only functions neither seed panic facts nor serve
//!   as reachability entries.

use super::lexer::{Tok, TokKind};
use super::rules::{test_ranges, KEYWORDS};
use std::collections::BTreeSet;

/// How a call site names its target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallKind {
    /// `.name(` — receiver type unknown; resolved only when `name` is
    /// unique crate-wide among impl methods.
    Method,
    /// Bare `name(` — same-module, impl-type, use-map, then crate root.
    Free,
    /// `a::b::name(` — resolved through the use map / path prefixes.
    Path,
}

/// One call site inside a function body.
#[derive(Debug, Clone)]
pub struct RawCall {
    pub kind: CallKind,
    /// `::`-joined path as written (single segment for method/free).
    pub name: String,
    pub line: u32,
    /// Code-token index of the callee name token.
    pub idx: usize,
}

/// One may-panic site inside a function body (non-serving files only —
/// serving files are kept panic-free by the per-file token rules).
#[derive(Debug, Clone)]
pub struct PanicSite {
    /// What panics: `.unwrap()`, `panic!`, `slice index`, …
    pub what: String,
    pub line: u32,
}

/// One extracted function item.
#[derive(Debug, Clone)]
pub struct Sym {
    /// Crate path, e.g. `coordinator::server::Coordinator::submit`.
    pub path: String,
    pub name: String,
    /// Enclosing `impl`/`trait` self-type, when any.
    pub impl_ty: Option<String>,
    /// Crate-relative file, e.g. `src/coordinator/server.rs`.
    pub file: String,
    pub decl_line: u32,
    /// Code-token index range (inclusive) of the `{ … }` body.
    pub body: (usize, usize),
    /// Lives inside a `#[cfg(test)] mod` body.
    pub is_test: bool,
    pub raw_calls: Vec<RawCall>,
    pub panic_sites: Vec<PanicSite>,
}

/// Module path of a crate-relative `.rs` file: `src/lib.rs` → ``,
/// `src/main.rs` → `main`, `src/x/mod.rs` → `x`, `src/x/y.rs` → `x::y`.
/// Non-`src/` files have no module path (their items are not symbols).
pub fn module_path_of(rel: &str) -> Option<Vec<String>> {
    let p = rel.replace('\\', "/");
    let p = p.strip_prefix("src/")?;
    if p == "lib.rs" {
        return Some(Vec::new());
    }
    if p == "main.rs" {
        return Some(vec!["main".to_string()]);
    }
    let stem = p.strip_suffix("/mod.rs").or_else(|| p.strip_suffix(".rs"))?;
    Some(stem.split('/').map(str::to_string).collect())
}

/// From `code[i] == '<'`, return the index past the matching `>` —
/// treating `->`'s `>` as an arrow, not a closer — or bail at `{` / `;`
/// (malformed or odd generics).
fn skip_angles(code: &[Tok], mut i: usize) -> usize {
    let n = code.len();
    let mut depth = 0i32;
    while i < n {
        let Some(t) = code.get(i) else { break };
        if t.is_punct('<') {
            depth += 1;
        } else if t.is_punct('>') {
            let arrow = i >= 1 && code.get(i - 1).is_some_and(|p| p.is_punct('-'));
            if !arrow {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
        } else if t.is_punct('{') || t.is_punct(';') {
            return i;
        }
        i += 1;
    }
    i
}

/// Index of the `}` matching the `{` at `open_idx`, bounded by `hi`.
fn match_brace(code: &[Tok], open_idx: usize, hi: usize) -> usize {
    let mut depth = 0i32;
    let mut k = open_idx;
    while k < hi {
        let Some(t) = code.get(k) else { break };
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
        k += 1;
    }
    hi.saturating_sub(1)
}

fn tok_at(code: &[Tok], i: usize) -> Option<&Tok> {
    code.get(i)
}

fn is_ident_at(code: &[Tok], i: usize) -> bool {
    tok_at(code, i).is_some_and(|t| t.kind == TokKind::Ident)
}

/// Extract every `fn` item in a src file, plus the set of `fn` names
/// that appear inside `macro_rules!` bodies (kept ambiguous during
/// method resolution).
pub fn extract_symbols(rel: &str, code: &[Tok]) -> (Vec<Sym>, BTreeSet<String>) {
    let Some(mp) = module_path_of(rel) else {
        return (Vec::new(), BTreeSet::new());
    };
    let tranges = test_ranges(code);
    let in_test = |idx: usize| tranges.iter().any(|&(a, b)| idx >= a && idx <= b);
    let mut syms: Vec<Sym> = Vec::new();
    let mut macro_fns: BTreeSet<String> = BTreeSet::new();
    let n = code.len();

    // explicit work stack instead of recursion: (lo, hi, mod_parts, impl_ty)
    // processed as nested segments of the linear token stream
    struct Frame {
        lo: usize,
        hi: usize,
        mod_parts: Vec<String>,
        impl_ty: Option<String>,
    }
    let mut stack = vec![Frame {
        lo: 0,
        hi: n,
        mod_parts: mp,
        impl_ty: None,
    }];

    while let Some(frame) = stack.pop() {
        let Frame {
            lo,
            hi,
            mod_parts,
            impl_ty,
        } = frame;
        let mut i = lo;
        while i < hi {
            let Some(t) = tok_at(code, i) else { break };

            if t.is_ident("macro_rules")
                && tok_at(code, i + 1).is_some_and(|t| t.is_punct('!'))
            {
                let mut j = i + 2;
                while j < hi && !tok_at(code, j).is_some_and(|t| t.is_punct('{')) {
                    j += 1;
                }
                if j < hi {
                    let close = match_brace(code, j, hi);
                    for k in j..close {
                        if tok_at(code, k).is_some_and(|t| t.is_ident("fn"))
                            && k + 1 < close
                            && is_ident_at(code, k + 1)
                        {
                            if let Some(nm) = tok_at(code, k + 1) {
                                macro_fns.insert(nm.text.clone());
                            }
                        }
                    }
                    i = close + 1;
                } else {
                    i = j;
                }
                continue;
            }

            if t.is_ident("mod") && is_ident_at(code, i + 1) {
                let name = tok_at(code, i + 1).map(|t| t.text.clone()).unwrap_or_default();
                let mut j = i + 2;
                while j < hi
                    && !tok_at(code, j).is_some_and(|t| t.is_punct('{') || t.is_punct(';'))
                {
                    j += 1;
                }
                if j < hi && tok_at(code, j).is_some_and(|t| t.is_punct('{')) {
                    let close = match_brace(code, j, hi);
                    let mut parts = mod_parts.clone();
                    parts.push(name);
                    stack.push(Frame {
                        lo: j + 1,
                        hi: close,
                        mod_parts: parts,
                        impl_ty: None,
                    });
                    i = close + 1;
                } else {
                    i = j + 1;
                }
                continue;
            }

            if t.is_ident("impl") || t.is_ident("trait") {
                let is_trait = t.is_ident("trait");
                let mut j = i + 1;
                if tok_at(code, j).is_some_and(|t| t.is_punct('<')) {
                    j = skip_angles(code, j);
                }
                let mut ty: Option<String> = None;
                while j < hi {
                    let Some(tk) = tok_at(code, j) else { break };
                    if tk.is_punct('{') || tk.is_punct(';') {
                        break;
                    }
                    if tk.is_ident("for") && !is_trait {
                        // `impl Trait for Type` — the self type follows
                        ty = None;
                        j += 1;
                        continue;
                    }
                    if tk.is_ident("where") {
                        while j < hi && !tok_at(code, j).is_some_and(|t| t.is_punct('{')) {
                            j += 1;
                        }
                        break;
                    }
                    if tk.kind == TokKind::Ident && !KEYWORDS.contains(&tk.text.as_str()) {
                        ty = Some(tk.text.clone());
                    }
                    if tk.is_punct('<') {
                        j = skip_angles(code, j);
                        continue;
                    }
                    j += 1;
                }
                if j < hi && tok_at(code, j).is_some_and(|t| t.is_punct('{')) {
                    let close = match_brace(code, j, hi);
                    stack.push(Frame {
                        lo: j + 1,
                        hi: close,
                        mod_parts: mod_parts.clone(),
                        impl_ty: ty,
                    });
                    i = close + 1;
                } else {
                    i = j + 1;
                }
                continue;
            }

            if t.is_ident("fn") && is_ident_at(code, i + 1) {
                let name = tok_at(code, i + 1).map(|t| t.text.clone()).unwrap_or_default();
                let decl_line = t.line;
                // scan the signature to the body `{` at paren/bracket
                // depth 0, or `;` (no body: trait method, extern)
                let mut j = i + 2;
                let mut depth = 0i32;
                let mut body: Option<(usize, usize)> = None;
                while j < hi {
                    let Some(tk) = tok_at(code, j) else { break };
                    if tk.is_punct('(') || tk.is_punct('[') {
                        depth += 1;
                    } else if tk.is_punct(')') || tk.is_punct(']') {
                        depth -= 1;
                    } else if depth == 0 && tk.is_punct('{') {
                        body = Some((j, match_brace(code, j, hi)));
                        break;
                    } else if depth == 0 && tk.is_punct(';') {
                        break;
                    }
                    j += 1;
                }
                let Some(body) = body else {
                    i = j + 1;
                    continue;
                };
                let mut parts = mod_parts.clone();
                if let Some(ty) = &impl_ty {
                    parts.push(ty.clone());
                }
                parts.push(name.clone());
                syms.push(Sym {
                    path: parts.join("::"),
                    name,
                    impl_ty: impl_ty.clone(),
                    file: rel.to_string(),
                    decl_line,
                    body,
                    is_test: in_test(body.0),
                    raw_calls: Vec::new(),
                    panic_sites: Vec::new(),
                });
                i = body.1 + 1;
                continue;
            }

            i += 1;
        }
    }

    syms.sort_by(|a, b| a.body.0.cmp(&b.body.0));
    (syms, macro_fns)
}

/// From the call-name ident at `code[i]`, walk back over a
/// `seg:: seg::` prefix; returns the full segment list.
fn walk_path_back(code: &[Tok], i: usize) -> Vec<String> {
    let mut segs = vec![code.get(i).map(|t| t.text.clone()).unwrap_or_default()];
    let mut j = i;
    while j >= 3
        && code.get(j - 1).is_some_and(|t| t.is_punct(':'))
        && code.get(j - 2).is_some_and(|t| t.is_punct(':'))
        && is_ident_at(code, j - 3)
    {
        if let Some(t) = code.get(j - 3) {
            segs.insert(0, t.text.clone());
        }
        j -= 3;
    }
    segs
}

const PANIC_MACRO_NAMES: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Fill each symbol's `raw_calls`, and — in non-serving src files —
/// its `panic_sites` (serving files are kept panic-free by the token
/// rules, so they contribute no base facts; asserts are deliberately
/// excluded everywhere — an assert is a contract check, not a latent
/// panic).
pub fn analyze_bodies(code: &[Tok], syms: &mut [Sym], serving: bool) {
    for sym in syms.iter_mut() {
        // test-only fns never seed panic facts (they are allowed to
        // unwrap) but their call edges are still recorded
        let quiet = serving || sym.is_test;
        let (lo, hi) = sym.body;
        let mut i = lo;
        while i <= hi {
            let Some(t) = tok_at(code, i) else { break };

            // method call: `. name (`
            if t.is_punct('.')
                && i + 2 <= hi
                && is_ident_at(code, i + 1)
                && tok_at(code, i + 2).is_some_and(|t| t.is_punct('('))
            {
                if let Some(nm) = tok_at(code, i + 1) {
                    sym.raw_calls.push(RawCall {
                        kind: CallKind::Method,
                        name: nm.text.clone(),
                        line: nm.line,
                        idx: i + 1,
                    });
                    if !quiet && (nm.text == "unwrap" || nm.text == "expect") {
                        sym.panic_sites.push(PanicSite {
                            what: format!(".{}()", nm.text),
                            line: nm.line,
                        });
                    }
                }
                i += 2;
                continue;
            }

            // free/path call: `name (` where the previous token is not
            // `.` (method) or `fn` (declaration)
            if t.kind == TokKind::Ident
                && !KEYWORDS.contains(&t.text.as_str())
                && i + 1 <= hi
                && tok_at(code, i + 1).is_some_and(|t| t.is_punct('('))
            {
                let prev_ok = i == 0
                    || !tok_at(code, i - 1)
                        .is_some_and(|p| p.is_punct('.') || p.is_ident("fn"));
                if prev_ok {
                    let segs = walk_path_back(code, i);
                    let kind = if segs.len() > 1 {
                        CallKind::Path
                    } else {
                        CallKind::Free
                    };
                    sym.raw_calls.push(RawCall {
                        kind,
                        name: segs.join("::"),
                        line: t.line,
                        idx: i,
                    });
                }
            }

            // panic macros
            if t.kind == TokKind::Ident
                && PANIC_MACRO_NAMES.contains(&t.text.as_str())
                && i + 1 <= hi
                && tok_at(code, i + 1).is_some_and(|t| t.is_punct('!'))
                && !quiet
            {
                sym.panic_sites.push(PanicSite {
                    what: format!("{}!", t.text),
                    line: t.line,
                });
            }

            // indexing: `expr [` — same prev-token test as the per-file
            // panic-slice-index rule
            if t.is_punct('[') && i >= 1 && !quiet {
                if let Some(prev) = tok_at(code, i - 1) {
                    let indexes = match prev.kind {
                        TokKind::Ident => !KEYWORDS.contains(&prev.text.as_str()),
                        TokKind::Punct => prev.is_punct(')') || prev.is_punct(']'),
                        _ => false,
                    };
                    if indexes {
                        sym.panic_sites.push(PanicSite {
                            what: "slice index".to_string(),
                            line: t.line,
                        });
                    }
                }
            }

            i += 1;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::super::lexer::{code_tokens, tokenize};
    use super::*;

    fn syms_of(rel: &str, src: &str) -> Vec<Sym> {
        let code = code_tokens(&tokenize(src));
        extract_symbols(rel, &code).0
    }

    #[test]
    fn module_paths() {
        assert_eq!(module_path_of("src/lib.rs"), Some(vec![]));
        assert_eq!(module_path_of("src/main.rs"), Some(vec!["main".into()]));
        assert_eq!(module_path_of("src/x/mod.rs"), Some(vec!["x".into()]));
        assert_eq!(
            module_path_of("src/x/y.rs"),
            Some(vec!["x".into(), "y".into()])
        );
        assert_eq!(module_path_of("tests/t.rs"), None);
    }

    #[test]
    fn free_impl_and_nested_mod_paths() {
        let src = "pub fn top() {}\n\
                   impl Widget { fn m(&self) {} }\n\
                   impl Display for Widget { fn fmt(&self) {} }\n\
                   mod inner { pub fn deep() {} }\n";
        let s = syms_of("src/a/b.rs", src);
        let paths: Vec<&str> = s.iter().map(|s| s.path.as_str()).collect();
        assert!(paths.contains(&"a::b::top"), "{paths:?}");
        assert!(paths.contains(&"a::b::Widget::m"), "{paths:?}");
        assert!(paths.contains(&"a::b::Widget::fmt"), "{paths:?}");
        assert!(paths.contains(&"a::b::inner::deep"), "{paths:?}");
    }

    #[test]
    fn generic_impl_and_arrow_in_signature() {
        let src = "impl<T: Iterator<Item = u8>> Holder<T> {\n\
                   fn get(&self) -> Option<&T> { None }\n}";
        let s = syms_of("src/m.rs", src);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].path, "m::Holder::get");
    }

    #[test]
    fn bodies_not_recursed_and_sigless_fns_skipped() {
        let src = "trait T { fn sig_only(&self); }\n\
                   fn outer() { let f = |x: u32| x + 1; fn inner_decl() {} }\n";
        let s = syms_of("src/m.rs", src);
        let paths: Vec<&str> = s.iter().map(|s| s.path.as_str()).collect();
        // sig-only trait method has no body; inner_decl is swallowed by
        // outer's body range (no recursion into fn bodies)
        assert_eq!(paths, vec!["m::outer"], "{paths:?}");
    }

    #[test]
    fn macro_rules_fns_harvested_not_symbolised() {
        let src = "macro_rules! gen { () => { pub fn value(&self) -> f64 { self.0 } }; }\n\
                   pub fn real() {}\n";
        let code = code_tokens(&tokenize(src));
        let (s, mfns) = extract_symbols("src/m.rs", &code);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].path, "m::real");
        assert!(mfns.contains("value"));
    }

    #[test]
    fn cfg_test_symbols_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn helper() {} }\n";
        let s = syms_of("src/m.rs", src);
        let t: Vec<(&str, bool)> = s.iter().map(|s| (s.name.as_str(), s.is_test)).collect();
        assert!(t.contains(&("live", false)), "{t:?}");
        assert!(t.contains(&("helper", true)), "{t:?}");
    }

    #[test]
    fn calls_and_panic_sites_extracted() {
        let src = "fn f(o: Option<u32>, v: &[u32]) -> u32 {\n\
                   helper();\n\
                   crate::util::go(1);\n\
                   o.map(|x| x).unwrap() + v[0]\n}";
        let code = code_tokens(&tokenize(src));
        let (mut s, _) = extract_symbols("src/m.rs", &code);
        analyze_bodies(&code, &mut s, false);
        let calls: Vec<(&CallKind, &str)> = s[0]
            .raw_calls
            .iter()
            .map(|c| (&c.kind, c.name.as_str()))
            .collect();
        assert!(calls.contains(&(&CallKind::Free, "helper")), "{calls:?}");
        assert!(calls.contains(&(&CallKind::Path, "crate::util::go")), "{calls:?}");
        assert!(calls.contains(&(&CallKind::Method, "unwrap")), "{calls:?}");
        let sites: Vec<&str> = s[0].panic_sites.iter().map(|p| p.what.as_str()).collect();
        assert!(sites.contains(&".unwrap()"), "{sites:?}");
        assert!(sites.contains(&"slice index"), "{sites:?}");
    }

    #[test]
    fn serving_files_contribute_no_base_facts() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let code = code_tokens(&tokenize(src));
        let (mut s, _) = extract_symbols("src/coordinator/x.rs", &code);
        analyze_bodies(&code, &mut s, true);
        assert!(s[0].panic_sites.is_empty());
        assert!(!s[0].raw_calls.is_empty());
    }
}
