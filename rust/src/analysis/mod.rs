//! `elastic-gen lint`: the repo-invariant static analysis pass.
//!
//! Enforces three rule families clippy cannot express (see DESIGN.md
//! §Static analysis):
//!
//! * **determinism** — parity-critical modules (`generator/`, `sim/`,
//!   `strategy/`, `workload/fit.rs`) must stay bit-reproducible: no hash
//!   iteration, no wall clocks, no entropy RNG, no unordered float
//!   folds;
//! * **panic surface** — serving/worker modules (`coordinator/`,
//!   `runtime/`, `generator/dist/`) must not panic: no
//!   `unwrap`/`expect`/`panic!`/direct indexing;
//! * **wire hygiene** — every struct with a codec in `dist/wire.rs`
//!   carries the schema tag and full encode/decode field coverage;
//! * **interprocedural** (`symbols`/`callgraph`/`lock`) — a crate-wide
//!   call graph propagates may-panic facts to serving entries
//!   (`panic-reach`), and lexical lock live-ranges catch inconsistent
//!   nesting (`lock-order`) and blocking calls under a held guard
//!   (`lock-blocking`);
//! * **dimensional** (`expr`/`units`) — units inferred from declared
//!   newtype fields, boundary calls, and the `_mj`/`_ms` suffix
//!   convention propagate bottom-up through expression trees in parity
//!   + serving scope: `unit-mixed-add`, `unit-scale-mismatch`, and
//!   `unit-wire-suffix` catch the mJ-vs-J / ms-vs-s arithmetic slips
//!   the compiler cannot see on bare `f64`s.
//!
//! A finding is suppressed only by an inline pragma carrying a written
//! reason: `// lint: allow(<rule>) — <reason>`.  The pass walks
//! `src/`, `tests/`, and `benches/`, reports `file:line` findings, can
//! emit a JSON report (`util::json`), and exits non-zero on any
//! unsuppressed finding — wired as both a CI step and a tier-1
//! integration test (`tests/integration_lint.rs`).

pub mod callgraph;
pub mod classify;
pub mod expr;
pub mod lexer;
pub mod lock;
pub mod rules;
pub mod symbols;
pub mod units;
pub mod wire;

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use rules::Finding;

/// One input file: crate-relative path + contents.  In-memory so the
/// fixture self-tests drive the exact pipeline the CLI runs.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub rel: String,
    pub text: String,
}

/// The whole pass's outcome.
#[derive(Debug)]
pub struct LintOutcome {
    /// Every finding, suppressed ones included, ordered by (file, line).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Total `lint: allow(...)` pragmas in the tree (the suppression
    /// inventory a meta-test pins).
    pub allow_count: usize,
    /// Call-graph statistics from the interprocedural pass.
    pub graph: callgraph::GraphSummary,
    /// Dimensional-analysis statistics from the units pass.
    pub units: units::UnitsSummary,
}

impl LintOutcome {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.suppressed)
    }

    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }
}

/// Lint a set of in-memory files (the engine behind both the CLI and the
/// fixture tests).
pub fn lint_files(files: &[SourceFile]) -> LintOutcome {
    struct Prepared {
        rel: String,
        code: Vec<lexer::Tok>,
        scope: classify::Scope,
        pragmas: rules::Pragmas,
    }

    let mut prepared: Vec<Prepared> = Vec::with_capacity(files.len());
    let mut structs: BTreeMap<String, wire::StructDef> = BTreeMap::new();
    let mut unit_table = units::UnitTable::default();
    for f in files {
        let toks = lexer::tokenize(&f.text);
        let code = lexer::code_tokens(&toks);
        let scope = classify::classify(&f.rel);
        let pragmas = rules::scan_pragmas(&f.rel, &toks, &rules::code_line_set(&code));
        if scope.src {
            for s in wire::collect_structs(&f.rel, &code, &pragmas.aliases) {
                structs.entry(s.name.clone()).or_insert(s);
            }
            units::harvest(&code, &mut unit_table);
        }
        prepared.push(Prepared {
            rel: f.rel.clone(),
            code,
            scope,
            pragmas,
        });
    }

    let mut findings: Vec<Finding> = Vec::new();
    let mut allow_count = 0usize;
    let mut unit_stats = units::UnitsSummary::default();
    for p in &prepared {
        let mut file_findings = rules::run_code_rules(&p.rel, &p.code, p.scope);
        if p.scope.wire {
            file_findings.extend(wire::check_wire_file(&p.rel, &p.code, &structs));
        }
        if p.scope.src && (p.scope.parity || p.scope.serving) {
            file_findings.extend(units::check_file(
                &p.rel,
                &p.code,
                &unit_table,
                p.scope.wire,
                &mut unit_stats,
            ));
        }
        rules::apply_suppressions(&mut file_findings, &p.pragmas.allows);
        file_findings.extend(p.pragmas.meta.iter().cloned());
        allow_count += p.pragmas.allows.len();
        findings.extend(file_findings);
    }

    // interprocedural pass — cut-based suppression is resolved inside,
    // so these findings skip apply_suppressions
    let ctxs: Vec<callgraph::FileCtx> = prepared
        .iter()
        .map(|p| callgraph::FileCtx {
            rel: &p.rel,
            code: &p.code,
            scope: p.scope,
            allows: &p.pragmas.allows,
        })
        .collect();
    let (graph_findings, graph) = callgraph::graph_pass(&ctxs);
    findings.extend(graph_findings);

    findings.sort_by(|a, b| {
        let ka = (a.file.as_str(), a.line, a.rule.as_str());
        ka.cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });

    let mut units = unit_stats;
    units.fields_typed = unit_table.fields_typed();
    units.fns_typed = unit_table.fns_typed();

    LintOutcome {
        findings,
        files_scanned: prepared.len(),
        allow_count,
        graph,
        units,
    }
}

/// Walk `src/`, `tests/`, and `benches/` under the crate root and lint
/// every `.rs` file, in sorted path order.
pub fn lint_tree(crate_root: &Path) -> Result<LintOutcome> {
    let mut files: Vec<SourceFile> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = crate_root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, crate_root, &mut files)?;
        }
    }
    if files.is_empty() {
        return Err(anyhow!(
            "no .rs files under {} — is this the crate root?",
            crate_root.display()
        ));
    }
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(lint_files(&files))
}

fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            out.push(SourceFile { rel, text });
        }
    }
    Ok(())
}

/// Locate the crate root from the current directory: either the crate
/// itself (`src/lib.rs` + `Cargo.toml`) or a repo root holding `rust/`.
pub fn find_crate_root() -> Result<PathBuf> {
    let mut d = std::env::current_dir().context("current dir")?;
    loop {
        if d.join("src/lib.rs").is_file() && d.join("Cargo.toml").is_file() {
            return Ok(d);
        }
        if d.join("rust/src/lib.rs").is_file() {
            return Ok(d.join("rust"));
        }
        if !d.pop() {
            return Err(anyhow!(
                "could not locate the crate root (src/lib.rs) from the current directory"
            ));
        }
    }
}

/// The machine-readable report (`elastic-gen lint --json <path>`).
pub fn report_json(o: &LintOutcome) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("elastic-gen/lint-report/v1".to_string())),
        ("files_scanned", Json::Num(o.files_scanned as f64)),
        ("unsuppressed", Json::Num(o.unsuppressed_count() as f64)),
        ("suppressed", Json::Num(o.suppressed_count() as f64)),
        ("allow_pragmas", Json::Num(o.allow_count as f64)),
        (
            "findings",
            Json::Arr(
                o.findings
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("rule", Json::Str(f.rule.clone())),
                            ("file", Json::Str(f.file.clone())),
                            ("line", Json::Num(f.line as f64)),
                            ("message", Json::Str(f.message.clone())),
                            ("suppressed", Json::Bool(f.suppressed)),
                            (
                                "reason",
                                match &f.reason {
                                    Some(r) => Json::Str(r.clone()),
                                    None => Json::Null,
                                },
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("graph", graph_json(&o.graph)),
        ("units", units_json(&o.units)),
    ])
}

/// The `units` report section: dimensional-analysis pass statistics.
pub fn units_json(u: &units::UnitsSummary) -> Json {
    Json::obj(vec![
        ("files_checked", Json::Num(u.files_checked as f64)),
        ("fns_checked", Json::Num(u.fns_checked as f64)),
        ("exprs", Json::Num(u.exprs as f64)),
        ("resolved", Json::Num(u.resolved as f64)),
        ("checks", Json::Num(u.checks as f64)),
        ("findings", Json::Num(u.findings as f64)),
        ("fields_typed", Json::Num(u.fields_typed as f64)),
        ("fns_typed", Json::Num(u.fns_typed as f64)),
    ])
}

/// The `graph` report section: call-graph statistics, the serving panic
/// frontier, and the observed lock-acquisition order.
pub fn graph_json(g: &callgraph::GraphSummary) -> Json {
    Json::obj(vec![
        ("symbols", Json::Num(g.symbols as f64)),
        ("edges", Json::Num(g.edges as f64)),
        ("method_edges", Json::Num(g.method_edges as f64)),
        ("unresolved_calls", Json::Num(g.unresolved_calls as f64)),
        ("base_panic_fns", Json::Num(g.base_panic_fns as f64)),
        ("may_panic_fns", Json::Num(g.may_panic_fns as f64)),
        ("serving_entries", Json::Num(g.serving_entries as f64)),
        (
            "panic_frontier",
            Json::Arr(g.panic_frontier.iter().map(|p| Json::Str(p.clone())).collect()),
        ),
        (
            "lock_order",
            Json::Arr(
                g.lock_order
                    .iter()
                    .map(|(a, b, n)| {
                        Json::obj(vec![
                            ("first", Json::Str(a.clone())),
                            ("second", Json::Str(b.clone())),
                            ("sites", Json::Num(*n as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    fn file(rel: &str, text: &str) -> SourceFile {
        SourceFile {
            rel: rel.to_string(),
            text: text.to_string(),
        }
    }

    #[test]
    fn cross_file_wire_check_sees_structs_from_other_files() {
        // struct in worker.rs, codec in wire.rs — the ShardResult shape
        let worker = file(
            "src/generator/dist/worker.rs",
            "pub struct Reply { pub x: usize, pub extra: bool }",
        );
        let wire = file(
            "src/generator/dist/wire.rs",
            r#"
            impl Reply {
                fn to_json(&self) -> Json {
                    Json::obj(vec![
                        ("schema", Json::Str(S.to_string())),
                        ("x", Json::Num(self.x as f64)),
                    ])
                }
                fn from_json(j: &Json) -> anyhow::Result<Reply> {
                    check_schema(j, S)?;
                    Ok(Reply { x: uint(j, "x")?, extra: false })
                }
            }
            "#,
        );
        let out = lint_files(&[worker, wire]);
        let cov: Vec<&rules::Finding> = out
            .findings
            .iter()
            .filter(|f| f.rule == rules::WIRE_FIELD_COVERAGE)
            .collect();
        assert_eq!(cov.len(), 2, "{:?}", out.findings);
        assert!(cov.iter().all(|f| f.message.contains("extra")));
    }

    #[test]
    fn report_json_shape() {
        let out = lint_files(&[file(
            "src/coordinator/x.rs",
            "fn f(o: Option<u32>) -> u32 { o.unwrap() }",
        )]);
        assert_eq!(out.unsuppressed_count(), 1);
        let j = report_json(&out);
        assert_eq!(
            j.get("schema").and_then(|s| s.as_str()),
            Some("elastic-gen/lint-report/v1")
        );
        assert_eq!(j.get("unsuppressed").and_then(|n| n.as_usize()), Some(1));
        let arr = j.get("findings").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("rule").and_then(|r| r.as_str()),
            Some(rules::PANIC_UNWRAP)
        );
    }

    #[test]
    fn graph_section_reports_cross_file_panic_reach() {
        let helper = file(
            "src/util/helper.rs",
            "pub fn boom(o: Option<u32>) -> u32 { o.unwrap() }",
        );
        let entry = file(
            "src/coordinator/entry.rs",
            "use crate::util::helper::boom;\npub fn serve(o: Option<u32>) -> u32 { boom(o) }",
        );
        let out = lint_files(&[entry, helper]);
        assert!(
            out.findings
                .iter()
                .any(|f| f.rule == rules::PANIC_REACH && !f.suppressed),
            "{:?}",
            out.findings
        );
        assert_eq!(out.graph.panic_frontier, vec!["coordinator::entry::serve"]);
        let j = report_json(&out);
        let g = j.get("graph").unwrap();
        assert_eq!(g.get("edges").and_then(|n| n.as_usize()), Some(1));
        assert_eq!(
            g.get("panic_frontier").and_then(|a| a.as_arr()).map(Vec::len),
            Some(1)
        );
    }

    #[test]
    fn units_pass_runs_in_scope_and_reports() {
        // declared type harvested from one file, misused in another
        let types = file("src/util/cfg.rs", "pub struct Cfg { pub margin: Joules }");
        let user = file(
            "src/runtime/x.rs",
            "fn f(c: &Cfg, x_mj: f64) -> f64 { x_mj + c.margin.value() }",
        );
        let out = lint_files(&[types, user.clone()]);
        let hits: Vec<&Finding> = out
            .findings
            .iter()
            .filter(|f| f.rule == rules::UNIT_SCALE_MISMATCH)
            .collect();
        assert_eq!(hits.len(), 1, "{:?}", out.findings);
        assert_eq!(out.units.files_checked, 1); // util/ is harvested, not checked
        assert_eq!(out.units.fields_typed, 1);
        assert_eq!(out.units.findings, 1);
        let j = report_json(&out);
        let u = j.get("units").unwrap();
        assert_eq!(u.get("findings").and_then(|n| n.as_usize()), Some(1));
        assert_eq!(u.get("fields_typed").and_then(|n| n.as_usize()), Some(1));

        // out of scope (neither parity nor serving): same code, no pass
        let elsewhere = file(
            "src/util/x.rs",
            "fn f(a_mj: f64, b_s: f64) -> f64 { a_mj + b_s }",
        );
        let out = lint_files(&[elsewhere]);
        assert_eq!(out.units.files_checked, 0);
        assert_eq!(out.unsuppressed_count(), 0);
        // suppression pragmas apply to unit findings like any rule
        let with_pragma = file(
            "src/runtime/y.rs",
            "fn g(a_mj: f64, b_s: f64) -> f64 {\n    \
             // lint: allow(unit-mixed-add) — fixture\n    a_mj + b_s\n}",
        );
        let out = lint_files(&[user, with_pragma]);
        assert_eq!(out.suppressed_count(), 1, "{:?}", out.findings);
    }

    #[test]
    fn allow_inventory_counts_pragmas() {
        let out = lint_files(&[file(
            "src/runtime/x.rs",
            "fn f(o: Option<u32>) -> u32 { o.unwrap() } // lint: allow(panic-unwrap) — fixture",
        )]);
        assert_eq!(out.allow_count, 1);
        assert_eq!(out.unsuppressed_count(), 0);
        assert_eq!(out.suppressed_count(), 1);
    }
}
