//! Criterion-lite benchmark harness (criterion is not in the vendored
//! crate set).  Warmup + timed iterations with summary statistics, plus
//! the table plumbing the E1-E8 bench binaries share.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub per_iter: Summary,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.per_iter.mean * 1e9
    }

    pub fn report_line(&self) -> String {
        // a non-zero dropped count means some timing samples were
        // non-finite (clock artifacts) — surface it rather than letting
        // an all-zero summary read as a perfect result
        let dropped = if self.per_iter.dropped > 0 {
            format!(", dropped {}", self.per_iter.dropped)
        } else {
            String::new()
        };
        format!(
            "{:<40} {:>12.3} us/iter (p50 {:.3}, p99 {:.3}, n={}{})",
            self.name,
            self.per_iter.mean * 1e6,
            self.per_iter.p50 * 1e6,
            self.per_iter.p99 * 1e6,
            self.iterations,
            dropped
        )
    }
}

/// Time `f` for ~`target` wall time after ~10% warmup, batching iterations
/// so each sample is long enough to measure (>= 1 us).
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // warmup + batch-size calibration
    let warm_until = Instant::now() + target / 10;
    let mut calib_iters = 0u64;
    let calib_start = Instant::now();
    while Instant::now() < warm_until || calib_iters == 0 {
        f();
        calib_iters += 1;
    }
    let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters as f64;
    let batch = ((1e-5 / per_iter.max(1e-12)).ceil() as u64).clamp(1, 1_000_000);

    let mut samples = Vec::new();
    let mut iterations = 0u64;
    let t_end = Instant::now() + target;
    while Instant::now() < t_end {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iterations += batch;
        if samples.len() >= 10_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iterations,
        per_iter: Summary::of(&samples),
    }
}

/// Default wall budget per benchmark.
pub fn default_target() -> Duration {
    std::env::var("BENCH_SECS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
        .unwrap_or_else(|| Duration::from_millis(800))
}

/// Standard header for the E1-E8 bench binaries.
pub fn banner(id: &str, title: &str, paper_claim: &str) {
    println!("==========================================================");
    println!("{id}: {title}");
    println!("paper: {paper_claim}");
    println!("==========================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iterations > 100);
        assert!(r.per_iter.mean > 0.0);
        assert!(r.report_line().contains("us/iter"));
    }

    #[test]
    fn bench_ordering_sane() {
        let fast = bench("fast", Duration::from_millis(40), || {
            black_box((0..10).sum::<u64>());
        });
        let slow = bench("slow", Duration::from_millis(40), || {
            black_box((0..10_000).sum::<u64>());
        });
        assert!(slow.per_iter.mean > fast.per_iter.mean);
    }
}
