//! Criterion-lite benchmark harness (criterion is not in the vendored
//! crate set).  Warmup + timed iterations with summary statistics, plus
//! the table plumbing the E1-E8 bench binaries share and the
//! machine-readable `BENCH_<date>.json` trajectory writer.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub per_iter: Summary,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.per_iter.mean * 1e9
    }

    pub fn report_line(&self) -> String {
        // a non-zero dropped count means some timing samples were
        // non-finite (clock artifacts) — surface it rather than letting
        // an all-zero summary read as a perfect result
        let dropped = if self.per_iter.dropped > 0 {
            format!(", dropped {}", self.per_iter.dropped)
        } else {
            String::new()
        };
        format!(
            "{:<40} {:>12.3} us/iter (p50 {:.3}, p99 {:.3}, n={}{})",
            self.name,
            self.per_iter.mean * 1e6,
            self.per_iter.p50 * 1e6,
            self.per_iter.p99 * 1e6,
            self.iterations,
            dropped
        )
    }
}

/// Time `f` for ~`target` wall time after ~10% warmup, batching iterations
/// so each sample is long enough to measure (>= 1 us).
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // warmup + batch-size calibration
    let warm_until = Instant::now() + target / 10;
    let mut calib_iters = 0u64;
    let calib_start = Instant::now();
    while Instant::now() < warm_until || calib_iters == 0 {
        f();
        calib_iters += 1;
    }
    let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters as f64;
    let batch = ((1e-5 / per_iter.max(1e-12)).ceil() as u64).clamp(1, 1_000_000);

    let mut samples = Vec::new();
    let mut iterations = 0u64;
    let t_end = Instant::now() + target;
    while Instant::now() < t_end {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iterations += batch;
        if samples.len() >= 10_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iterations,
        per_iter: Summary::of(&samples),
    }
}

/// Default wall budget per benchmark.
pub fn default_target() -> Duration {
    std::env::var("BENCH_SECS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
        .unwrap_or_else(|| Duration::from_millis(800))
}

/// The machine-readable twin of the bench binaries' text output: a flat
/// `sections` map of section name -> representative wall-clock seconds
/// (harness benches record their median per-iter; the scaling sections
/// record their phase wall-clocks).  Written as `BENCH_<date>.json` so
/// successive runs leave a dated perf trajectory that scripts and CI can
/// diff without scraping stdout.  `BENCH_JSON_DIR` overrides the target
/// directory (default: the repo root, found by walking up to
/// ROADMAP.md); `BENCH_JSON_DATE` overrides the date stamp.
#[derive(Debug, Clone, Default)]
pub struct BenchJson {
    sections: Vec<(String, f64)>,
}

impl BenchJson {
    pub fn new() -> BenchJson {
        BenchJson::default()
    }

    /// Record one section's representative wall-clock, in seconds.
    pub fn record(&mut self, section: &str, seconds: f64) {
        self.sections.push((section.to_string(), seconds));
    }

    /// Record a harness result under its bench name (median per-iter).
    pub fn record_result(&mut self, r: &BenchResult) {
        self.record(&r.name, r.per_iter.p50);
    }

    pub fn len(&self) -> usize {
        self.sections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }

    /// Serialise as `{"date", "unit", "sections"}` (keys sorted, so the
    /// output is byte-deterministic for a given section set).
    pub fn render(&self, date: &str) -> String {
        let map: BTreeMap<String, Json> = self
            .sections
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        Json::obj(vec![
            ("date", Json::Str(date.to_string())),
            ("unit", Json::Str("seconds".into())),
            ("sections", Json::Obj(map)),
        ])
        .dump()
    }

    /// Write `BENCH_<date>.json` into the trajectory directory; returns
    /// the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let date = std::env::var("BENCH_JSON_DATE").unwrap_or_else(|_| utc_date());
        let dir = std::env::var("BENCH_JSON_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| bench_json_dir());
        self.write_to(&dir, &date)
    }

    /// Write `BENCH_<date>.json` into an explicit directory.
    pub fn write_to(&self, dir: &Path, date: &str) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{date}.json"));
        std::fs::write(&path, self.render(date) + "\n")?;
        Ok(path)
    }
}

/// Default trajectory directory: the repo root, found by walking up from
/// the cwd to the directory holding ROADMAP.md (falls back to the cwd so
/// a detached checkout still writes somewhere sensible).
fn bench_json_dir() -> PathBuf {
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut dir = start.clone();
    loop {
        if dir.join("ROADMAP.md").is_file() {
            return dir;
        }
        if !dir.pop() {
            return start;
        }
    }
}

/// Proleptic-Gregorian civil date from days since 1970-01-01
/// (Hinnant's `civil_from_days`).
fn civil_from_days(days: i64) -> (i64, u32, u32) {
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32;
    let y = yoe + era * 400 + i64::from(m <= 2);
    (y, m, d)
}

/// Today's UTC date as `YYYY-MM-DD`.
fn utc_date() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Standard header for the E1-E8 bench binaries.
pub fn banner(id: &str, title: &str, paper_claim: &str) {
    println!("==========================================================");
    println!("{id}: {title}");
    println!("paper: {paper_claim}");
    println!("==========================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iterations > 100);
        assert!(r.per_iter.mean > 0.0);
        assert!(r.report_line().contains("us/iter"));
    }

    #[test]
    fn civil_date_pins() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(59), (1970, 3, 1));
        assert_eq!(civil_from_days(11_016), (2000, 2, 29));
        assert_eq!(civil_from_days(20_000), (2024, 10, 4));
        assert_eq!(civil_from_days(20_672), (2026, 8, 7));
        let today = utc_date();
        assert_eq!(today.len(), 10);
        assert_eq!(today.as_bytes()[4], b'-');
        assert_eq!(today.as_bytes()[7], b'-');
    }

    #[test]
    fn bench_json_round_trips() {
        let mut j = BenchJson::new();
        assert!(j.is_empty());
        j.record("dse/sweep", 1.25);
        j.record("coordinator/2-shard", 0.5);
        assert_eq!(j.len(), 2);
        let text = j.render("2026-08-07");
        let parsed = crate::util::json::parse(&text).expect("render emits valid JSON");
        assert_eq!(parsed.path(&["date"]).as_str(), Some("2026-08-07"));
        assert_eq!(parsed.path(&["unit"]).as_str(), Some("seconds"));
        assert_eq!(parsed.path(&["sections", "dse/sweep"]).as_f64(), Some(1.25));
        assert_eq!(
            parsed.path(&["sections", "coordinator/2-shard"]).as_f64(),
            Some(0.5)
        );
        // byte-deterministic for a given section set
        assert_eq!(text, j.render("2026-08-07"));

        let dir = std::env::temp_dir().join(format!("elastic-bench-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = j.write_to(&dir, "2026-08-07").unwrap();
        assert!(path.ends_with("BENCH_2026-08-07.json"));
        let back = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(back.path(&["sections", "dse/sweep"]).as_f64(), Some(1.25));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_ordering_sane() {
        let fast = bench("fast", Duration::from_millis(40), || {
            black_box((0..10).sum::<u64>());
        });
        let slow = bench("slow", Duration::from_millis(40), || {
            black_box((0..10_000).sum::<u64>());
        });
        assert!(slow.per_iter.mean > fast.per_iter.mean);
    }
}
