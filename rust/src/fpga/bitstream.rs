//! Synthetic bitstream synthesis.
//!
//! E6 (Fritzsch et al. [21]) studies bitstream *compression*: the achievable
//! ratio depends on how much of the device a design actually uses, because
//! configuration frames for unused fabric are almost entirely zeros.  We
//! reproduce that structure: a bitstream is a sync header plus a sequence of
//! fixed-size configuration frames; frames covering used fabric carry
//! high-entropy payload, frames covering unused fabric are zero runs with a
//! sprinkle of default non-zero configuration words.

use super::device::FpgaDevice;
use crate::util::rng::Rng;

/// 7-series configuration frame payload: 101 words x 32 bit = 404 bytes.
pub const FRAME_BYTES: usize = 404;
/// Sync header (type-1 packets, sync word, device id...).
pub const HEADER_BYTES: usize = 64;

/// A synthesised configuration bitstream.
#[derive(Debug, Clone)]
pub struct Bitstream {
    pub bytes: Vec<u8>,
    /// Fraction of frames carrying real design content.
    pub used_frame_fraction: f64,
}

impl Bitstream {
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// Synthesise a bitstream for `device` with a design occupying
/// `utilization` of the fabric (0.0 ..= 1.0).  Deterministic in `seed`.
pub fn synthesize(device: &FpgaDevice, utilization: f64, seed: u64) -> Bitstream {
    let utilization = utilization.clamp(0.0, 1.0);
    let total = device.bitstream_bytes as usize;
    let n_frames = (total.saturating_sub(HEADER_BYTES)) / FRAME_BYTES;
    let mut rng = Rng::new(seed ^ 0xB175_74EA);
    let mut bytes = Vec::with_capacity(total);

    // header: sync word + type-1/type-2 command packets (fixed structure)
    bytes.extend_from_slice(&[0xFF; 16]); // dummy pad
    bytes.extend_from_slice(&[0xAA, 0x99, 0x55, 0x66]); // 7-series sync word
    while bytes.len() < HEADER_BYTES {
        bytes.push(0x20); // NOOP packets
    }

    // Frames for used fabric are interleaved with unused ones the way a
    // placed design is: a contiguous placed region plus scattered routing.
    let used_frames = (n_frames as f64 * utilization).round() as usize;
    for i in 0..n_frames {
        let in_placed_region = i < used_frames;
        // ~3% of "unused" frames still carry clock/IO default config
        let carries_content = in_placed_region || rng.chance(0.03);
        if carries_content {
            for _ in 0..FRAME_BYTES {
                bytes.push(rng.next_u64() as u8);
            }
        } else {
            // zero run with occasional default words
            for j in 0..FRAME_BYTES {
                if j % 101 == 0 && rng.chance(0.05) {
                    bytes.push(0x01);
                } else {
                    bytes.push(0x00);
                }
            }
        }
    }
    // trailer / padding up to the exact device bitstream length
    while bytes.len() < total {
        bytes.push(0x00);
    }
    bytes.truncate(total);

    Bitstream {
        bytes,
        used_frame_fraction: used_frames as f64 / n_frames.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;

    #[test]
    fn exact_device_length() {
        let d = device("xc7s15").unwrap();
        let b = synthesize(d, 0.5, 1);
        assert_eq!(b.len(), d.bitstream_bytes as usize);
    }

    #[test]
    fn deterministic() {
        let d = device("xc7s6").unwrap();
        assert_eq!(synthesize(d, 0.3, 7).bytes, synthesize(d, 0.3, 7).bytes);
    }

    #[test]
    fn sync_word_present() {
        let d = device("xc7s6").unwrap();
        let b = synthesize(d, 0.1, 1);
        assert_eq!(&b.bytes[16..20], &[0xAA, 0x99, 0x55, 0x66]);
    }

    #[test]
    fn sparsity_tracks_utilization() {
        let d = device("xc7s15").unwrap();
        let lo = synthesize(d, 0.05, 3);
        let hi = synthesize(d, 0.95, 3);
        let zeros = |b: &Bitstream| b.bytes.iter().filter(|&&x| x == 0).count();
        assert!(zeros(&lo) > zeros(&hi) * 3, "{} vs {}", zeros(&lo), zeros(&hi));
    }

    #[test]
    fn utilization_clamped() {
        let d = device("xc7s6").unwrap();
        let b = synthesize(d, 7.5, 1);
        assert!((b.used_frame_fraction - 1.0).abs() < 1e-9);
    }
}
