//! FPGA device substrate: the part catalog, synthetic bitstreams, the
//! compression study (E6) and the configuration-controller cost model that
//! the workload-aware strategies trade against.

pub mod bitstream;
pub mod compression;
pub mod config_ctrl;
pub mod device;

pub use config_ctrl::{ConfigController, ConfigSource};
pub use device::{device, Family, FpgaDevice, Resources, DEVICES};
