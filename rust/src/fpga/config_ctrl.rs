//! Configuration controller model: the time/energy cost of (re)configuring
//! the FPGA, with optional bitstream compression.
//!
//! This is the quantity the workload-aware strategies trade against idle
//! power ([6]): the On-Off strategy pays `powerup + config` on every
//! request, Idle-Waiting pays it once.

use super::compression::CompressionResult;
use super::device::FpgaDevice;
use crate::util::units::{Joules, Secs, Watts};

/// How the bitstream is delivered to the configuration port.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConfigSource {
    /// Raw bitstream streamed at the config clock.
    Raw,
    /// Compressed image; the soft decompressor streams at the config clock
    /// but only `compressed_bytes` must be fetched from flash, which is the
    /// bottleneck on the Elastic Node (flash SPI shares the config clock).
    Compressed { compressed_bytes: u32 },
}

/// Configuration controller bound to one device.
#[derive(Debug, Clone)]
pub struct ConfigController {
    pub device: &'static FpgaDevice,
    pub source: ConfigSource,
}

impl ConfigController {
    pub fn raw(device: &'static FpgaDevice) -> ConfigController {
        ConfigController {
            device,
            source: ConfigSource::Raw,
        }
    }

    pub fn compressed(device: &'static FpgaDevice, r: &CompressionResult) -> ConfigController {
        ConfigController {
            device,
            source: ConfigSource::Compressed {
                compressed_bytes: r.compressed_bytes as u32,
            },
        }
    }

    /// Bytes that must cross the flash/config link.
    pub fn transfer_bytes(&self) -> u32 {
        match self.source {
            ConfigSource::Raw => self.device.bitstream_bytes,
            ConfigSource::Compressed { compressed_bytes } => compressed_bytes,
        }
    }

    /// Time to configure, excluding power-up.
    pub fn config_time(&self) -> Secs {
        let bits = self.transfer_bytes() as f64 * 8.0;
        let raw = bits / (self.device.config_clock.value() * self.device.config_width_bits as f64);
        // the decompressor adds a small fixed pipeline overhead
        let overhead = match self.source {
            ConfigSource::Raw => 0.0,
            ConfigSource::Compressed { .. } => 50e-6,
        };
        Secs(raw + overhead)
    }

    /// Full power-off -> operational sequence time.
    pub fn cold_start_time(&self) -> Secs {
        Secs(self.device.powerup_s) + self.config_time()
    }

    /// Energy of the power-up + configuration sequence.
    pub fn cold_start_energy(&self) -> Joules {
        // power-up ramp at ~half config power, then configuration
        let ramp = Watts(self.device.config_power.value() * 0.5) * Secs(self.device.powerup_s);
        ramp + self.device.config_power * self.config_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::device;

    #[test]
    fn raw_config_time_matches_device() {
        let d = device("xc7s15").unwrap();
        let c = ConfigController::raw(d);
        assert!((c.config_time().value() - d.config_time_s()).abs() < 1e-12);
    }

    #[test]
    fn compression_shortens_config() {
        let d = device("xc7s15").unwrap();
        let raw = ConfigController::raw(d);
        let comp = ConfigController::compressed(
            d,
            &CompressionResult {
                original_bytes: d.bitstream_bytes as usize,
                compressed_bytes: d.bitstream_bytes as usize / 8,
            },
        );
        assert!(comp.config_time().value() < raw.config_time().value() / 6.0);
        assert!(comp.cold_start_energy().value() < raw.cold_start_energy().value());
    }

    #[test]
    fn cold_start_includes_powerup() {
        let d = device("xc7s6").unwrap();
        let c = ConfigController::raw(d);
        assert!(c.cold_start_time().value() > c.config_time().value());
        assert!(c.cold_start_energy().value() > 0.0);
    }
}
