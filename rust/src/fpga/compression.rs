//! Bitstream compression (E6, Fritzsch et al. [21]).
//!
//! Two codecs matched to what a soft decompressor on an MCU / config
//! controller can afford:
//!
//! * **RLE** — zero-run-length coding, the scheme actually deployable on
//!   tiny config controllers (decode is a counter); implemented here.
//! * **Deflate** — upper-bound general-purpose codec (flate2), standing in
//!   for the dictionary schemes the paper's related work explores.
//!
//! The interesting output is the *ratio as a function of device
//! utilisation*, which drives the configuration-time model used by the
//! workload-aware strategies.

use std::io::{Read, Write};

/// Result of compressing one bitstream.
#[derive(Debug, Clone)]
pub struct CompressionResult {
    pub original_bytes: usize,
    pub compressed_bytes: usize,
}

impl CompressionResult {
    pub fn ratio(&self) -> f64 {
        self.original_bytes as f64 / self.compressed_bytes.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// RLE codec: 0x00-run coding.
//
// Encoding: a literal block is `len (u8, 1..=255)` followed by `len` raw
// bytes; a zero run is `0x00` followed by a u16 (LE) run length (1..=65535).
// Chosen so the decoder is a ~10-line state machine (one BRAM FIFO + a
// counter in RTL terms).
// ---------------------------------------------------------------------------

/// RLE-encode `data`.
pub fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            let mut run = 0usize;
            while i + run < data.len() && data[i + run] == 0 && run < 65_535 {
                run += 1;
            }
            out.push(0x00);
            out.extend_from_slice(&(run as u16).to_le_bytes());
            i += run;
        } else {
            let start = i;
            while i < data.len() && data[i] != 0 && i - start < 255 {
                i += 1;
            }
            let lit = &data[start..i];
            out.push(lit.len() as u8);
            out.extend_from_slice(lit);
        }
    }
    out
}

/// Inverse of [`rle_encode`].
pub fn rle_decode(enc: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(enc.len() * 4);
    let mut i = 0;
    while i < enc.len() {
        let tag = enc[i];
        i += 1;
        if tag == 0x00 {
            if i + 2 > enc.len() {
                return Err("truncated zero-run header".into());
            }
            let run = u16::from_le_bytes([enc[i], enc[i + 1]]) as usize;
            i += 2;
            out.resize(out.len() + run, 0);
        } else {
            let len = tag as usize;
            if i + len > enc.len() {
                return Err("truncated literal block".into());
            }
            out.extend_from_slice(&enc[i..i + len]);
            i += len;
        }
    }
    Ok(out)
}

/// Compress with the RLE codec.
pub fn rle(data: &[u8]) -> CompressionResult {
    CompressionResult {
        original_bytes: data.len(),
        compressed_bytes: rle_encode(data).len(),
    }
}

/// Compress with deflate (flate2, level 6) — the general-purpose upper bound.
pub fn deflate(data: &[u8]) -> CompressionResult {
    let mut enc =
        flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::new(6));
    enc.write_all(data).expect("in-memory deflate");
    let compressed = enc.finish().expect("in-memory deflate finish");
    CompressionResult {
        original_bytes: data.len(),
        compressed_bytes: compressed.len(),
    }
}

/// Deflate round-trip helper used by tests.
pub fn deflate_roundtrip(data: &[u8]) -> Vec<u8> {
    let mut enc =
        flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::new(6));
    enc.write_all(data).unwrap();
    let c = enc.finish().unwrap();
    let mut dec = flate2::read::DeflateDecoder::new(&c[..]);
    let mut out = Vec::new();
    dec.read_to_end(&mut out).unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::{bitstream::synthesize, device::device};

    #[test]
    fn rle_roundtrip_random() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..20 {
            let n = rng.below(4096) as usize;
            let data: Vec<u8> = (0..n)
                .map(|_| {
                    if rng.chance(0.6) {
                        0
                    } else {
                        rng.next_u64() as u8
                    }
                })
                .collect();
            assert_eq!(rle_decode(&rle_encode(&data)).unwrap(), data);
        }
    }

    #[test]
    fn rle_roundtrip_edges() {
        for data in [vec![], vec![0u8; 200_000], vec![0xFF; 1000]] {
            assert_eq!(rle_decode(&rle_encode(&data)).unwrap(), data);
        }
    }

    #[test]
    fn rle_decode_rejects_truncation() {
        assert!(rle_decode(&[0x00, 0x10]).is_err());
        assert!(rle_decode(&[5, 1, 2]).is_err());
    }

    #[test]
    fn zero_heavy_compresses_well() {
        let mut data = vec![0u8; 100_000];
        data[500] = 7;
        let r = rle(&data);
        assert!(r.ratio() > 100.0, "ratio {}", r.ratio());
    }

    #[test]
    fn deflate_roundtrips() {
        let d = device("xc7s6").unwrap();
        let b = synthesize(d, 0.4, 9);
        assert_eq!(deflate_roundtrip(&b.bytes), b.bytes);
    }

    #[test]
    fn ratio_grows_as_utilization_drops() {
        // the paper's related work reports 1.05x (full device) .. 12.2x
        // (nearly empty device); the shape must reproduce
        let d = device("xc7s15").unwrap();
        let low = rle(&synthesize(d, 0.05, 3).bytes).ratio();
        let high = rle(&synthesize(d, 0.95, 3).bytes).ratio();
        assert!(low > 5.0, "low-util ratio {low}");
        assert!(high < 1.6, "high-util ratio {high}");
        assert!(low > 3.0 * high);
    }
}
