//! FPGA device catalog.
//!
//! Parametric models of the resource-constrained parts the paper's research
//! line targets: Spartan-7 (XC7S6/15/25, the Elastic Node main fabric
//! [8,22]), the older Spartan-6 LX9 [10], and the Lattice iCE40UP5K (the
//! low-static-power comparison point reachable with Radiant, §2.3).
//!
//! Constants are datasheet-derived (capacities, bitstream lengths) or
//! calibrated to the published measurements of the Elastic Node line
//! (static/config power).  Absolute watts are approximations; the design
//! space exploration depends on the *relative* standing of the devices,
//! which these numbers preserve (DESIGN.md §2 substitution table).

use crate::util::units::{Hertz, Watts};

/// FPGA resource vector.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    /// Logic LUTs (device-native: 6-input for 7-series, 4-input for iCE40).
    pub luts: u32,
    /// Flip-flops.
    pub ffs: u32,
    /// Block RAM, in 18 Kb-equivalent half-blocks.
    pub bram18: u32,
    /// DSP/MAC hard blocks.
    pub dsps: u32,
}

impl Resources {
    pub const fn new(luts: u32, ffs: u32, bram18: u32, dsps: u32) -> Resources {
        Resources { luts, ffs, bram18, dsps }
    }

    pub fn fits_in(&self, cap: &Resources) -> bool {
        self.luts <= cap.luts
            && self.ffs <= cap.ffs
            && self.bram18 <= cap.bram18
            && self.dsps <= cap.dsps
    }

    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            bram18: self.bram18 + o.bram18,
            dsps: self.dsps + o.dsps,
        }
    }

    pub fn scale(&self, k: u32) -> Resources {
        Resources {
            luts: self.luts * k,
            ffs: self.ffs * k,
            bram18: self.bram18 * k,
            dsps: self.dsps * k,
        }
    }

    /// Worst-case utilisation fraction against a capacity vector.
    pub fn utilization(&self, cap: &Resources) -> f64 {
        let frac = |a: u32, b: u32| {
            if b == 0 {
                if a == 0 { 0.0 } else { f64::INFINITY }
            } else {
                a as f64 / b as f64
            }
        };
        frac(self.luts, cap.luts)
            .max(frac(self.ffs, cap.ffs))
            .max(frac(self.bram18, cap.bram18))
            .max(frac(self.dsps, cap.dsps))
    }
}

/// FPGA family, selects the synthesis technology factors (eda::synth).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Spartan7,
    Spartan6,
    Ice40,
}

/// Static model of one FPGA part.
#[derive(Debug, Clone)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub family: Family,
    /// Process node in nm (drives the dynamic-power coefficient).
    pub node_nm: u32,
    pub resources: Resources,
    /// Static (leakage + fixed) power with the fabric configured and idle.
    pub static_power: Watts,
    /// Power drawn while the configuration controller is loading.
    pub config_power: Watts,
    /// Full configuration bitstream length in bytes.
    pub bitstream_bytes: u32,
    /// Configuration interface clock.
    pub config_clock: Hertz,
    /// Configuration interface width in bits (1 = SPI, 4 = QSPI, 8 = SelectMAP).
    pub config_width_bits: u32,
    /// Power-up ramp + PLL lock overhead before configuration can start.
    pub powerup_s: f64,
    /// Fabric speed ceiling for simple pipelined logic at this node.
    pub fmax_ceiling: Hertz,
    /// Dynamic power per MHz per 1000 LUTs toggling (calibration
    /// constant).  Fitted **per device**, so it is pre-scaled for the
    /// process node: `power::power` must not apply the 28 nm node factor
    /// to this term (only the shared DSP/BRAM surcharges scale by node).
    pub dyn_mw_per_mhz_per_klut: f64,
}

impl FpgaDevice {
    /// Raw (uncompressed) configuration time.
    pub fn config_time_s(&self) -> f64 {
        let bits = self.bitstream_bytes as f64 * 8.0;
        bits / (self.config_clock.value() * self.config_width_bits as f64)
    }
}

/// The device catalog.
pub static DEVICES: &[FpgaDevice] = &[
    FpgaDevice {
        name: "xc7s6",
        family: Family::Spartan7,
        node_nm: 28,
        resources: Resources::new(3750, 7500, 10, 10),
        static_power: Watts(0.026),
        config_power: Watts(0.110),
        // XC7S6 and XC7S15 share a die: identical bitstream length.
        bitstream_bytes: 4_310_752 / 8,
        config_clock: Hertz(66e6),
        config_width_bits: 1,
        powerup_s: 1.2e-3,
        fmax_ceiling: Hertz(160e6),
        dyn_mw_per_mhz_per_klut: 0.085,
    },
    FpgaDevice {
        name: "xc7s15",
        family: Family::Spartan7,
        node_nm: 28,
        resources: Resources::new(8000, 16_000, 20, 20),
        static_power: Watts(0.032),
        config_power: Watts(0.120),
        bitstream_bytes: 4_310_752 / 8,
        config_clock: Hertz(66e6),
        config_width_bits: 1,
        powerup_s: 1.2e-3,
        fmax_ceiling: Hertz(160e6),
        dyn_mw_per_mhz_per_klut: 0.085,
    },
    FpgaDevice {
        name: "xc7s25",
        family: Family::Spartan7,
        node_nm: 28,
        resources: Resources::new(14_600, 29_200, 90, 80),
        static_power: Watts(0.048),
        config_power: Watts(0.140),
        bitstream_bytes: 9_934_432 / 8,
        config_clock: Hertz(66e6),
        config_width_bits: 1,
        powerup_s: 1.2e-3,
        fmax_ceiling: Hertz(160e6),
        dyn_mw_per_mhz_per_klut: 0.085,
    },
    FpgaDevice {
        name: "lx9",
        family: Family::Spartan6,
        node_nm: 45,
        resources: Resources::new(5720, 11_440, 32, 16),
        static_power: Watts(0.041),
        config_power: Watts(0.130),
        bitstream_bytes: 2_742_528 / 8,
        config_clock: Hertz(26e6),
        config_width_bits: 1,
        powerup_s: 2.0e-3,
        fmax_ceiling: Hertz(100e6),
        dyn_mw_per_mhz_per_klut: 0.140,
    },
    FpgaDevice {
        name: "ice40up5k",
        family: Family::Ice40,
        node_nm: 40,
        resources: Resources::new(5280, 5280, 30, 8),
        // iCE40 UltraPlus headline feature: ~100 uW static.
        static_power: Watts(0.000_1),
        config_power: Watts(0.008),
        bitstream_bytes: 104_161,
        config_clock: Hertz(20e6),
        config_width_bits: 1,
        powerup_s: 0.8e-3,
        fmax_ceiling: Hertz(48e6),
        dyn_mw_per_mhz_per_klut: 0.060,
    },
];

/// Look a device up by name (case-insensitive).
pub fn device(name: &str) -> Option<&'static FpgaDevice> {
    let lower = name.to_ascii_lowercase();
    DEVICES.iter().find(|d| d.name == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert_eq!(device("XC7S15").unwrap().resources.luts, 8000);
        assert!(device("nope").is_none());
    }

    #[test]
    fn same_die_same_bitstream() {
        assert_eq!(
            device("xc7s6").unwrap().bitstream_bytes,
            device("xc7s15").unwrap().bitstream_bytes
        );
    }

    #[test]
    fn config_time_plausible() {
        // XC7S15 over 1-bit SPI @ 66 MHz: ~65 ms
        let t = device("xc7s15").unwrap().config_time_s();
        assert!((0.05..0.08).contains(&t), "config time {t}");
        // iCE40 is much faster to configure (tiny bitstream)
        assert!(device("ice40up5k").unwrap().config_time_s() < t);
    }

    #[test]
    fn fits_and_utilization() {
        let need = Resources::new(4000, 8000, 8, 12);
        let s6 = &device("xc7s6").unwrap().resources;
        let s15 = &device("xc7s15").unwrap().resources;
        assert!(!need.fits_in(s6));
        assert!(need.fits_in(s15));
        assert!((need.utilization(s15) - 0.6).abs() < 1e-9); // dsps 12/20
    }

    #[test]
    fn utilization_handles_zero_capacity() {
        let need = Resources::new(0, 0, 0, 1);
        let cap = Resources::new(100, 100, 10, 0);
        assert!(need.utilization(&cap).is_infinite());
        assert!(!need.fits_in(&cap));
    }

    #[test]
    fn static_power_ordering() {
        // iCE40's static power is orders of magnitude below Spartan-7's.
        let ice = device("ice40up5k").unwrap().static_power;
        let s7 = device("xc7s15").unwrap().static_power;
        assert!(ice.value() * 100.0 < s7.value());
    }

    #[test]
    fn resources_arithmetic() {
        let a = Resources::new(1, 2, 3, 4);
        let b = a.add(&a).scale(2);
        assert_eq!(b, Resources::new(4, 8, 12, 16));
    }
}
