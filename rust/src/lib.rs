//! # elastic-gen
//!
//! Reproduction of *"Leveraging Application-Specific Knowledge for
//! Energy-Efficient Deep Learning Accelerators on Resource-Constrained
//! FPGAs"* (Qian, CS.AR 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time Python)** — bit-true fixed-point Pallas kernels
//!   and JAX model graphs, AOT-lowered to HLO-text artifacts
//!   (`python/compile/`, `make artifacts`).
//! * **L3 (this crate)** — the paper's contribution: the accelerator
//!   *Generator* (design-space exploration over RTL templates ×
//!   workload-aware strategies × application constraints), every substrate
//!   it needs (FPGA device models, EDA estimation, behavioural simulation,
//!   discrete-event energy simulation, the Elastic Node testbed emulation)
//!   and a sharded serving coordinator that executes the compiled
//!   artifacts (PJRT CPU client under the `pjrt` feature, the bit-true
//!   behavioural executor otherwise).
//!
//! See DESIGN.md for the module inventory, the serving architecture, and
//! the experiment index (E1-E8, benches/).

pub mod analysis;
pub mod behav;
pub mod bench;
pub mod coordinator;
pub mod eda;
pub mod elastic_node;
pub mod fpga;
pub mod generator;
pub mod models;
pub mod obs;
pub mod power;
pub mod rtl;
pub mod runtime;
pub mod sim;
pub mod strategy;
pub mod util;
pub mod workload;

/// Workspace-relative artifacts directory (overridable via ELASTIC_ARTIFACTS).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ELASTIC_ARTIFACTS") {
        return p.into();
    }
    // look upward from cwd for an `artifacts/` directory (so tests,
    // examples and benches work from any workspace subdirectory)
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
