//! Current-sense measurement emulation ("Real Hardware Measurements",
//! §2.3).
//!
//! The Elastic Node instruments each rail with an INA226-class sensor:
//! finite LSB, gaussian noise, and a finite sampling rate.  The testbed
//! layer samples a ground-truth power trajectory through this model so
//! that "measured" numbers carry realistic uncertainty, and the evaluation
//! can cross-check EDA estimates against (emulated) hardware the way the
//! paper does.

use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::units::{Joules, Secs, Watts};

/// Sensor characteristics.
#[derive(Debug, Clone, Copy)]
pub struct Sensor {
    /// Power LSB (current LSB x bus voltage).
    pub lsb: Watts,
    /// Gaussian noise sigma.
    pub noise: Watts,
    /// Sampling interval.
    pub interval: Secs,
}

impl Default for Sensor {
    fn default() -> Sensor {
        Sensor {
            lsb: Watts::from_mw(0.025),
            noise: Watts::from_mw(0.08),
            interval: Secs::from_us(140.0), // INA226 1.1ms conv / 8 avg ~ fast mode
        }
    }
}

impl Sensor {
    /// One noisy, quantised sample of a true power value.
    pub fn sample(&self, truth: Watts, rng: &mut Rng) -> Watts {
        let noisy = truth.value() + rng.normal_ms(0.0, self.noise.value());
        let q = (noisy / self.lsb.value()).round() * self.lsb.value();
        Watts(q.max(0.0))
    }

    /// Sample a piecewise-constant power trajectory `(t_start, p)` segments
    /// over `[0, horizon]`; returns per-sample measurements and the
    /// integrated (measured) energy.
    pub fn measure_trajectory(
        &self,
        segments: &[(Secs, Watts)],
        horizon: Secs,
        rng: &mut Rng,
    ) -> MeasuredRun {
        assert!(!segments.is_empty());
        let mut samples = Vec::new();
        let mut energy = 0.0;
        let mut t = 0.0;
        let dt = self.interval.value();
        while t < horizon.value() {
            // find the active segment (segments sorted by start time)
            let p = segments
                .iter()
                .rev()
                .find(|(s, _)| s.value() <= t)
                .map(|(_, p)| *p)
                .unwrap_or(segments[0].1);
            let m = self.sample(p, rng);
            samples.push(m.value());
            energy += m.value() * dt;
            t += dt;
        }
        MeasuredRun {
            power_summary: Summary::of(&samples),
            energy: Joules(energy),
            n_samples: samples.len(),
        }
    }
}

/// Aggregated measurement of one run.
#[derive(Debug, Clone)]
pub struct MeasuredRun {
    pub power_summary: Summary,
    pub energy: Joules,
    pub n_samples: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_near_truth() {
        let s = Sensor::default();
        let mut rng = Rng::new(11);
        let truth = Watts::from_mw(50.0);
        let mean: f64 =
            (0..5000).map(|_| s.sample(truth, &mut rng).value()).sum::<f64>() / 5000.0;
        assert!((mean - truth.value()).abs() < 0.2e-3, "mean {mean}");
    }

    #[test]
    fn never_negative() {
        let s = Sensor::default();
        let mut rng = Rng::new(13);
        for _ in 0..1000 {
            assert!(s.sample(Watts(0.0), &mut rng).value() >= 0.0);
        }
    }

    #[test]
    fn trajectory_energy_close_to_truth() {
        let s = Sensor::default();
        let mut rng = Rng::new(17);
        // 100ms at 100mW then 100ms at 20mW -> 12 mJ
        let run = s.measure_trajectory(
            &[(Secs(0.0), Watts::from_mw(100.0)), (Secs(0.1), Watts::from_mw(20.0))],
            Secs(0.2),
            &mut rng,
        );
        assert!((run.energy.mj() - 12.0).abs() < 0.5, "energy {}", run.energy);
        assert!(run.n_samples > 1000);
    }
}
