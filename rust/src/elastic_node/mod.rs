//! Elastic Node platform emulation (§3.3, [8,9]).
//!
//! The Elastic Node is the research group's MCU + FPGA board: the MCU owns
//! the sensors and the FPGA power rail, streams bitstreams from flash into
//! the configuration port, and carries current-sense instrumentation on
//! every rail.  The simulator needs its power constants (the MCU and flash
//! are active *during configuration* — a first-order term in the On-Off
//! strategy's cost) and the measurement layer reproduces the INA-style
//! sensing used for "real hardware measurements".

pub mod measurement;

use crate::util::units::Watts;

/// Board-level power constants around the FPGA.
#[derive(Debug, Clone, Copy)]
pub struct Platform {
    /// MCU active (streaming a bitstream or marshalling a request).
    pub mcu_active: Watts,
    /// MCU in its sleep mode (waiting on a timer/sensor interrupt).
    pub mcu_sleep: Watts,
    /// SPI flash read current while a bitstream streams out.
    pub flash_read: Watts,
}

impl Default for Platform {
    fn default() -> Platform {
        // STM32-class MCU + NOR flash, values in the Elastic Node's
        // published envelope
        Platform {
            mcu_active: Watts::from_mw(30.0),
            mcu_sleep: Watts::from_mw(0.9),
            flash_read: Watts::from_mw(50.0),
        }
    }
}

impl Platform {
    /// Extra board power on top of the FPGA's own draw, per node state.
    pub fn overhead(&self, state: BoardState) -> Watts {
        match state {
            BoardState::Configuring => self.mcu_active + self.flash_read,
            BoardState::Serving => self.mcu_active,
            BoardState::Waiting => self.mcu_sleep,
        }
    }
}

/// Coarse board activity classes used for overhead accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoardState {
    /// MCU streaming the bitstream from flash.
    Configuring,
    /// MCU shuttling request/response data.
    Serving,
    /// Idle/off periods.
    Waiting,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_is_most_expensive_overhead() {
        let p = Platform::default();
        assert!(p.overhead(BoardState::Configuring).value() > p.overhead(BoardState::Serving).value());
        assert!(p.overhead(BoardState::Serving).value() > p.overhead(BoardState::Waiting).value());
    }
}
