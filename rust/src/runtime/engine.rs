//! Execution engine facade: one `Engine` type over three backends.
//!
//! * **pjrt** (feature `pjrt`) — the compiled HLO artifacts on the PJRT
//!   CPU client (`runtime/pjrt.rs`).  Needs the `xla` bindings, which are
//!   not on crates.io; see the feature note in Cargo.toml.
//! * **behavioural** (default) — the bit-true fixed-point executor
//!   (`behav::run_model`) over the same artifact manifest and exported
//!   weights.  Pure-integer activation variants match the compiled HLO
//!   bit-for-bit, so the serving stack behaves identically from a clean
//!   checkout with no native XLA install.
//! * **synthetic** — manifest-free artifacts burning a deterministic
//!   amount of CPU per request; the hermetic workload for coordinator
//!   tests and the shard-scaling benchmarks.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

use super::artifact::{ArtifactMeta, Manifest};
use crate::behav::{self, ExecConfig, ModelWeights};
use crate::models::Topology;
use crate::rtl::activation::ActVariant;
use crate::rtl::fixed_point::Q16_8;
use crate::util::rng::fnv1a;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A loaded artifact set ready to serve inference calls.
pub struct Engine {
    backend: Backend,
}

enum Backend {
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::PjrtEngine),
    Behav(BehavBackend),
    Synthetic(SyntheticBackend),
}

impl Engine {
    /// Load the named artifacts (all model artifacts when `names` is
    /// empty).  Uses PJRT when the `pjrt` feature is enabled, the
    /// behavioural executor otherwise.  Loading/compilation happens once,
    /// up front, so callers get artifact errors eagerly.
    pub fn load(artifacts_dir: &Path, names: &[&str]) -> Result<Engine> {
        Engine::load_impl(artifacts_dir, names, true)
    }

    /// Like [`Engine::load`], but an empty `names` list loads *no*
    /// artifacts — used by the affinity-sharded coordinator, where a
    /// shard may own an empty artifact group.
    pub fn load_exact(artifacts_dir: &Path, names: &[&str]) -> Result<Engine> {
        Engine::load_impl(artifacts_dir, names, false)
    }

    fn load_impl(artifacts_dir: &Path, names: &[&str], empty_means_all: bool) -> Result<Engine> {
        #[cfg(feature = "pjrt")]
        {
            Ok(Engine {
                backend: Backend::Pjrt(super::pjrt::PjrtEngine::load_with(
                    artifacts_dir,
                    names,
                    empty_means_all,
                )?),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(Engine {
                backend: Backend::Behav(BehavBackend::load(
                    artifacts_dir,
                    names,
                    empty_means_all,
                )?),
            })
        }
    }

    /// A manifest-free engine serving the synthetic artifacts in `spec`.
    pub fn synthetic(spec: SyntheticSpec) -> Engine {
        Engine {
            backend: Backend::Synthetic(SyntheticBackend::new(spec)),
        }
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.platform(),
            Backend::Behav(_) => "behav-cpu".to_string(),
            Backend::Synthetic(_) => "synthetic-cpu".to_string(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.manifest(),
            Backend::Behav(e) => &e.manifest,
            Backend::Synthetic(e) => &e.manifest,
        }
    }

    pub fn loaded(&self) -> Vec<&str> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.loaded(),
            Backend::Behav(e) => e.kernels.keys().map(|s| s.as_str()).collect(),
            Backend::Synthetic(e) => e.by_name.keys().map(|s| s.as_str()).collect(),
        }
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest().get(name)
    }

    /// Run one inference: flat f32 input -> flat f32 output.
    pub fn infer(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(e) => e.infer(name, input),
            Backend::Behav(e) => e.infer(name, input),
            Backend::Synthetic(e) => e.infer(name, input),
        }
    }

    /// Run a batch sequentially (single-FPGA semantics: the accelerator is
    /// one physical engine; batching amortises dispatch, not compute).
    pub fn infer_batch(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        inputs.iter().map(|x| self.infer(name, x)).collect()
    }
}

// ---------------------------------------------------------------------------
// behavioural backend
// ---------------------------------------------------------------------------

struct BehavBackend {
    manifest: Manifest,
    kernels: HashMap<String, BehavKernel>,
}

enum BehavKernel {
    Model {
        topology: Topology,
        weights: Arc<ModelWeights>,
        cfg: ExecConfig,
    },
    /// E2 activation micro-kernels: the variant applied elementwise.
    Activation { variant: ActVariant },
}

impl BehavBackend {
    fn load(artifacts_dir: &Path, names: &[&str], empty_means_all: bool) -> Result<BehavBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let selected: Vec<String> = if names.is_empty() && empty_means_all {
            manifest.models().map(|a| a.name.clone()).collect()
        } else {
            names.iter().map(|s| s.to_string()).collect()
        };
        let mut weights_cache: HashMap<String, Arc<ModelWeights>> = HashMap::new();
        let mut kernels = HashMap::new();
        for name in &selected {
            let meta = manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let act = meta.sigmoid_variant().ok_or_else(|| {
                anyhow!(
                    "artifact '{name}': unknown activation '{}/{}'",
                    meta.act,
                    meta.act_impl
                )
            })?;
            let kernel = if meta.kind == "activation" {
                BehavKernel::Activation { variant: act }
            } else {
                let topology = Topology::parse(&meta.model)
                    .ok_or_else(|| anyhow!("artifact '{name}': unknown model '{}'", meta.model))?;
                let weights = match weights_cache.get(&meta.model) {
                    Some(w) => w.clone(),
                    None => {
                        let w = Arc::new(behav::load(artifacts_dir, &meta.model)?);
                        weights_cache.insert(meta.model.clone(), w.clone());
                        w
                    }
                };
                BehavKernel::Model {
                    topology,
                    weights,
                    cfg: ExecConfig {
                        fmt: meta.fmt,
                        act,
                        tanh: meta.tanh_variant().unwrap_or(act),
                    },
                }
            };
            kernels.insert(name.clone(), kernel);
        }
        Ok(BehavBackend { manifest, kernels })
    }

    fn infer(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if input.len() != meta.input_len() {
            return Err(anyhow!(
                "{name}: input length {} != expected {}",
                input.len(),
                meta.input_len()
            ));
        }
        let kernel = self
            .kernels
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        match kernel {
            BehavKernel::Model {
                topology,
                weights,
                cfg,
            } => {
                let x: Vec<f64> = input.iter().map(|&v| v as f64).collect();
                let y = behav::run_model(*topology, weights, cfg, &x)
                    .with_context(|| format!("executing {name}"))?;
                Ok(y.into_iter().map(|v| v as f32).collect())
            }
            BehavKernel::Activation { variant } => {
                let fmt = meta.fmt;
                Ok(input
                    .iter()
                    .map(|&x| fmt.dequantize(variant.eval(fmt.quantize(x as f64), fmt)) as f32)
                    .collect())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// synthetic backend
// ---------------------------------------------------------------------------

/// One synthetic artifact: a named endpoint burning a deterministic amount
/// of CPU per request (`work_iters` rounds of an integer mix function).
#[derive(Debug, Clone)]
pub struct SyntheticArtifact {
    pub name: String,
    pub input_len: usize,
    pub output_len: usize,
    pub work_iters: u64,
}

/// Spec for a manifest-free engine (coordinator tests / scaling benches).
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub artifacts: Vec<SyntheticArtifact>,
}

impl SyntheticSpec {
    /// `count` identical artifacts named `syn.0` .. `syn.{count-1}`.
    pub fn uniform(count: usize, input_len: usize, output_len: usize, work_iters: u64) -> Self {
        SyntheticSpec {
            artifacts: (0..count)
                .map(|i| SyntheticArtifact {
                    name: format!("syn.{i}"),
                    input_len,
                    output_len,
                    work_iters,
                })
                .collect(),
        }
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.name.clone()).collect()
    }
}

struct SyntheticBackend {
    manifest: Manifest,
    by_name: HashMap<String, SyntheticArtifact>,
}

impl SyntheticBackend {
    fn new(spec: SyntheticSpec) -> SyntheticBackend {
        let artifacts = spec
            .artifacts
            .iter()
            .map(|a| ArtifactMeta {
                name: a.name.clone(),
                file: String::new(),
                kind: "model".to_string(),
                model: a.name.clone(),
                fmt: Q16_8,
                act: "sigmoid".to_string(),
                act_impl: "hard".to_string(),
                tanh_impl: String::new(),
                pipelined: false,
                alus: 1,
                input_shape: vec![a.input_len],
                output_shape: vec![a.output_len],
                note: "synthetic".to_string(),
            })
            .collect();
        SyntheticBackend {
            manifest: Manifest {
                dir: PathBuf::new(),
                artifacts,
            },
            by_name: spec
                .artifacts
                .into_iter()
                .map(|a| (a.name.clone(), a))
                .collect(),
        }
    }

    fn infer(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let art = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if input.len() != art.input_len {
            return Err(anyhow!(
                "{name}: input length {} != expected {}",
                input.len(),
                art.input_len
            ));
        }
        // absorb the input, then spin a multiply-rotate chain the optimiser
        // cannot collapse — deterministic per (artifact, input)
        let mut acc: u64 = 0xcbf2_9ce4_8422_2325 ^ fnv1a(name);
        for (i, &x) in input.iter().enumerate() {
            acc ^= (x.to_bits() as u64).wrapping_add(i as u64);
            acc = acc.wrapping_mul(0x100_0000_01b3);
        }
        for _ in 0..art.work_iters {
            acc = acc
                .rotate_left(7)
                .wrapping_mul(0x5851_f42d_4c95_7f2d)
                .wrapping_add(0x1405_7b7e_f767_814f);
        }
        Ok((0..art.output_len)
            .map(|j| {
                let h = acc.wrapping_add((j as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                ((h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) as f32
            })
            .collect())
    }
}

/// Convenience: load every model artifact from the default directory.
pub fn load_default() -> Result<Engine> {
    let dir = crate::artifacts_dir();
    Engine::load(&dir, &[]).with_context(|| {
        format!(
            "loading artifacts from {} (run `make artifacts` first)",
            dir.display()
        )
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_engine_serves_deterministically() {
        let engine = Engine::synthetic(SyntheticSpec::uniform(2, 4, 3, 100));
        assert_eq!(engine.platform(), "synthetic-cpu");
        assert_eq!(engine.loaded().len(), 2);
        let x = vec![0.25, -0.5, 1.0, 0.0];
        let a = engine.infer("syn.0", &x).unwrap();
        let b = engine.infer("syn.0", &x).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        // different artifact or input -> different digest
        assert_ne!(a, engine.infer("syn.1", &x).unwrap());
        assert_ne!(a, engine.infer("syn.0", &[0.25, -0.5, 1.0, 0.5]).unwrap());
    }

    #[test]
    fn synthetic_engine_validates_requests() {
        let engine = Engine::synthetic(SyntheticSpec::uniform(1, 4, 1, 10));
        assert!(engine.infer("syn.0", &[0.0; 3]).is_err());
        assert!(engine.infer("nope", &[0.0; 4]).is_err());
        assert!(engine.meta("syn.0").is_some());
        assert_eq!(engine.meta("syn.0").unwrap().input_len(), 4);
    }

    #[test]
    fn behav_engine_errors_without_artifacts() {
        // empty dir: manifest load must fail, not panic
        let r = Engine::load(Path::new("/definitely/missing"), &[]);
        assert!(r.is_err());
    }
}
