//! Runtime: artifact manifest + the execution engine that runs the
//! AOT-compiled artifacts on the request path (no Python).  The engine
//! dispatches to PJRT (feature `pjrt`), the bit-true behavioural executor
//! (default), or a synthetic CPU-burner backend for hermetic serving
//! tests — see `engine.rs`.  `adapt.rs` hosts the adaptive serving loop's
//! drift supervisor (observe → fit → sweep → drain-and-switch).

// serving path: a panic here takes down a shard mid-request, so the
// panic-surface invariant is enforced both by `elastic-gen lint` and at
// the clippy layer (tests opt back out per-module)
#![warn(clippy::unwrap_used, clippy::indexing_slicing)]

pub mod adapt;
pub mod artifact;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use adapt::{AdaptConfig, AdaptOutcome, AdaptState, Supervisor, SwitchDecision};
pub use artifact::{ArtifactMeta, Golden, Manifest};
pub use engine::{load_default, Engine, SyntheticArtifact, SyntheticSpec};
