//! Runtime: artifact manifest + the PJRT CPU execution engine that runs
//! the AOT-compiled HLO artifacts on the request path (no Python).

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Golden, Manifest};
pub use engine::{load_default, Engine};
