//! Runtime: artifact manifest + the execution engine that runs the
//! AOT-compiled artifacts on the request path (no Python).  The engine
//! dispatches to PJRT (feature `pjrt`), the bit-true behavioural executor
//! (default), or a synthetic CPU-burner backend for hermetic serving
//! tests — see `engine.rs`.  `adapt.rs` hosts the adaptive serving loop's
//! drift supervisor (observe → fit → sweep → drain-and-switch).

pub mod adapt;
pub mod artifact;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use adapt::{AdaptConfig, AdaptOutcome, AdaptState, Supervisor, SwitchDecision};
pub use artifact::{ArtifactMeta, Golden, Manifest};
pub use engine::{load_default, Engine, SyntheticArtifact, SyntheticSpec};
