//! PJRT execution backend (feature `pjrt`): loads the HLO-text artifacts,
//! compiles them once on the CPU PJRT client, and serves inference calls.
//!
//! HLO **text** is the interchange format — jax >= 0.5 serialises protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).  Lowering used `return_tuple=True`, so results
//! unwrap with `to_tuple1`.
//!
//! The executables hold raw runtime handles, so a `PjrtEngine` must stay
//! on the thread that created it — each coordinator shard owns one.

use super::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// A compiled-and-loaded artifact set bound to one PJRT client.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Load and compile the named artifacts (all model artifacts when
    /// `names` is empty).  Compilation happens once, up front.
    pub fn load(artifacts_dir: &Path, names: &[&str]) -> Result<PjrtEngine> {
        PjrtEngine::load_with(artifacts_dir, names, true)
    }

    /// As `load`; `empty_means_all` distinguishes "all models" from an
    /// intentionally empty artifact group (affinity-sharded coordinator).
    pub fn load_with(
        artifacts_dir: &Path,
        names: &[&str],
        empty_means_all: bool,
    ) -> Result<PjrtEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        let mut executables = HashMap::new();
        let selected: Vec<String> = if names.is_empty() && empty_means_all {
            manifest.models().map(|a| a.name.clone()).collect()
        } else {
            names.iter().map(|s| s.to_string()).collect()
        };
        for name in &selected {
            let meta = manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = manifest.hlo_path(meta);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(PjrtEngine {
            client,
            manifest,
            executables,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    /// Run one inference: flat f32 input -> flat f32 output.
    pub fn infer(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if input.len() != meta.input_len() {
            return Err(anyhow!(
                "{name}: input length {} != expected {}",
                input.len(),
                meta.input_len()
            ));
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;

        let dims: Vec<i64> = meta.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let out = result
            .first()
            .and_then(|per_device| per_device.first())
            .ok_or_else(|| anyhow!("execute {name}: empty result set"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow!("unwrap tuple: {e}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("read result: {e}"))?;
        if v.len() != meta.output_len() {
            return Err(anyhow!(
                "{name}: output length {} != expected {}",
                v.len(),
                meta.output_len()
            ));
        }
        Ok(v)
    }
}
