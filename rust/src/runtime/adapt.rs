//! The adaptive serving loop supervisor: observe → fit → sweep → switch.
//!
//! Closes the loop the paper leaves open — the coordinator serves a
//! configuration chosen once, offline, from a hand-written workload spec;
//! this module connects observed traffic back to design choice.  The
//! state machine (DESIGN.md "Adaptive serving loop"):
//!
//! * **Observing** — the coordinator's metrics record arrival timestamps
//!   into a bounded ring; below the fitter's sample floor (or on a
//!   degenerate trace) the supervisor stays here.
//! * **Fitting** — [`fit_trace`] recovers the generating family; if drift
//!   against the deployed spec's workload stays within the hysteresis
//!   threshold, nothing else runs.
//! * **Sweeping** — past the threshold, the calibrated sweep
//!   ([`calibrate_and_refine`], distributed when `dist` is set) re-ranks
//!   the design space against the *fitted* workload.  The winner must
//!   beat the deployed candidate's calibrated energy/item by more than
//!   the configured margin *net of* reconfiguration cost
//!   ([`ConfigController::cold_start_energy`] amortized over the fitted
//!   arrival rate) — otherwise the decision is "keep".
//! * **Draining / Switched** — [`Supervisor::run_cycle`] executes the
//!   drain-and-switch on the coordinator; a failed engine build aborts
//!   back to the old engine (state stays `Draining`), success records a
//!   switch event, rebaselines the deployed spec to the fitted workload
//!   and resets the arrival ring (hysteresis: drift is henceforth
//!   measured against the regime we just adapted to).
//!
//! [`Supervisor::evaluate`] is **pure**: it consumes an explicit trace
//! and never reads the wall clock, so the whole decision pipeline is
//! deterministic under a fixed seed and hermetically testable.

use crate::coordinator::{Coordinator, DecisionRecord, EngineSpec, SwitchInfo};
use crate::fpga::config_ctrl::ConfigController;
use crate::generator::{
    calibrate_and_refine, calibrate_and_refine_dist, AppSpec, CalibrateOpts, Calibration,
    DistOpts, Estimate,
};
use crate::obs::{CycleEvent, Event, Journal};
use crate::util::units::{Joules, Secs};
use crate::workload::fit::{drift, fit_trace, Family, FitReport};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Supervisor knobs.
#[derive(Debug, Clone)]
pub struct AdaptConfig {
    /// The application spec the deployment was generated for; its
    /// `workload` is the drift baseline and is rebaselined on switch.
    pub spec: AppSpec,
    /// The currently-deployed configuration.
    pub deployed: Estimate,
    /// Hysteresis: drift at or below this never triggers a sweep.
    pub drift_threshold: f64,
    /// Required net energy/item gain beyond the amortized reconfiguration
    /// cost; a switch happens only when the gain *strictly exceeds* this.
    pub margin: Joules,
    /// Horizon the one-time reconfiguration energy is amortized over.
    pub amortize_horizon: Secs,
    /// Sweep/calibration knobs (threads, replay length, seed, budget).
    pub calibrate: CalibrateOpts,
    /// When set, the re-exploration runs process-sharded.
    pub dist: Option<DistOpts>,
    /// Engine to install on switch; `None` reuses the coordinator's
    /// current engine spec (the modeled accelerator changes, the serving
    /// backend stays).
    pub switch_to: Option<EngineSpec>,
    /// Event journal the supervisor emits [`CycleEvent`]s into — one per
    /// `run_cycle`/`probe`, rejected decisions included.
    pub journal: Option<Arc<Journal>>,
}

impl AdaptConfig {
    pub fn new(spec: AppSpec, deployed: Estimate) -> AdaptConfig {
        AdaptConfig {
            spec,
            deployed,
            drift_threshold: 0.5,
            margin: Joules::ZERO,
            amortize_horizon: Secs(60.0),
            calibrate: CalibrateOpts::default(),
            dist: None,
            switch_to: None,
            journal: None,
        }
    }
}

/// Stage the adaptive cycle ended in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptState {
    /// Not enough (or degenerate) data — keep observing.
    Observing,
    /// Fit succeeded but drift is within the hysteresis threshold.
    Fitting,
    /// Sweep ran; the decision (if any) said keep — or recommended a
    /// switch that [`Supervisor::run_cycle`] has not executed yet.
    Sweeping,
    /// A switch was attempted but aborted (engine build failure); the old
    /// deployment keeps serving.
    Draining,
    /// The drain-and-switch completed and the baseline was rebased.
    Switched,
}

impl AdaptState {
    pub fn name(&self) -> &'static str {
        match self {
            AdaptState::Observing => "observing",
            AdaptState::Fitting => "fitting",
            AdaptState::Sweeping => "sweeping",
            AdaptState::Draining => "draining",
            AdaptState::Switched => "switched",
        }
    }
}

/// The switch predicate, fully expanded for reports and regression tests.
#[derive(Debug, Clone)]
pub struct SwitchDecision {
    /// The sweep winner under the fitted workload (corrected coordinates).
    pub to: Estimate,
    /// Deployed candidate's calibrated energy/item under the *fitted* gap.
    pub before: Joules,
    /// Winner's calibrated energy/item.
    pub after: Joules,
    /// One-time reconfiguration energy: cold start of the new device plus
    /// the deployed node idling through the swap window.
    pub reconfig: Joules,
    /// `reconfig` spread over the items the fitted rate serves within the
    /// amortization horizon.
    pub amortized: Joules,
    /// `(before - after) - amortized`.
    pub net_gain: Joules,
    /// True iff `net_gain` strictly exceeds the configured margin.
    pub switch: bool,
}

/// One pass through the state machine.
#[derive(Debug, Clone)]
pub struct AdaptOutcome {
    pub state: AdaptState,
    pub fit: FitReport,
    /// Drift of the fitted workload vs the deployed spec's workload.
    pub drift: Option<f64>,
    /// Present once a sweep ran and produced a feasible winner.
    pub decision: Option<SwitchDecision>,
    /// True when the distributed sweep failed and the supervisor fell
    /// back to the in-process pool.
    pub dist_fell_back: bool,
}

/// Drift supervisor.  `evaluate` is the pure decision pipeline;
/// `run_cycle` additionally reads the coordinator's arrival ring and
/// executes the drain-and-switch.
pub struct Supervisor {
    cfg: AdaptConfig,
    /// Monotonic cycle counter stamped into emitted [`CycleEvent`]s.
    cycle: u64,
}

impl Supervisor {
    pub fn new(cfg: AdaptConfig) -> Supervisor {
        Supervisor { cfg, cycle: 0 }
    }

    pub fn config(&self) -> &AdaptConfig {
        &self.cfg
    }

    /// The full observe→fit→sweep decision pipeline on an explicit trace.
    /// Pure and deterministic: no wall clock, no coordinator — the sweep
    /// seeds come from `cfg.calibrate`.  Never switches anything; the
    /// returned decision says whether a switch is warranted.
    pub fn evaluate(&self, trace: &[Secs]) -> AdaptOutcome {
        let report = fit_trace(trace);
        if report.family == Family::Unknown {
            return AdaptOutcome {
                state: AdaptState::Observing,
                fit: report,
                drift: None,
                decision: None,
                dist_fell_back: false,
            };
        }
        // a classified family always carries a fitted workload, but a
        // fitter regression must degrade to "keep observing", not panic
        let Some(fitted) = report.fitted.clone() else {
            return AdaptOutcome {
                state: AdaptState::Observing,
                fit: report,
                drift: None,
                decision: None,
                dist_fell_back: false,
            };
        };
        let drift_score = drift(&fitted, &self.cfg.spec.workload);
        let Some(d) = drift_score else {
            return AdaptOutcome {
                state: AdaptState::Observing,
                fit: report,
                drift: None,
                decision: None,
                dist_fell_back: false,
            };
        };
        if d <= self.cfg.drift_threshold {
            return AdaptOutcome {
                state: AdaptState::Fitting,
                fit: report,
                drift: Some(d),
                decision: None,
                dist_fell_back: false,
            };
        }

        // re-explore against the fitted workload
        let mut fitted_spec = self.cfg.spec.clone();
        fitted_spec.workload = fitted;
        let (cal, best, dist_fell_back) = self.sweep(&fitted_spec);
        let decision = best.map(|winner| self.decide(&cal, &fitted_spec, winner));
        AdaptOutcome {
            state: AdaptState::Sweeping,
            fit: report,
            drift: Some(d),
            decision,
            dist_fell_back,
        }
    }

    /// Calibrated sweep against the fitted spec; a failed distributed run
    /// falls back to the in-process pool rather than stalling the loop.
    fn sweep(&self, fitted_spec: &AppSpec) -> (Calibration, Option<Estimate>, bool) {
        if let Some(dopts) = &self.cfg.dist {
            match calibrate_and_refine_dist(fitted_spec, &self.cfg.calibrate, dopts) {
                Ok(out) => return (out.calibration, out.refined.best, false),
                Err(_) => {
                    let (cal, refined) = calibrate_and_refine(fitted_spec, &self.cfg.calibrate);
                    return (cal, refined.best, true);
                }
            }
        }
        let (cal, refined) = calibrate_and_refine(fitted_spec, &self.cfg.calibrate);
        (cal, refined.best, false)
    }

    /// The single definition of the switch predicate: switch iff
    /// `(before - after) - amortized > margin`, strictly.
    fn decide(&self, cal: &Calibration, fitted_spec: &AppSpec, winner: Estimate) -> SwitchDecision {
        let gap = fitted_spec.workload.mean_gap();
        let before = cal.scales.energy_per_item(&self.cfg.deployed, gap);
        let after = winner.energy_per_item;
        let cc = ConfigController::raw(winner.candidate.device);
        let reconfig =
            cc.cold_start_energy() + self.cfg.deployed.cost.idle_power * cc.cold_start_time();
        let items = (self.cfg.amortize_horizon / gap.max(Secs(1e-12))).max(1.0);
        let amortized = reconfig / items;
        let net_gain = (before - after) - amortized;
        SwitchDecision {
            to: winner,
            before,
            after,
            reconfig,
            amortized,
            net_gain,
            switch: net_gain > self.cfg.margin,
        }
    }

    /// One full cycle against a live coordinator: read the arrival ring
    /// for `artifact`, evaluate, and when the decision says switch,
    /// drain-and-switch the shards.  On success the deployed baseline is
    /// rebased onto the winner + fitted workload and the arrival ring is
    /// reset; on an aborted swap the old deployment keeps serving.
    pub fn run_cycle(&mut self, coord: &Coordinator, artifact: &str) -> Result<AdaptOutcome> {
        self.cycle += 1;
        let trace = coord.metrics().arrival_trace(artifact);
        let started = Instant::now();
        let mut outcome = self.evaluate(&trace);
        let cycle = Secs(started.elapsed().as_secs_f64());
        let Some(decision) = &outcome.decision else {
            self.note_cycle(coord, artifact, &outcome, cycle, false);
            return Ok(outcome);
        };
        if !decision.switch {
            self.note_cycle(coord, artifact, &outcome, cycle, false);
            return Ok(outcome);
        }

        let engine = self
            .cfg
            .switch_to
            .clone()
            .unwrap_or_else(|| coord.config().engine.clone());
        let info = SwitchInfo {
            from: self.cfg.deployed.candidate.describe(),
            to: decision.to.candidate.describe(),
            before_mj: Some(decision.before.mj()),
            after_mj: Some(decision.after.mj()),
            drift: outcome.drift,
        };
        let report = coord.swap_engines(engine, info)?;
        if report.all_swapped() {
            self.cfg.deployed = decision.to.clone();
            if let Some(w) = &outcome.fit.fitted {
                self.cfg.spec.workload = w.clone();
            }
            coord.metrics().reset_arrivals(artifact);
            outcome.state = AdaptState::Switched;
        } else {
            // abort edge: some shard kept its old engine — keep the old
            // baseline so the next cycle retries
            outcome.state = AdaptState::Draining;
        }
        let switched = outcome.state == AdaptState::Switched;
        self.note_cycle(coord, artifact, &outcome, cycle, switched);
        Ok(outcome)
    }

    /// Force one decision cycle regardless of drift: drop the hysteresis
    /// threshold for a single `evaluate` over the live arrival ring and
    /// record the outcome — **without executing any switch**.  Right
    /// after a committed switch the rebased baseline makes the sweep
    /// winner's net gain ≈ `-amortized`, so the recorded decision is a
    /// rejection: exactly the margin-gate audit trail the smoke run and
    /// anti-flapping analysis need.
    pub fn probe(&mut self, coord: &Coordinator, artifact: &str) -> AdaptOutcome {
        self.cycle += 1;
        let saved = self.cfg.drift_threshold;
        // any finite drift exceeds -1.0, so a successful fit always sweeps
        self.cfg.drift_threshold = -1.0;
        let trace = coord.metrics().arrival_trace(artifact);
        let started = Instant::now();
        let outcome = self.evaluate(&trace);
        let cycle = Secs(started.elapsed().as_secs_f64());
        self.cfg.drift_threshold = saved;
        self.note_cycle(coord, artifact, &outcome, cycle, false);
        outcome
    }

    /// Record one cycle's outcome into the metrics decision log and — when
    /// a journal is attached — as a [`CycleEvent`].  Called for *every*
    /// cycle: rejected and absent decisions are data, not noise.
    fn note_cycle(
        &self,
        coord: &Coordinator,
        artifact: &str,
        outcome: &AdaptOutcome,
        cycle: Secs,
        switched: bool,
    ) {
        if let Some(d) = &outcome.decision {
            coord.metrics().record_decision(DecisionRecord {
                at_s: 0.0,
                to: d.to.candidate.describe(),
                before_mj: d.before.mj(),
                after_mj: d.after.mj(),
                reconfig_mj: d.reconfig.mj(),
                amortized_mj: d.amortized.mj(),
                net_gain_mj: d.net_gain.mj(),
                margin_mj: self.cfg.margin.mj(),
                drift: outcome.drift,
                switched,
            });
        }
        if let Some(j) = &self.cfg.journal {
            let mut ev = CycleEvent::new(self.cycle, outcome.state.name(), artifact);
            ev.drift = outcome.drift;
            if outcome.fit.family != Family::Unknown {
                ev.family = Some(outcome.fit.family.name().to_string());
            }
            // the sweep dominates the cycle wall-clock; Observing/Fitting
            // cycles never swept, so their timing is uninteresting
            if matches!(
                outcome.state,
                AdaptState::Sweeping | AdaptState::Draining | AdaptState::Switched
            ) {
                ev.sweep_s = Some(cycle.value());
            }
            ev.decided = outcome.decision.is_some();
            ev.switched = switched;
            if let Some(d) = &outcome.decision {
                ev.to = Some(d.to.candidate.describe());
                ev.before_mj = Some(d.before.mj());
                ev.after_mj = Some(d.after.mj());
                ev.reconfig_mj = Some(d.reconfig.mj());
                ev.amortized_mj = Some(d.amortized.mj());
                ev.net_gain_mj = Some(d.net_gain.mj());
                ev.margin_mj = Some(self.cfg.margin.mj());
            }
            j.record(Event::Cycle(ev));
        }
    }

    /// Run cycles in a background thread every `interval` until `stop`
    /// is set, collecting the outcomes.  Serving continues concurrently:
    /// only the drain windows of an actual switch reject submissions.
    pub fn spawn(
        mut self,
        coord: Arc<Coordinator>,
        artifact: String,
        interval: Duration,
        stop: Arc<AtomicBool>,
    ) -> Result<JoinHandle<Vec<AdaptOutcome>>> {
        std::thread::Builder::new()
            .name("elastic-adapt".into())
            .spawn(move || {
                let mut outcomes = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    if let Ok(outcome) = self.run_cycle(&coord, &artifact) {
                        outcomes.push(outcome);
                    }
                    // sleep in small slices so stop stays responsive
                    let mut remaining = interval;
                    let slice = Duration::from_millis(20);
                    while !remaining.is_zero() && !stop.load(Ordering::SeqCst) {
                        let step = remaining.min(slice);
                        std::thread::sleep(step);
                        remaining -= step;
                    }
                }
                outcomes
            })
            .map_err(|e| anyhow::anyhow!("spawning adapt supervisor thread: {e}"))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::generator::{EvalPool, Evaluator, Goal, StrategyKind};
    use crate::util::rng::Rng;
    use crate::workload::Workload;

    /// A deployed estimate: the best idle-wait candidate for the spec
    /// (deliberately pinned to one strategy so a drifted workload can
    /// beat it with another).
    fn deployed_for(spec: &AppSpec, strategy: StrategyKind) -> Estimate {
        let space = crate::generator::design_space::enumerate(&spec.device_allowlist);
        let mut pool = EvalPool::new(2);
        let mut best: Option<Estimate> = None;
        for c in space.iter().filter(|c| c.strategy == strategy) {
            if let Some(e) = pool.evaluate(spec, c) {
                if e.feasible
                    && best
                        .as_ref()
                        .map(|b| e.score(spec.goal) > b.score(spec.goal))
                        .unwrap_or(true)
                {
                    best = Some(e);
                }
            }
        }
        best.expect("spec has at least one feasible candidate for the strategy")
    }

    fn quick_opts() -> CalibrateOpts {
        CalibrateOpts {
            threads: 2,
            requests: 120,
            ..CalibrateOpts::default()
        }
    }

    fn test_spec() -> AppSpec {
        let mut spec = AppSpec::soft_sensor();
        // narrow the space so sweeps stay fast in tests
        spec.device_allowlist = vec!["xc7s6"];
        spec.goal = Goal::EnergyPerItem;
        spec
    }

    #[test]
    fn observes_until_sample_floor() {
        let spec = test_spec();
        let deployed = deployed_for(&spec, StrategyKind::IdleWait);
        let sup = Supervisor::new(AdaptConfig::new(spec.clone(), deployed));
        let trace = spec.workload.arrivals(8, &mut Rng::new(1));
        let out = sup.evaluate(&trace);
        assert_eq!(out.state, AdaptState::Observing);
        assert!(out.decision.is_none());
    }

    #[test]
    fn hysteresis_holds_within_threshold() {
        let spec = test_spec();
        let deployed = deployed_for(&spec, StrategyKind::IdleWait);
        let mut cfg = AdaptConfig::new(spec.clone(), deployed);
        cfg.drift_threshold = 0.5;
        let sup = Supervisor::new(cfg);
        // a trace drawn from the deployed workload itself: drift ~ 0
        let trace = spec.workload.arrivals(512, &mut Rng::new(7));
        let out = sup.evaluate(&trace);
        assert_eq!(out.state, AdaptState::Fitting);
        assert!(out.drift.unwrap() <= 0.5, "drift {:?}", out.drift);
        assert!(out.decision.is_none(), "no sweep may run under the threshold");
    }

    #[test]
    fn drifted_workload_triggers_sweep_and_decision() {
        let spec = test_spec();
        let deployed = deployed_for(&spec, StrategyKind::IdleWait);
        let mut cfg = AdaptConfig::new(spec.clone(), deployed);
        cfg.drift_threshold = 0.5;
        cfg.calibrate = quick_opts();
        let sup = Supervisor::new(cfg);
        // the workload slows 50x: long gaps favour switching off
        let drifted = Workload::Poisson { mean_gap: Secs(2.5) };
        let trace = drifted.arrivals(512, &mut Rng::new(11));
        let out = sup.evaluate(&trace);
        assert_eq!(out.state, AdaptState::Sweeping);
        assert!(out.drift.unwrap() > 0.5);
        let d = out.decision.expect("sweep must produce a winner");
        assert!(d.before.value() > 0.0 && d.after.value() > 0.0);
        assert!(d.amortized.value() > 0.0);
        // predicate consistency
        assert_eq!(d.switch, d.net_gain.value() > 0.0);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let spec = test_spec();
        let deployed = deployed_for(&spec, StrategyKind::IdleWait);
        let mut cfg = AdaptConfig::new(spec, deployed);
        cfg.drift_threshold = 0.1;
        cfg.calibrate = quick_opts();
        let sup = Supervisor::new(cfg);
        let drifted = Workload::Poisson { mean_gap: Secs(1.0) };
        let trace = drifted.arrivals(256, &mut Rng::new(13));
        let a = sup.evaluate(&trace);
        let b = sup.evaluate(&trace);
        assert_eq!(a.state, b.state);
        assert_eq!(a.drift, b.drift);
        let (da, db) = (a.decision.unwrap(), b.decision.unwrap());
        assert_eq!(da.switch, db.switch);
        assert_eq!(da.net_gain.value().to_bits(), db.net_gain.value().to_bits());
        assert_eq!(da.to.candidate.describe(), db.to.candidate.describe());
    }

    /// The acceptance-criteria regression: a switch must never occur when
    /// net gain minus amortized reconfiguration cost is <= the margin.
    /// Crafted borderline: margin set to exactly the achievable net gain.
    #[test]
    fn borderline_margin_blocks_switch() {
        let spec = test_spec();
        let deployed = deployed_for(&spec, StrategyKind::IdleWait);
        let mut cfg = AdaptConfig::new(spec, deployed);
        cfg.drift_threshold = 0.1;
        cfg.calibrate = quick_opts();
        let drifted = Workload::Poisson { mean_gap: Secs(2.5) };
        let trace = drifted.arrivals(512, &mut Rng::new(11));

        let probe = Supervisor::new(cfg.clone()).evaluate(&trace);
        let gain = probe.decision.expect("winner expected").net_gain;
        assert!(
            gain.value() > 0.0,
            "borderline test needs a positive achievable gain, got {gain:?}"
        );

        // margin == exact achievable gain: "gain - cost <= margin" holds
        // with equality, so the strict predicate must refuse
        cfg.margin = gain;
        let at_margin = Supervisor::new(cfg.clone()).evaluate(&trace);
        assert!(
            !at_margin.decision.unwrap().switch,
            "switch at exact margin violates the strict predicate"
        );

        // a hair below the gain: now the switch is allowed
        cfg.margin = Joules(gain.value() * (1.0 - 1e-9));
        let below = Supervisor::new(cfg).evaluate(&trace);
        assert!(below.decision.unwrap().switch);
    }
}
