//! Artifact manifest: the index of AOT-compiled HLO modules written by
//! `python/compile/aot.py` (`artifacts/manifest.json`), plus the golden
//! cross-check vectors.

use crate::rtl::activation::ActVariant;
use crate::rtl::fixed_point::QFormat;
use crate::util::json::{parse_file, Json};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One compiled accelerator artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "model" or "activation" (E2 micro-kernels).
    pub kind: String,
    pub model: String,
    pub fmt: QFormat,
    pub act: String,
    pub act_impl: String,
    pub tanh_impl: String,
    pub pipelined: bool,
    pub alus: u32,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub note: String,
}

impl ArtifactMeta {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// The sigmoid-position activation variant of this artifact.
    pub fn sigmoid_variant(&self) -> Option<ActVariant> {
        ActVariant::parse(&self.act, &self.act_impl)
    }

    /// The tanh-position variant (LSTM/CNN artifacts).
    pub fn tanh_variant(&self) -> Option<ActVariant> {
        if self.tanh_impl.is_empty() {
            return None;
        }
        let kind = if self.tanh_impl == "hard" { "hardtanh" } else { "tanh" };
        ActVariant::parse(kind, &self.tanh_impl)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        // lint: allow(panic-reach) — the json parser's indexing is bounds-guarded; a bad
        // manifest file surfaces as Err from parse_file, not a panic
        let j = parse_file(&dir.join("manifest.json")).context("loading manifest")?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::new();
        for a in arts {
            artifacts.push(parse_artifact(a)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Model artifacts only (excludes the E2 activation micro-kernels).
    pub fn models(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == "model")
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactMeta> {
    let s = |k: &str| -> String {
        a.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string()
    };
    let shape = |k: &str| -> Vec<usize> {
        a.get(k)
            .and_then(|v| v.as_arr())
            .map(|arr| arr.iter().filter_map(|d| d.as_usize()).collect())
            .unwrap_or_default()
    };
    let fmt_name = s("fmt");
    let fmt = QFormat::parse(&fmt_name)
        .ok_or_else(|| anyhow!("artifact {}: bad fmt '{fmt_name}'", s("name")))?;
    Ok(ArtifactMeta {
        name: s("name"),
        file: s("file"),
        kind: s("kind"),
        model: s("model"),
        fmt,
        act: s("act"),
        act_impl: s("act_impl"),
        tanh_impl: s("tanh_impl"),
        pipelined: a.get("pipelined").and_then(|v| v.as_bool()).unwrap_or(false),
        alus: a.get("alus").and_then(|v| v.as_usize()).unwrap_or(1) as u32,
        input_shape: shape("input_shape"),
        output_shape: shape("output_shape"),
        note: s("note"),
    })
}

/// One golden test case: flat input/output pair.
#[derive(Debug, Clone)]
pub struct GoldenCase {
    pub input: Vec<f64>,
    pub output: Vec<f64>,
}

/// Golden vectors for one artifact.
#[derive(Debug, Clone)]
pub struct Golden {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub cases: Vec<GoldenCase>,
}

impl Golden {
    pub fn load(dir: &Path, name: &str) -> Result<Golden> {
        // lint: allow(panic-reach) — the json parser's indexing is bounds-guarded; bad
        // golden vectors surface as Err from parse_file, not a panic
        let j = parse_file(&dir.join("golden").join(format!("{name}.json")))
            .with_context(|| format!("golden vectors for {name}"))?;
        let cases = j
            .get("cases")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow!("golden {name}: missing cases"))?
            .iter()
            .map(|c| GoldenCase {
                input: c.get("input").map(|v| v.to_f64_vec()).unwrap_or_default(),
                output: c.get("output").map(|v| v.to_f64_vec()).unwrap_or_default(),
            })
            .collect();
        let shape = |k: &str| -> Vec<usize> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .map(|arr| arr.iter().filter_map(|d| d.as_usize()).collect())
                .unwrap_or_default()
        };
        Ok(Golden {
            name: name.to_string(),
            input_shape: shape("input_shape"),
            output_shape: shape("output_shape"),
            cases,
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::indexing_slicing)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parse_artifact_entry() {
        let j = parse(
            r#"{"name": "x.y", "file": "x.y.hlo.txt", "kind": "model",
                "model": "lstm_har", "fmt": "q16_8", "act": "sigmoid",
                "act_impl": "hard", "tanh_impl": "hard", "pipelined": true,
                "alus": 4, "input_shape": [24, 6], "output_shape": [6],
                "note": ""}"#,
        )
        .unwrap();
        let a = parse_artifact(&j).unwrap();
        assert_eq!(a.input_len(), 144);
        assert_eq!(a.output_len(), 6);
        assert!(a.pipelined);
        assert_eq!(a.fmt.frac_bits, 8);
        assert!(a.sigmoid_variant().is_some());
        assert!(a.tanh_variant().is_some());
    }

    #[test]
    fn bad_fmt_rejected() {
        let j = parse(r#"{"name": "x", "fmt": "zzz"}"#).unwrap();
        assert!(parse_artifact(&j).is_err());
    }

    // manifest-file loading is exercised by the integration tests (needs
    // `make artifacts`)
}
