//! Duty-cycled node simulation: a single-server queueing simulation of the
//! Elastic Node (MCU + FPGA) processing a request stream under a
//! workload-aware strategy, with exact joule accounting per power state.
//!
//! This is the evaluation engine behind E3 (Idle-Waiting vs On-Off), E4
//! (adaptive threshold switching) and the workload-aware terms of the
//! Generator's objective (E7).

pub mod lifetime;
pub mod multi;

use crate::elastic_node::{BoardState, Platform};
use crate::fpga::{ConfigController, FpgaDevice};
use crate::power;
use crate::rtl::composition::Accelerator;
use crate::strategy::{CostModel, GapPredictor, PostAction, Strategy};
use crate::util::units::{Hertz, Joules, Secs, Watts};
use std::collections::VecDeque;

/// Energy breakdown of one simulated run.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyLedger {
    pub config: Joules,
    pub busy: Joules,
    pub idle: Joules,
    pub off: Joules,
}

impl EnergyLedger {
    pub fn total(&self) -> Joules {
        self.config + self.busy + self.idle + self.off
    }
}

/// Outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub strategy: &'static str,
    pub served: u64,
    pub dropped: u64,
    pub sim_time: Secs,
    pub energy: EnergyLedger,
    /// Request latency (arrival -> completion), seconds, per served item.
    pub latencies: Vec<f64>,
    /// Cumulative total energy at each completion (for budget queries).
    pub energy_at_completion: Vec<f64>,
}

impl SimReport {
    pub fn energy_per_item(&self) -> Joules {
        if self.served == 0 {
            Joules(f64::INFINITY)
        } else {
            Joules(self.energy.total().value() / self.served as f64)
        }
    }

    /// E3's metric: how many items complete before the energy budget runs
    /// out.
    pub fn items_within_budget(&self, budget: Joules) -> u64 {
        self.energy_at_completion
            .iter()
            .take_while(|&&e| e <= budget.value())
            .count() as u64
    }
}

/// Build the strategy-facing cost model for an accelerator mapped on a
/// device at a clock, including the board overheads.
pub fn cost_model(
    acc: &Accelerator,
    device: &'static FpgaDevice,
    clock: Hertz,
    platform: &Platform,
    config: &ConfigController,
) -> CostModel {
    let est = power::power(acc, device, clock);
    let cold_time = config.cold_start_time();
    let cold_energy =
        config.cold_start_energy() + platform.overhead(BoardState::Configuring) * cold_time;
    CostModel {
        cold_energy,
        cold_time,
        idle_power: device.static_power + platform.overhead(BoardState::Waiting),
        off_power: platform.overhead(BoardState::Waiting),
        busy_time: acc.latency(clock),
        busy_power: est.total() + platform.overhead(BoardState::Serving),
        clock,
        min_clock: Hertz::from_mhz(1.0),
    }
}

/// Busy time/power at a scaled clock: latency stretches as f_nom/f, the
/// dynamic share of busy power scales with f.
fn scaled_busy(cost: &CostModel, f: Hertz) -> (Secs, Watts) {
    let ratio = f.value() / cost.clock.value();
    let t = Secs(cost.busy_time.value() / ratio);
    // split busy power: idle_power approximates the static + board share
    let dyn_part = (cost.busy_power.value() - cost.idle_power.value()).max(0.0);
    let p = Watts(cost.idle_power.value() + dyn_part * ratio);
    (t, p)
}

/// Single-server FIFO simulation of a request stream under `strategy`.
pub struct NodeSim {
    pub cost: CostModel,
    /// Maximum number of in-flight items (the one in service plus the
    /// queued backlog); arrivals beyond this bound are dropped (sensor
    /// buffers are finite on the Elastic Node).
    pub queue_capacity: usize,
    /// EMA weight of the gap predictor feeding the strategy.
    pub predictor_alpha: f64,
}

impl NodeSim {
    pub fn new(cost: CostModel) -> NodeSim {
        NodeSim {
            cost,
            queue_capacity: 64,
            predictor_alpha: 0.3,
        }
    }

    /// Run over a sorted arrival trace.  The FPGA starts powered off.
    pub fn run(&self, arrivals: &[Secs], strategy: &mut dyn Strategy) -> SimReport {
        let cost = &self.cost;
        let mut ledger = EnergyLedger::default();
        let mut latencies = Vec::with_capacity(arrivals.len());
        let mut energy_at_completion = Vec::with_capacity(arrivals.len());
        let mut predictor = GapPredictor::new(self.predictor_alpha);

        // node state between servings
        let mut powered_off = true;
        // time the server becomes free (configured or off per `powered_off`)
        let mut t_free = 0.0f64;
        // time up to which idle/off gap energy has been accounted; it
        // advances to every arrival *before* the admission check, so a
        // dropped request never leaves the gap behind it uncharged (the
        // node was burning idle or off power regardless of the drop)
        let mut t_acct = 0.0f64;
        let mut served = 0u64;
        let mut dropped = 0u64;
        // completion times of in-flight/queued work, for queue accounting
        let mut completions: VecDeque<f64> = VecDeque::new();

        for (i, a) in arrivals.iter().enumerate() {
            let a = a.value();
            while let Some(&c) = completions.front() {
                if c <= a {
                    completions.pop_front();
                } else {
                    break;
                }
            }

            // idle/off energy across any gap the node spent waiting before
            // this arrival (charged whether or not the request is admitted)
            if a > t_acct {
                let gap = Secs(a - t_acct);
                if powered_off {
                    ledger.off += cost.off_power * gap;
                } else {
                    ledger.idle += cost.idle_power * gap;
                }
                t_acct = a;
            }

            // admission: at most `queue_capacity` items in flight,
            // counting the one in service (`>=`, not `>` — the off-by-one
            // admitted capacity + 1)
            if completions.len() >= self.queue_capacity {
                dropped += 1;
                continue;
            }
            let mut t = a.max(t_free);

            // cold start if off (powered_off is re-decided after serving)
            if powered_off {
                ledger.config += cost.cold_energy;
                t += cost.cold_time.value();
            }

            // predicted gap for clock scaling + the post-decision
            let predicted = predictor
                .predict()
                .unwrap_or_else(|| Secs(cost.breakeven_gap().value().min(1.0)));

            // inference at the strategy's clock
            let f = strategy.clock(cost, predicted);
            let (busy_t, busy_p) = scaled_busy(cost, f);
            t += busy_t.value();
            ledger.busy += busy_p * busy_t;

            served += 1;
            latencies.push(t - a);
            energy_at_completion.push(ledger.total().value());
            completions.push_back(t);
            t_free = t;
            t_acct = t;

            // decide what to do until the next request
            match strategy.decide(cost, predicted) {
                PostAction::PowerOff => powered_off = true,
                PostAction::StayIdle => powered_off = false,
            }

            // feedback: realised gap between completion and next arrival
            if let Some(next) = arrivals.get(i + 1) {
                let realized = Secs((next.value() - t_free).max(0.0));
                strategy.observe(realized);
                predictor.observe(Secs((next.value() - a).max(1e-9)));
            }
        }

        SimReport {
            strategy: strategy.name(),
            served,
            dropped,
            sim_time: Secs(t_free),
            energy: ledger,
            latencies,
            energy_at_completion,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic_node::Platform;
    use crate::fpga::device::device;
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;
    use crate::strategy::{IdleWait, OnOff, PredefinedThreshold};
    use crate::util::rng::Rng;
    use crate::workload::Workload;

    fn fixture() -> (NodeSim, Vec<Secs>) {
        let acc = build(Topology::LstmHar, &BuildOpts::optimised(Q16_8));
        let d = device("xc7s15").unwrap();
        let platform = Platform::default();
        let cfg = ConfigController::raw(d);
        let cost = cost_model(&acc, d, Hertz::from_mhz(100.0), &platform, &cfg);
        let arrivals = Workload::Periodic { period: Secs::from_ms(40.0) }
            .arrivals(500, &mut Rng::new(1));
        (NodeSim::new(cost), arrivals)
    }

    #[test]
    fn idle_wait_beats_on_off_at_40ms() {
        let (sim, arrivals) = fixture();
        let idle = sim.run(&arrivals, &mut IdleWait);
        let onoff = sim.run(&arrivals, &mut OnOff);
        assert_eq!(idle.served, 500);
        let ratio = onoff.energy_per_item().value() / idle.energy_per_item().value();
        // the paper reports 12.39x at the 40ms period; the shape (order of
        // magnitude in idle-waiting's favour) must reproduce
        assert!(ratio > 4.0, "ratio {ratio}");
    }

    #[test]
    fn on_off_wins_at_long_periods() {
        let (sim, _) = fixture();
        let arrivals = Workload::Periodic { period: Secs(30.0) }
            .arrivals(30, &mut Rng::new(2));
        let idle = sim.run(&arrivals, &mut IdleWait);
        let onoff = sim.run(&arrivals, &mut OnOff);
        assert!(
            onoff.energy_per_item().value() < idle.energy_per_item().value(),
            "onoff {} !< idle {}",
            onoff.energy_per_item(),
            idle.energy_per_item()
        );
    }

    #[test]
    fn threshold_matches_best_pure_strategy_on_each_side() {
        let (sim, _) = fixture();
        for (period, best_is_idle) in [(Secs::from_ms(40.0), true), (Secs(30.0), false)] {
            let arrivals = Workload::Periodic { period }.arrivals(50, &mut Rng::new(3));
            let adaptive = sim.run(&arrivals, &mut PredefinedThreshold::breakeven());
            let idle = sim.run(&arrivals, &mut IdleWait);
            let onoff = sim.run(&arrivals, &mut OnOff);
            let best = if best_is_idle { &idle } else { &onoff };
            // the predictor has no history before the first gap: allow one
            // worst-case mispredicted gap on top of the pure optimum
            let slack = sim.cost.idle_power.value() * period.value()
                + sim.cost.cold_energy.value();
            assert!(
                adaptive.energy.total().value()
                    <= best.energy.total().value() * 1.05 + slack,
                "period {period}: adaptive {} vs best {}",
                adaptive.energy.total(),
                best.energy.total()
            );
        }
    }

    #[test]
    fn energy_ledger_components_positive() {
        let (sim, arrivals) = fixture();
        let r = sim.run(&arrivals, &mut OnOff);
        assert!(r.energy.config.value() > 0.0);
        assert!(r.energy.busy.value() > 0.0);
        assert!(r.energy.total().value() > r.energy.config.value());
    }

    #[test]
    fn budget_query_monotone() {
        let (sim, arrivals) = fixture();
        let r = sim.run(&arrivals, &mut IdleWait);
        let half = r.items_within_budget(Joules(r.energy.total().value() / 2.0));
        let full = r.items_within_budget(r.energy.total());
        assert!(half < full);
        assert_eq!(full, r.served);
    }

    #[test]
    fn latencies_include_cold_start() {
        let (sim, arrivals) = fixture();
        let onoff = sim.run(&arrivals, &mut OnOff);
        let idle = sim.run(&arrivals, &mut IdleWait);
        // every on-off response pays the ~66ms configuration
        assert!(onoff.latencies.iter().skip(2).all(|&l| l > 0.06));
        // idle-waiting responses are pure inference after the first
        assert!(idle.latencies.last().unwrap() < &0.01);
    }

    /// Synthetic cost model with service times that make queue dynamics
    /// exactly predictable on millisecond-spaced traces.
    fn slow_cost() -> CostModel {
        CostModel {
            cold_energy: Joules::from_mj(5.0),
            cold_time: Secs::from_ms(50.0),
            idle_power: Watts::from_mw(30.0),
            off_power: Watts::from_mw(0.9),
            busy_time: Secs::from_ms(100.0),
            busy_power: Watts::from_mw(80.0),
            clock: Hertz::from_mhz(100.0),
            min_clock: Hertz::from_mhz(5.0),
        }
    }

    #[test]
    fn overload_drops_requests() {
        let (sim, _) = fixture();
        // arrivals far faster than the on-off service time
        let arrivals = Workload::Periodic { period: Secs::from_ms(1.0) }
            .arrivals(2000, &mut Rng::new(4));
        let mut sim = sim;
        sim.queue_capacity = 4;
        let r = sim.run(&arrivals, &mut OnOff);
        assert!(r.dropped > 0, "expected drops");
        assert_eq!(r.served + r.dropped, 2000);

        // pin the exact admitted count: 10 arrivals 1 ms apart against a
        // 100 ms service time, so the first completion lands long after
        // the last arrival and exactly `queue_capacity` items (the one in
        // service plus the backlog) are admitted.  The old `>` bound
        // admitted capacity + 1.
        let mut sim = NodeSim::new(slow_cost());
        sim.queue_capacity = 3;
        let arrivals: Vec<Secs> = (1..=10).map(|i| Secs(i as f64 * 1e-3)).collect();
        let r = sim.run(&arrivals, &mut IdleWait);
        assert_eq!(r.served, 3, "queue bound admitted {} items", r.served);
        assert_eq!(r.dropped, 7);
    }

    #[test]
    fn dropped_arrivals_do_not_skip_gap_energy() {
        // capacity 0: every request is dropped, each inside an off gap;
        // the ledger must still charge the off power up to each arrival
        // (the old code `continue`d before the gap accounting)
        let cost = slow_cost();
        let mut sim = NodeSim::new(cost);
        sim.queue_capacity = 0;
        let r = sim.run(&[Secs(1.0), Secs(2.0)], &mut OnOff);
        assert_eq!(r.served, 0);
        assert_eq!(r.dropped, 2);
        let expect = cost.off_power.value() * 2.0;
        assert!(
            (r.energy.off.value() - expect).abs() < 1e-12,
            "off ledger {} != {expect}",
            r.energy.off.value()
        );
        assert_eq!(r.energy.total().value(), r.energy.off.value());
    }

    #[test]
    fn drop_leaves_ledger_identical_to_trace_without_it() {
        // a request dropped mid-run must not perturb the energy
        // accounting of the admitted ones: the ledger of a trace with the
        // drop equals the ledger of the same trace with the dropped
        // arrival removed (on-off ignores the gap predictor, which is the
        // only state a dropped arrival can influence)
        let mut sim = NodeSim::new(slow_cost());
        sim.queue_capacity = 1;
        let with_drop = sim.run(&[Secs(0.01), Secs(0.05), Secs(3.0)], &mut OnOff);
        let without = sim.run(&[Secs(0.01), Secs(3.0)], &mut OnOff);
        assert_eq!(with_drop.served, 2);
        assert_eq!(with_drop.dropped, 1);
        assert_eq!(without.served, 2);
        assert_eq!(without.dropped, 0);
        for (name, a, b) in [
            ("config", with_drop.energy.config, without.energy.config),
            ("busy", with_drop.energy.busy, without.energy.busy),
            ("idle", with_drop.energy.idle, without.energy.idle),
            ("off", with_drop.energy.off, without.energy.off),
        ] {
            assert!(
                (a.value() - b.value()).abs() < 1e-15,
                "{name}: {} vs {}",
                a.value(),
                b.value()
            );
        }
    }
}
