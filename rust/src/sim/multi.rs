//! Multi-accelerator node simulation: one FPGA, several generated
//! accelerators, a request stream that mixes models.
//!
//! This is the §4 future-work extension ("dynamic inclusion of inputs"):
//! when a request targets a model whose bitstream is not resident, the
//! node must reconfigure — so *which* accelerator stays resident becomes a
//! workload-aware decision.  Two policies:
//!
//! * [`SwapPolicy::Always`] — naive: reconfigure on every model switch.
//! * [`SwapPolicy::Hysteresis`] — keep the resident accelerator until the
//!   other model has been requested `threshold` times in a row (absorbs
//!   ping-pong mixes by batching requests MCU-side for the non-resident
//!   model up to a small buffer).

use crate::strategy::CostModel;
use crate::util::units::{Joules, Secs};

/// Per-model serving profile on the shared fabric.
#[derive(Debug, Clone, Copy)]
pub struct ModelProfile {
    /// Cold configuration of this model's bitstream.
    pub config_energy: Joules,
    pub config_time: Secs,
    /// One inference.
    pub busy_energy: Joules,
    pub busy_time: Secs,
}

impl ModelProfile {
    pub fn from_cost(cost: &CostModel) -> ModelProfile {
        ModelProfile {
            config_energy: cost.cold_energy,
            config_time: cost.cold_time,
            busy_energy: cost.busy_power * cost.busy_time,
            busy_time: cost.busy_time,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapPolicy {
    Always,
    /// Swap only after `threshold` consecutive foreign-model requests;
    /// foreign requests queue MCU-side meanwhile (bounded buffer).
    Hysteresis { threshold: u32, buffer: u32 },
}

/// Outcome of a multi-model run.
#[derive(Debug, Clone, Default)]
pub struct MultiReport {
    pub served: u64,
    pub deferred_served: u64,
    pub reconfigurations: u64,
    pub config_energy: Joules,
    pub busy_energy: Joules,
}

impl MultiReport {
    pub fn total_energy(&self) -> Joules {
        self.config_energy + self.busy_energy
    }
}

/// Simulate a request stream over two models (ids 0/1) with idle power
/// ignored (both policies idle identically; the comparison is about
/// reconfiguration energy).
pub fn run(
    profiles: [ModelProfile; 2],
    requests: &[u8],
    policy: SwapPolicy,
) -> MultiReport {
    let mut report = MultiReport::default();
    let mut resident: Option<u8> = None;
    let mut foreign_streak = 0u32;
    let mut deferred: Vec<u8> = Vec::new();

    let serve = |model: u8, report: &mut MultiReport| {
        let p = &profiles[model as usize];
        report.busy_energy += p.busy_energy;
        report.served += 1;
    };
    let configure = |model: u8, report: &mut MultiReport| {
        let p = &profiles[model as usize];
        report.config_energy += p.config_energy;
        report.reconfigurations += 1;
    };

    for &m in requests {
        debug_assert!(m < 2);
        match resident {
            None => {
                configure(m, &mut report);
                resident = Some(m);
                serve(m, &mut report);
            }
            Some(r) if r == m => {
                foreign_streak = 0;
                serve(m, &mut report);
            }
            Some(_) => match policy {
                SwapPolicy::Always => {
                    configure(m, &mut report);
                    resident = Some(m);
                    foreign_streak = 0;
                    serve(m, &mut report);
                }
                SwapPolicy::Hysteresis { threshold, buffer } => {
                    foreign_streak += 1;
                    deferred.push(m);
                    if foreign_streak >= threshold || deferred.len() as u32 >= buffer {
                        configure(m, &mut report);
                        resident = Some(m);
                        foreign_streak = 0;
                        for d in deferred.drain(..) {
                            let p = &profiles[d as usize];
                            report.busy_energy += p.busy_energy;
                            report.deferred_served += 1;
                            report.served += 1;
                        }
                    }
                }
            },
        }
    }
    // flush any deferred work at the end of the run
    if let (Some(_), false) = (resident, deferred.is_empty()) {
        let m = deferred[0];
        let mut cfg_done = false;
        for d in deferred.drain(..) {
            if !cfg_done {
                configure(m, &mut report);
                cfg_done = true;
            }
            let p = &profiles[d as usize];
            report.busy_energy += p.busy_energy;
            report.deferred_served += 1;
            report.served += 1;
        }
        resident = Some(m);
    }
    let _ = resident;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(cfg_mj: f64, busy_uj: f64) -> ModelProfile {
        ModelProfile {
            config_energy: Joules::from_mj(cfg_mj),
            config_time: Secs::from_ms(60.0),
            busy_energy: Joules::from_uj(busy_uj),
            busy_time: Secs::from_us(50.0),
        }
    }

    fn ping_pong(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i % 2) as u8).collect()
    }

    #[test]
    fn always_swaps_every_switch() {
        let r = run([profile(10.0, 5.0); 2], &ping_pong(100), SwapPolicy::Always);
        assert_eq!(r.reconfigurations, 100);
        assert_eq!(r.served, 100);
    }

    #[test]
    fn hysteresis_batches_ping_pong() {
        let r = run(
            [profile(10.0, 5.0); 2],
            &ping_pong(100),
            SwapPolicy::Hysteresis { threshold: 8, buffer: 16 },
        );
        assert_eq!(r.served, 100);
        assert!(r.reconfigurations < 20, "{}", r.reconfigurations);
        let naive = run([profile(10.0, 5.0); 2], &ping_pong(100), SwapPolicy::Always);
        assert!(r.total_energy().value() < naive.total_energy().value() / 4.0);
    }

    #[test]
    fn hysteresis_no_cost_on_single_model() {
        let reqs = vec![0u8; 50];
        let r = run(
            [profile(10.0, 5.0); 2],
            &reqs,
            SwapPolicy::Hysteresis { threshold: 4, buffer: 8 },
        );
        assert_eq!(r.reconfigurations, 1);
        assert_eq!(r.served, 50);
    }

    #[test]
    fn all_requests_eventually_served() {
        // trailing deferred requests must flush
        let mut reqs = vec![0u8; 5];
        reqs.extend([1, 1]); // below the threshold at stream end
        let r = run(
            [profile(10.0, 5.0); 2],
            &reqs,
            SwapPolicy::Hysteresis { threshold: 5, buffer: 8 },
        );
        assert_eq!(r.served, 7);
        assert_eq!(r.deferred_served, 2);
    }

    #[test]
    fn phase_structured_stream_cheap_for_both() {
        // long runs per model: hysteresis matches Always
        let mut reqs = vec![0u8; 40];
        reqs.extend(vec![1u8; 40]);
        let a = run([profile(10.0, 5.0); 2], &reqs, SwapPolicy::Always);
        let h = run(
            [profile(10.0, 5.0); 2],
            &reqs,
            SwapPolicy::Hysteresis { threshold: 4, buffer: 8 },
        );
        assert_eq!(a.reconfigurations, 2);
        assert_eq!(h.reconfigurations, 2);
    }
}
