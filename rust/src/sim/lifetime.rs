//! Battery-lifetime model ("effectively extending the system lifetime",
//! §3.2).
//!
//! Converts a simulated energy-per-item + workload rate into deployment
//! lifetime on a battery, with self-discharge and usable-capacity derating
//! — the numbers an IoT deployment actually plans against.

use super::SimReport;
use crate::util::units::{Joules, Secs, Watts};

/// A battery, described the way datasheets do.
#[derive(Debug, Clone, Copy)]
pub struct Battery {
    /// Nominal capacity in watt-hours.
    pub capacity_wh: f64,
    /// Fraction usable before brown-out (depth-of-discharge derating).
    pub usable_fraction: f64,
    /// Self-discharge per month (fraction of nominal).
    pub self_discharge_monthly: f64,
}

impl Battery {
    /// CR123A-class lithium primary cell.
    pub fn cr123a() -> Battery {
        Battery {
            capacity_wh: 4.5,
            usable_fraction: 0.85,
            self_discharge_monthly: 0.003,
        }
    }

    /// Compact LiPo pouch (rechargeable, deeper self-discharge).
    pub fn lipo_1000mah() -> Battery {
        Battery {
            capacity_wh: 3.7,
            usable_fraction: 0.80,
            self_discharge_monthly: 0.05,
        }
    }

    pub fn usable_energy(&self) -> Joules {
        Joules(self.capacity_wh * 3600.0 * self.usable_fraction)
    }

    /// Equivalent continuous self-discharge power.
    pub fn self_discharge_power(&self) -> Watts {
        let j_per_month = self.capacity_wh * 3600.0 * self.self_discharge_monthly;
        Watts(j_per_month / (30.0 * 86_400.0))
    }

    /// Deployment lifetime given a mean load power.
    pub fn lifetime(&self, load: Watts) -> Secs {
        let total = load + self.self_discharge_power();
        self.usable_energy() / total
    }
}

/// Lifetime from a simulation report: mean power = total energy / span.
pub fn lifetime_from_report(battery: &Battery, report: &SimReport) -> Secs {
    let mean_power = report.energy.total() / report.sim_time;
    battery.lifetime(mean_power)
}

/// Convenience: lifetime in days.
pub fn days(t: Secs) -> f64 {
    t.value() / 86_400.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic_node::Platform;
    use crate::fpga::{device, ConfigController};
    use crate::models::Topology;
    use crate::rtl::composition::{build, BuildOpts};
    use crate::rtl::fixed_point::Q16_8;
    use crate::sim::{cost_model, NodeSim};
    use crate::strategy::{IdleWait, OnOff};
    use crate::util::rng::Rng;
    use crate::util::units::Hertz;
    use crate::workload::Workload;

    #[test]
    fn cr123a_basics() {
        let b = Battery::cr123a();
        assert!((b.usable_energy().value() - 4.5 * 3600.0 * 0.85).abs() < 1e-6);
        // ~10 mW load: about two weeks
        let t = b.lifetime(Watts::from_mw(10.0));
        assert!(days(t) > 10.0 && days(t) < 25.0, "{} days", days(t));
    }

    #[test]
    fn self_discharge_bounds_lifetime() {
        let b = Battery::lipo_1000mah();
        // at (almost) zero load, lifetime approaches the self-discharge
        // limit (~16 months for 5%/month), not infinity
        let t = b.lifetime(Watts(1e-9));
        assert!(days(t) < 700.0, "{} days", days(t));
    }

    #[test]
    fn idle_wait_extends_lifetime_at_40ms() {
        // the paper's framing of E3: the strategy choice extends system
        // lifetime
        let acc = build(Topology::LstmHar, &BuildOpts::optimised(Q16_8));
        let d = device("xc7s15").unwrap();
        let cost = cost_model(
            &acc,
            d,
            Hertz::from_mhz(100.0),
            &Platform::default(),
            &ConfigController::raw(d),
        );
        let arrivals = Workload::Periodic {
            period: crate::util::units::Secs::from_ms(40.0),
        }
        .arrivals(500, &mut Rng::new(1));
        let sim = NodeSim::new(cost);
        let b = Battery::cr123a();
        let idle = lifetime_from_report(&b, &sim.run(&arrivals, &mut IdleWait));
        let onoff = lifetime_from_report(&b, &sim.run(&arrivals, &mut OnOff));
        assert!(
            idle.value() > 3.0 * onoff.value(),
            "idle {} vs onoff {} days",
            days(idle),
            days(onoff)
        );
    }
}
