//! The estimator↔simulator calibration loop (§2.2): the closed-form
//! workload-energy model sweeps thousands of candidates, the
//! discrete-event simulator validates the finalists — this module
//! reconciles the two.
//!
//! Pipeline: sweep → Pareto finalists → DES replay of each finalist on
//! the spec's workload trace (parallel via [`map_ordered`], bit-identical
//! across thread counts) → per-component least-squares fit of the
//! closed-form constants against the DES ledger → rank-agreement check
//! (Kendall tau + crossover count) → corrected constants fed back into a
//! [`CalibratedEstimator`] for an optional refinement sweep.
//!
//! The fit is one multiplier per energy term, in the DES ledger's own
//! coordinates ([`EnergyComponents`]): `busy` corrects the dynamic-power
//! chain (`dyn_mw_per_mhz_per_klut` and the DSP/BRAM surcharges fold
//! into busy power together), `cold` corrects the cold-start energy, and
//! `idle`/`off` correct the gap overheads.  A fit that does not improve
//! rank agreement is discarded in favour of the identity scales, so
//! calibration can never make the estimator's ranking worse.

use super::constraints::AppSpec;
use super::design_space::StrategyKind;
use super::estimator::{
    strategy_energy_components, strategy_energy_per_item, EnergyComponents, Estimate,
};
use super::eval::{default_threads, map_ordered, EvalPool, Evaluator};
use super::search::exhaustive::Exhaustive;
use super::search::pareto::ParetoFront;
use super::search::Searcher;
use crate::sim::NodeSim;
use crate::util::rng::Rng;
use crate::util::units::{Joules, Secs};

/// Multiplicative corrections to the closed-form model's energy
/// constants, fitted against DES ledgers.  Identity = uncalibrated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelScales {
    /// Busy-power multiplier: corrects the `dyn_mw_per_mhz_per_klut` +
    /// DSP/BRAM-surcharge chain (they enter busy power together).
    pub busy: f64,
    /// Idle-overhead multiplier (device static + board wait overhead).
    pub idle: f64,
    /// Off-overhead multiplier (MCU sleep).
    pub off: f64,
    /// Cold-start (power-up + configuration) energy multiplier.
    pub cold: f64,
}

impl ModelScales {
    pub fn identity() -> ModelScales {
        ModelScales { busy: 1.0, idle: 1.0, off: 1.0, cold: 1.0 }
    }

    pub fn is_identity(&self) -> bool {
        *self == ModelScales::identity()
    }

    /// The four components as raw bits — the single parity predicate the
    /// driver's refinement merge, the CLI parity checks, the tests and
    /// the benches all compare with (bit equality, never approximate).
    pub fn to_bits(&self) -> [u64; 4] {
        [
            self.busy.to_bits(),
            self.idle.to_bits(),
            self.off.to_bits(),
            self.cold.to_bits(),
        ]
    }

    /// Corrected closed-form energy per item for an estimate at mean gap
    /// `g`: the scales are pushed into the cost model and the closed form
    /// re-evaluated, so a threshold strategy may legitimately flip to the
    /// other side of its (corrected) crossover.
    pub fn energy_per_item(&self, e: &Estimate, g: Secs) -> Joules {
        let cost = e.cost.with_corrections(self.busy, self.idle, self.off, self.cold);
        strategy_energy_per_item(&cost, e.candidate.strategy, g)
    }

    /// Apply this correction to an estimate: replace its closed-form
    /// energy per item with the corrected value for the spec's workload.
    /// This is the single definition of "corrected coordinates" — the
    /// [`CalibratedEstimator`] and the distributed refinement merge both
    /// go through here, so a driver re-deriving a worker's corrected
    /// estimate reproduces it bit-for-bit.
    pub fn correct_estimate(&self, spec: &AppSpec, mut e: Estimate) -> Estimate {
        e.energy_per_item = self.energy_per_item(&e, spec.workload.mean_gap());
        e
    }

    /// Weighted mean of several fits, per component — how the distributed
    /// DSE driver folds trusted shards' per-host scales into one
    /// consensus correction (weights are each shard's replayed-finalist
    /// count).  Zero total weight falls back to the identity.
    pub fn weighted_mean(fits: &[(ModelScales, f64)]) -> ModelScales {
        let (mut busy, mut idle, mut off, mut cold) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let mut total = 0.0f64;
        for (s, w) in fits {
            if !w.is_finite() || *w <= 0.0 {
                continue;
            }
            busy += s.busy * w;
            idle += s.idle * w;
            off += s.off * w;
            cold += s.cold * w;
            total += w;
        }
        if total <= 0.0 {
            return ModelScales::identity();
        }
        ModelScales {
            busy: busy / total,
            idle: idle / total,
            off: off / total,
            cold: cold / total,
        }
    }
}

impl Default for ModelScales {
    fn default() -> ModelScales {
        ModelScales::identity()
    }
}

/// One finalist's DES replay outcome, with the simulated ledger reduced
/// to per-served-item components in the closed form's coordinates.
#[derive(Debug, Clone)]
pub struct Replay {
    pub estimate: Estimate,
    pub sim_energy_per_item: Joules,
    pub sim_components: EnergyComponents,
    pub served: u64,
    pub dropped: u64,
}

/// Replay one finalist through the DES on a shared workload trace.
pub fn replay_one(e: &Estimate, arrivals: &[Secs]) -> Replay {
    let mut strategy = e.candidate.strategy.instantiate();
    let report = NodeSim::new(e.cost).run(arrivals, strategy.as_mut());
    let per = |j: Joules| {
        if report.served == 0 {
            Joules(f64::INFINITY)
        } else {
            Joules(j.value() / report.served as f64)
        }
    };
    Replay {
        estimate: e.clone(),
        sim_energy_per_item: report.energy_per_item(),
        sim_components: EnergyComponents {
            busy: per(report.energy.busy),
            idle: per(report.energy.idle),
            off: per(report.energy.off),
            cold: per(report.energy.config),
        },
        served: report.served,
        dropped: report.dropped,
    }
}

/// Parallel DES replay of the finalists on one shared arrival trace.
/// Chunk-sharded like `EvalPool` batches and merged in submission order,
/// so the result is bit-identical across thread counts.
pub fn replay_all(finalists: &[Estimate], arrivals: &[Secs], threads: usize) -> Vec<Replay> {
    map_ordered(threads, finalists, |e| replay_one(e, arrivals))
}

/// Per-component least squares of `sim = θ · closed_form` over the
/// replayed finalists: θ_k = Σ pred·sim / Σ pred² is the exact
/// one-parameter solution per component, computed independently for
/// busy/idle/off/cold.  Components the finalists never exercise (zero
/// predicted everywhere) keep the identity scale.  Clock-scaling
/// finalists are excluded: their DES ledger books the stretched window's
/// static share as busy energy, which the closed form's coordinates
/// split differently — they still count for the rank-agreement check.
pub fn fit(spec: &AppSpec, replays: &[Replay]) -> ModelScales {
    let g = spec.workload.mean_gap();
    let mut num = [0.0f64; 4];
    let mut den = [0.0f64; 4];
    for r in replays {
        if r.served == 0 || r.estimate.candidate.strategy == StrategyKind::ClockScale {
            continue;
        }
        let p = strategy_energy_components(&r.estimate.cost, r.estimate.candidate.strategy, g);
        let a = &r.sim_components;
        let pairs = [
            (p.busy, a.busy),
            (p.idle, a.idle),
            (p.off, a.off),
            (p.cold, a.cold),
        ];
        for ((pv, av), (nk, dk)) in pairs
            .into_iter()
            .zip(num.iter_mut().zip(den.iter_mut()))
        {
            *nk += pv.value() * av.value();
            *dk += pv.value() * pv.value();
        }
    }
    let theta = |n: f64, d: f64| if d > 1e-30 { n / d } else { 1.0 };
    let [n0, n1, n2, n3] = num;
    let [d0, d1, d2, d3] = den;
    ModelScales {
        busy: theta(n0, d0),
        idle: theta(n1, d1),
        off: theta(n2, d2),
        cold: theta(n3, d3),
    }
}

/// Rank agreement between two paired score lists.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankAgreement {
    /// Kendall tau-a in [-1, 1]; 1 = identical ranking.
    pub tau: f64,
    /// Discordant pairs: finalists the two metrics order oppositely.
    pub crossovers: usize,
    /// Total pairs compared, n·(n-1)/2.
    pub pairs: usize,
}

/// Kendall tau-a over all pairs (ties count as neither concordant nor
/// discordant), plus the crossover count.
pub fn rank_agreement(a: &[f64], b: &[f64]) -> RankAgreement {
    assert_eq!(a.len(), b.len(), "paired score lists differ in length");
    let n = a.len();
    if n < 2 {
        return RankAgreement { tau: 1.0, crossovers: 0, pairs: 0 };
    }
    let mut concordant = 0usize;
    let mut discordant = 0usize;
    for (i, (ai, bi)) in a.iter().zip(b.iter()).enumerate() {
        for (aj, bj) in a.iter().zip(b.iter()).skip(i + 1) {
            let s = (ai - aj) * (bi - bj);
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = n * (n - 1) / 2;
    RankAgreement {
        tau: (concordant as f64 - discordant as f64) / pairs as f64,
        crossovers: discordant,
        pairs,
    }
}

/// Knobs for the calibration pipeline.
#[derive(Debug, Clone)]
pub struct CalibrateOpts {
    /// Worker threads for both the sweep and the DES replay stage.
    pub threads: usize,
    /// Length of the replayed arrival trace per finalist.
    pub requests: usize,
    /// Workload-trace seed (one trace shared by every finalist).
    pub seed: u64,
    /// Optional estimator-evaluation budget for the sweep.
    pub budget: Option<usize>,
}

impl Default for CalibrateOpts {
    fn default() -> CalibrateOpts {
        CalibrateOpts {
            threads: default_threads(),
            requests: 600,
            seed: 11,
            budget: None,
        }
    }
}

/// Outcome of one scenario's calibration.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub spec: AppSpec,
    /// The scales in force after the guard (identity if the fit fell back).
    pub scales: ModelScales,
    /// True when the fitted scales were discarded because they did not
    /// improve rank agreement.
    pub fell_back: bool,
    /// Per-finalist DES replays, in deterministic (describe-sorted) order.
    pub replays: Vec<Replay>,
    /// Agreement of the uncalibrated closed form vs the DES.
    pub before: RankAgreement,
    /// Agreement of the calibrated closed form vs the DES (== `before`
    /// when the fit fell back).
    pub after: RankAgreement,
    /// Agreement of the *fitted* scales before the fallback guard —
    /// equals `after` unless the fit fell back; kept so callers can
    /// alert on a fit that regressed agreement even though the guard
    /// discarded it.
    pub fitted: RankAgreement,
    /// Best estimate of the sweep that produced the finalists, if the
    /// pipeline ran one (None when calibrating externally-supplied
    /// finalists).
    pub sweep_best: Option<Estimate>,
}

/// Calibrate against an explicit finalist set (e.g. the Pareto front a
/// caller already swept).  Finalists are describe-sorted first so the
/// outcome is independent of the order the sweep produced them in.
pub fn calibrate_finalists(
    spec: &AppSpec,
    mut finalists: Vec<Estimate>,
    opts: &CalibrateOpts,
) -> Calibration {
    finalists.sort_by(|a, b| a.candidate.describe().cmp(&b.candidate.describe()));
    let arrivals = spec.workload.arrivals(opts.requests, &mut Rng::new(opts.seed));
    let replays = replay_all(&finalists, &arrivals, opts.threads);
    let g = spec.workload.mean_gap();

    let sim: Vec<f64> = replays.iter().map(|r| r.sim_energy_per_item.value()).collect();
    let est: Vec<f64> = replays
        .iter()
        .map(|r| r.estimate.energy_per_item.value())
        .collect();
    let before = rank_agreement(&est, &sim);

    let fitted = fit(spec, &replays);
    let est_cal: Vec<f64> = replays
        .iter()
        .map(|r| fitted.energy_per_item(&r.estimate, g).value())
        .collect();
    let fitted_after = rank_agreement(&est_cal, &sim);

    // never ship a fit that worsens the ranking: fall back to identity
    // (post-calibration agreement is then exactly the pre-calibration one)
    let (scales, after, fell_back) = if fitted_after.tau + 1e-12 >= before.tau {
        (fitted, fitted_after, false)
    } else {
        (ModelScales::identity(), before, true)
    };

    Calibration {
        spec: spec.clone(),
        scales,
        fell_back,
        replays,
        before,
        after,
        fitted: fitted_after,
        sweep_best: None,
    }
}

/// The full pipeline for one scenario: exhaustive sweep (pool-parallel,
/// optionally budgeted) → streaming Pareto front as the finalist set →
/// [`calibrate_finalists`].
pub fn calibrate(spec: &AppSpec, opts: &CalibrateOpts) -> Calibration {
    calibrate_and_refine(spec, opts).0
}

/// Outcome of a refinement sweep under corrected constants: the best
/// configuration by the spec's goal plus the Pareto front, both in the
/// *corrected* closed form's coordinates.
#[derive(Debug, Clone)]
pub struct Refinement {
    /// Best corrected estimate by the spec's goal (ties in a distributed
    /// merge are broken by global enumeration index, matching the
    /// first-in-enumeration-order winner of this single-process sweep).
    pub best: Option<Estimate>,
    /// Pareto front over the corrected estimates.
    pub front: ParetoFront,
    /// Fresh estimator evaluations the refinement paid (zero when the
    /// sweep pool's memo already covered the space).
    pub evaluations: usize,
    /// Evaluation requests including memo hits.
    pub requests: usize,
    pub budget_exhausted: bool,
}

/// [`calibrate`] plus the refinement sweep, sharing one [`EvalPool`]:
/// the refinement re-ranks the space through a [`CalibratedEstimator`]
/// wrapped around the *same* pool the calibration sweep populated, so
/// every candidate is a memo hit and the second pass costs zero
/// estimator evaluations (`refined.evaluations == 0` on an unbudgeted
/// run).  A budget set in `opts` governs the combined spend.
pub fn calibrate_and_refine(spec: &AppSpec, opts: &CalibrateOpts) -> (Calibration, Refinement) {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    let mut pool = EvalPool::new(opts.threads);
    if let Some(b) = opts.budget {
        pool = pool.with_budget(b);
    }
    let sweep = Exhaustive.search_with(spec, &space, &mut pool);
    let finalists = pool.take_front().into_members();
    let mut cal = calibrate_finalists(spec, finalists, opts);
    cal.sweep_best = sweep.best;
    let refined = refine_with(spec, &space, CalibratedEstimator::new(pool, cal.scales));
    (cal, refined)
}

/// [`calibrate_and_refine`], distributed: the sweep *and* the refinement
/// both run process-sharded across `dopts.workers` workers
/// ([`DistSweep::run_calibrated`]), with `opts` supplying the replay
/// trace (`seed`/`requests`) and the evaluation budget so the outcome is
/// bit-identical to the single-process `calibrate_and_refine(spec,
/// opts)` — same fitted scales, same agreement, same refined front/best
/// — at any worker count, crashes included.
pub fn calibrate_and_refine_dist(
    spec: &AppSpec,
    opts: &CalibrateOpts,
    dopts: &super::dist::DistOpts,
) -> anyhow::Result<super::dist::DistCalOutcome> {
    let merged = super::dist::DistOpts {
        budget: opts.budget,
        seed: opts.seed,
        requests: opts.requests,
        ..dopts.clone()
    };
    super::dist::DistSweep::new(merged).run_calibrated(spec)
}

/// Re-rank `space` through a calibrated evaluator in one full-space
/// batch.  Not `Exhaustive::search_with`: on a budget-cut pool the
/// sticky `budget_exhausted` flag would make its shard loop break after
/// the first shard, skipping memoized candidates that cost nothing to
/// re-rank.  A single `evaluate_batch` serves every memo hit for free
/// and only refuses candidates the budget never reached.
pub fn refine_with(
    spec: &AppSpec,
    space: &[super::design_space::Candidate],
    mut eval: CalibratedEstimator,
) -> Refinement {
    let start_evals = eval.evaluations();
    let start_requests = eval.requests();
    let mut best: Option<Estimate> = None;
    let mut front = ParetoFront::new();
    for e in eval.evaluate_batch(spec, space).into_iter().flatten() {
        if !e.feasible {
            continue;
        }
        front.insert(&e);
        let better = match &best {
            None => true,
            Some(b) => e.score(spec.goal) > b.score(spec.goal),
        };
        if better {
            best = Some(e);
        }
    }
    Refinement {
        best,
        front,
        evaluations: eval.evaluations() - start_evals,
        requests: eval.requests() - start_requests,
        budget_exhausted: eval.budget_exhausted(),
    }
}

/// An [`Evaluator`] that feeds corrected constants back into the sweep:
/// it reuses an inner [`EvalPool`] (memo, budget accounting, worker
/// threads — DES-fitted scales change joules, not which candidates are
/// worth estimating) and replaces each estimate's closed-form
/// energy-per-item with the calibration-corrected value.  Latency and
/// GOPS/s/W are untouched: calibration corrects the workload-energy
/// model only.
pub struct CalibratedEstimator {
    pool: EvalPool,
    scales: ModelScales,
}

impl CalibratedEstimator {
    pub fn new(pool: EvalPool, scales: ModelScales) -> CalibratedEstimator {
        CalibratedEstimator { pool, scales }
    }

    pub fn scales(&self) -> ModelScales {
        self.scales
    }

    /// Recover the inner pool (e.g. for its memo statistics).  Note the
    /// pool's streaming Pareto front holds *uncorrected* estimates.
    pub fn into_pool(self) -> EvalPool {
        self.pool
    }

    fn correct(&self, spec: &AppSpec, e: Estimate) -> Estimate {
        self.scales.correct_estimate(spec, e)
    }
}

impl Evaluator for CalibratedEstimator {
    fn evaluate(&mut self, spec: &AppSpec, c: &super::design_space::Candidate) -> Option<Estimate> {
        self.pool.evaluate(spec, c).map(|e| self.correct(spec, e))
    }

    fn evaluate_batch(
        &mut self,
        spec: &AppSpec,
        cands: &[super::design_space::Candidate],
    ) -> Vec<Option<Estimate>> {
        self.pool
            .evaluate_batch(spec, cands)
            .into_iter()
            .map(|o| o.map(|e| self.correct(spec, e)))
            .collect()
    }

    fn evaluations(&self) -> usize {
        self.pool.evaluations()
    }

    fn requests(&self) -> usize {
        self.pool.requests()
    }

    fn budget_exhausted(&self) -> bool {
        self.pool.budget_exhausted()
    }
}

/// Standalone refinement sweep under corrected constants, on a fresh
/// pool: re-rank the scenario's space through a [`CalibratedEstimator`].
/// Bit-identical across thread counts.  When you already ran the
/// calibration sweep, prefer [`calibrate_and_refine`], which reuses its
/// fully-memoized pool instead of re-estimating the space.
pub fn refine(spec: &AppSpec, scales: ModelScales, threads: usize) -> Refinement {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    let eval = CalibratedEstimator::new(EvalPool::new(threads), scales);
    refine_with(spec, &space, eval)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kendall_tau_extremes() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let same = rank_agreement(&a, &[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(same.tau, 1.0);
        assert_eq!(same.crossovers, 0);
        assert_eq!(same.pairs, 6);
        let rev = rank_agreement(&a, &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(rev.tau, -1.0);
        assert_eq!(rev.crossovers, 6);
        // ties count as neither
        let tied = rank_agreement(&[1.0, 1.0], &[1.0, 2.0]);
        assert_eq!(tied.tau, 0.0);
        assert_eq!(tied.crossovers, 0);
    }

    #[test]
    fn weighted_mean_of_scales() {
        let a = ModelScales { busy: 2.0, idle: 1.0, off: 1.0, cold: 4.0 };
        let b = ModelScales { busy: 4.0, idle: 3.0, off: 1.0, cold: 0.0 };
        let m = ModelScales::weighted_mean(&[(a, 1.0), (b, 3.0)]);
        assert_eq!(m.busy, 3.5);
        assert_eq!(m.idle, 2.5);
        assert_eq!(m.off, 1.0);
        assert_eq!(m.cold, 1.0);
        // zero / non-finite weights are skipped; empty input -> identity
        assert!(ModelScales::weighted_mean(&[]).is_identity());
        assert!(ModelScales::weighted_mean(&[(a, 0.0), (b, f64::NAN)]).is_identity());
    }

    #[test]
    fn identity_scales_reproduce_closed_form() {
        let spec = AppSpec::soft_sensor();
        let space = super::super::design_space::enumerate(&["xc7s6"]);
        let mut pool = EvalPool::new(1);
        let e = pool.evaluate(&spec, &space[0]).unwrap();
        let id = ModelScales::identity();
        assert!(id.is_identity());
        let again = id.energy_per_item(&e, spec.workload.mean_gap());
        assert_eq!(again.value(), e.energy_per_item.value());
    }

    #[test]
    fn fit_is_finite_and_fallback_guard_holds() {
        let spec = AppSpec::soft_sensor();
        let cal = calibrate(
            &spec,
            &CalibrateOpts { threads: 2, requests: 200, ..Default::default() },
        );
        assert!(!cal.replays.is_empty(), "sweep produced no finalists");
        for s in [cal.scales.busy, cal.scales.idle, cal.scales.off, cal.scales.cold] {
            assert!(s.is_finite() && s >= 0.0, "bad fitted scale {s}");
        }
        assert!(
            cal.after.tau + 1e-12 >= cal.before.tau,
            "guard violated: {} < {}",
            cal.after.tau,
            cal.before.tau
        );
        assert!(cal.sweep_best.is_some());
    }
}
