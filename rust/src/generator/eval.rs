//! Parallel, budget-aware candidate evaluation — the engine under every
//! searcher (§2.2 "Exploration and Estimation").
//!
//! [`EvalPool`] shards batch evaluation across `std::thread::scope`
//! workers, each with its own [`EstimatorCache`], and memoises finished
//! estimates by candidate key so no candidate is ever estimated twice
//! within a search run (the genetic searcher's duplicate children and
//! greedy's re-probed axes become free).  Results are merged in
//! submission order, so a sweep at N threads is bit-identical to the
//! single-threaded sweep — threads only change wall-clock.
//!
//! The pool also carries an optional evaluation budget (estimator calls,
//! memo hits are free) and a streaming [`ParetoFront`] over every
//! feasible estimate it produces.

use std::collections::{HashMap, HashSet};

use super::constraints::AppSpec;
use super::design_space::{Candidate, StrategyKind};
use super::estimator::{estimate_cached, Estimate, EstimatorCache};
use super::search::pareto::ParetoFront;
use crate::rtl::activation::ActVariant;
use crate::util::rng::fnv1a;

/// Common evaluation interface the searchers run against: a shared
/// cache/memo with explicit budget accounting.
pub trait Evaluator {
    /// Evaluate one candidate; `None` only once the budget is exhausted.
    fn evaluate(&mut self, spec: &AppSpec, c: &Candidate) -> Option<Estimate>;

    /// Evaluate a batch, preserving order; entries are `None` only for
    /// candidates the budget ran out before reaching.
    fn evaluate_batch(&mut self, spec: &AppSpec, cands: &[Candidate]) -> Vec<Option<Estimate>>;

    /// Estimator evaluations actually spent (memo hits are free).
    fn evaluations(&self) -> usize;

    /// Total evaluation requests, including memo hits.
    fn requests(&self) -> usize;

    fn budget_exhausted(&self) -> bool;
}

/// Memo key: one entry per distinct (application, design point).  The
/// genome axes all round-trip through these fields, so two genomes that
/// materialise the same candidate share one estimate.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct CandKey {
    spec: u64,
    device: &'static str,
    fmt: (u32, u32),
    sigmoid: ActVariant,
    tanh: ActVariant,
    alus: u32,
    pipelined: bool,
    clock_bits: u64,
    strategy: StrategyKind,
}

/// Fingerprint of every spec field the estimator reads, so a pool fed
/// two specs that differ in constraints (even under one name) never
/// shares estimates between them.  The goal is deliberately excluded:
/// it only affects `score()`, which callers compute, not the `Estimate`.
fn spec_key(spec: &AppSpec) -> u64 {
    let mut h = fnv1a(&spec.name);
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100_0000_01b3);
    };
    mix(spec.topology as u64);
    mix(spec.workload.mean_gap().value().to_bits());
    mix(spec.max_latency.map(|s| s.value().to_bits()).unwrap_or(1));
    mix(spec.max_act_error_lsb.map(|e| e.to_bits()).unwrap_or(2));
    for d in &spec.device_allowlist {
        mix(fnv1a(d));
    }
    h
}

fn cand_key(spec: &AppSpec, c: &Candidate) -> CandKey {
    CandKey {
        spec: spec_key(spec),
        device: c.device.name,
        fmt: (c.fmt.total_bits, c.fmt.frac_bits),
        sigmoid: c.sigmoid,
        tanh: c.tanh,
        alus: c.alus,
        pipelined: c.pipelined,
        clock_bits: c.clock_mhz.to_bits(),
        strategy: c.strategy,
    }
}

/// Deterministic parallel map: shards `items` across `threads` scoped
/// workers in contiguous chunks and merges results in submission order,
/// so the output is bit-identical across thread counts (the same
/// contract [`EvalPool::evaluate_batch`] gives the searchers).  Used by
/// the calibration loop to parallelise DES replays of a sweep's Pareto
/// finalists.
pub fn map_ordered<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let workers = threads.min(items.len());
    let chunk = items.len().div_ceil(workers);
    let mut results: Vec<Option<R>> = Vec::with_capacity(items.len());
    results.resize_with(items.len(), || None);
    let f = &f;
    std::thread::scope(|s| {
        for (slots, part) in results.chunks_mut(chunk).zip(items.chunks(chunk)) {
            s.spawn(move || {
                for (slot, item) in slots.iter_mut().zip(part) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    results
        .into_iter()
        // lint: allow(panic-reach) — the scope joins every worker before returning, so
        // each slot is filled; a panicking worker propagates at scope exit before this runs
        .map(|r| r.expect("worker filled its slot"))
        .collect()
}

/// Worker count for host-sized pools (the estimator is compute-bound and
/// memory-light; beyond ~8 workers the sweep is scheduling-dominated).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 8)
}

/// The parallel evaluation engine (see module docs).
pub struct EvalPool {
    threads: usize,
    budget: Option<usize>,
    evaluations: usize,
    requests: usize,
    budget_exhausted: bool,
    memo: HashMap<CandKey, Estimate>,
    seq_cache: EstimatorCache,
    front: ParetoFront,
}

impl EvalPool {
    pub fn new(threads: usize) -> EvalPool {
        EvalPool {
            threads: threads.max(1),
            budget: None,
            evaluations: 0,
            requests: 0,
            budget_exhausted: false,
            memo: HashMap::new(),
            seq_cache: EstimatorCache::new(),
            front: ParetoFront::new(),
        }
    }

    /// Pool sized to the host.
    pub fn with_host_threads() -> EvalPool {
        EvalPool::new(default_threads())
    }

    /// Cap the number of estimator evaluations this pool will spend.
    pub fn with_budget(mut self, budget: usize) -> EvalPool {
        self.budget = Some(budget);
        self
    }

    /// Raise the evaluation cap by `extra` and clear the exhaustion flag,
    /// so a budget-cut search can be resumed with a fresh installment
    /// (the successive-halving portfolio scheduler's reallocation
    /// primitive).  On an unbudgeted pool this *introduces* a cap of
    /// `evaluations() + extra`.
    pub fn grant(&mut self, extra: usize) {
        match self.budget.as_mut() {
            Some(b) => *b += extra,
            None => self.budget = Some(self.evaluations + extra),
        }
        self.budget_exhausted = false;
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Distinct candidates estimated so far (== `evaluations()`: the memo
    /// guarantees one paid estimate per unique candidate).
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// Streaming Pareto front over every feasible estimate produced.
    pub fn front(&self) -> &ParetoFront {
        &self.front
    }

    pub fn take_front(&mut self) -> ParetoFront {
        std::mem::take(&mut self.front)
    }

    fn remaining(&self) -> usize {
        match self.budget {
            Some(b) => b.saturating_sub(self.evaluations),
            None => usize::MAX,
        }
    }

    fn record(&mut self, key: CandKey, e: Estimate) {
        self.evaluations += 1;
        self.front.insert(&e);
        self.memo.insert(key, e);
    }
}

impl Evaluator for EvalPool {
    fn evaluate(&mut self, spec: &AppSpec, c: &Candidate) -> Option<Estimate> {
        self.requests += 1;
        let key = cand_key(spec, c);
        if let Some(e) = self.memo.get(&key) {
            return Some(e.clone());
        }
        if self.remaining() == 0 {
            self.budget_exhausted = true;
            return None;
        }
        let e = estimate_cached(spec, c, &mut self.seq_cache);
        self.record(key, e.clone());
        Some(e)
    }

    fn evaluate_batch(&mut self, spec: &AppSpec, cands: &[Candidate]) -> Vec<Option<Estimate>> {
        self.requests += cands.len();
        let keys: Vec<CandKey> = cands.iter().map(|c| cand_key(spec, c)).collect();

        // unique memo misses, in first-seen order, capped by the budget
        let mut jobs: Vec<usize> = Vec::new();
        let mut scheduled: HashSet<CandKey> = HashSet::new();
        let budget_left = self.remaining();
        for (i, k) in keys.iter().enumerate() {
            if self.memo.contains_key(k) || scheduled.contains(k) {
                continue;
            }
            if jobs.len() >= budget_left {
                self.budget_exhausted = true;
                break;
            }
            scheduled.insert(*k);
            jobs.push(i);
        }

        // Small batches (greedy's per-axis probes, single stragglers) stay
        // on the pool's persistent sequential cache: spawning workers with
        // cold template caches for a handful of candidates costs more than
        // the overlap buys (the estimator docs cite ~3x from template
        // reuse across candidates differing only in clock/strategy).
        const MIN_PARALLEL_BATCH: usize = 16;
        if self.threads == 1 || jobs.len() < MIN_PARALLEL_BATCH {
            for &i in &jobs {
                let e = estimate_cached(spec, &cands[i], &mut self.seq_cache);
                self.record(keys[i], e);
            }
        } else {
            let workers = self.threads.min(jobs.len());
            let chunk = jobs.len().div_ceil(workers);
            let mut results: Vec<Option<Estimate>> = vec![None; jobs.len()];
            std::thread::scope(|s| {
                for (slots, idxs) in results.chunks_mut(chunk).zip(jobs.chunks(chunk)) {
                    s.spawn(move || {
                        let mut cache = EstimatorCache::new();
                        for (slot, &i) in slots.iter_mut().zip(idxs) {
                            *slot = Some(estimate_cached(spec, &cands[i], &mut cache));
                        }
                    });
                }
            });
            // merge in submission order so the memo and the streaming
            // front are independent of thread scheduling
            for (&i, e) in jobs.iter().zip(results) {
                self.record(keys[i], e.expect("worker filled its slot"));
            }
        }

        keys.iter().map(|k| self.memo.get(k).cloned()).collect()
    }

    fn evaluations(&self) -> usize {
        self.evaluations
    }

    fn requests(&self) -> usize {
        self.requests
    }

    fn budget_exhausted(&self) -> bool {
        self.budget_exhausted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn memo_pays_once_per_unique_candidate() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&["xc7s6"]);
        let mut pool = EvalPool::new(1);
        let a = pool.evaluate(&spec, &space[0]).unwrap();
        let b = pool.evaluate(&spec, &space[0]).unwrap();
        assert_eq!(pool.evaluations(), 1);
        assert_eq!(pool.requests(), 2);
        assert_eq!(a.score(spec.goal), b.score(spec.goal));

        // in-batch duplicates are also deduplicated
        let batch = vec![space[1].clone(), space[2].clone(), space[1].clone()];
        let out = pool.evaluate_batch(&spec, &batch);
        assert_eq!(pool.evaluations(), 3);
        assert_eq!(pool.memo_len(), 3);
        assert!(out.iter().all(|e| e.is_some()));
        assert_eq!(
            out[0].as_ref().unwrap().candidate.describe(),
            out[2].as_ref().unwrap().candidate.describe()
        );
    }

    #[test]
    fn memo_distinguishes_specs_with_same_name() {
        // two specs sharing a name but differing in constraints must not
        // share memo entries — the key fingerprints the estimator inputs
        let spec = AppSpec::soft_sensor();
        let mut tight = AppSpec::soft_sensor();
        tight.max_latency = Some(crate::util::units::Secs(1e-6));
        let c = &enumerate(&["xc7s15"])[0];
        let mut pool = EvalPool::new(1);
        let _ = pool.evaluate(&spec, c).unwrap();
        let b = pool.evaluate(&tight, c).unwrap();
        assert_eq!(pool.evaluations(), 2, "specs shared a memo entry");
        // a 1us response bound is unsatisfiable for an on-off candidate
        assert!(!b.feasible);
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        let spec = AppSpec::ecg_monitor();
        let cands: Vec<Candidate> = enumerate(&["xc7s15"]).into_iter().take(200).collect();
        let seq = EvalPool::new(1).evaluate_batch(&spec, &cands);
        let par = EvalPool::new(4).evaluate_batch(&spec, &cands);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.feasible, b.feasible);
            assert_eq!(a.score(spec.goal), b.score(spec.goal));
            assert_eq!(a.energy_per_item.value(), b.energy_per_item.value());
        }
    }

    #[test]
    fn budget_caps_spending_and_flags_exhaustion() {
        let spec = AppSpec::soft_sensor();
        let cands: Vec<Candidate> = enumerate(&["xc7s6"]).into_iter().take(50).collect();
        let mut pool = EvalPool::new(2).with_budget(10);
        let out = pool.evaluate_batch(&spec, &cands);
        assert!(pool.budget_exhausted());
        assert_eq!(pool.evaluations(), 10);
        assert_eq!(out.iter().filter(|e| e.is_some()).count(), 10);
        // memo hits stay free after exhaustion, new candidates are refused
        assert!(pool.evaluate(&spec, &cands[0]).is_some());
        assert!(pool.evaluate(&spec, &cands[20]).is_none());
        assert_eq!(pool.evaluations(), 10);
    }

    #[test]
    fn grant_extends_an_exhausted_budget() {
        let spec = AppSpec::soft_sensor();
        let cands: Vec<Candidate> = enumerate(&["xc7s6"]).into_iter().take(30).collect();
        let mut pool = EvalPool::new(1).with_budget(10);
        pool.evaluate_batch(&spec, &cands);
        assert!(pool.budget_exhausted());
        assert_eq!(pool.evaluations(), 10);
        pool.grant(5);
        assert!(!pool.budget_exhausted());
        pool.evaluate_batch(&spec, &cands);
        assert_eq!(pool.evaluations(), 15);
        assert!(pool.budget_exhausted());
        // granting on an unbudgeted pool introduces a cap from "now"
        let mut free = EvalPool::new(1);
        free.evaluate(&spec, &cands[0]);
        free.grant(2);
        let out = free.evaluate_batch(&spec, &cands);
        assert_eq!(out.iter().filter(|e| e.is_some()).count(), 3);
        assert_eq!(free.evaluations(), 3);
    }

    #[test]
    fn front_tracks_feasible_estimates() {
        let spec = AppSpec::soft_sensor();
        let cands = enumerate(&["xc7s15"]);
        let mut pool = EvalPool::new(2);
        let out = pool.evaluate_batch(&spec, &cands);
        let feasible = out.iter().flatten().filter(|e| e.feasible).count();
        assert!(feasible > 0);
        assert!(!pool.front().is_empty());
        assert!(pool.front().len() <= feasible);
    }
}
