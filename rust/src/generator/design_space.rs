//! The Generator's design space (§2.2): the cross product of RTL template
//! parameters, datapath formats, devices, clocks and workload strategies.

use crate::fpga::device::{FpgaDevice, DEVICES};
use crate::rtl::activation::{ActImpl, ActKind, ActVariant};
use crate::rtl::composition::BuildOpts;
use crate::rtl::fixed_point::{QFormat, Q12_6, Q16_8, Q8_4};

/// Which workload-handling strategy a candidate deploys with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    OnOff,
    IdleWait,
    ClockScale,
    PredefinedThreshold,
    LearnableThreshold,
}

impl StrategyKind {
    pub fn all() -> &'static [StrategyKind] {
        &[
            StrategyKind::OnOff,
            StrategyKind::IdleWait,
            StrategyKind::ClockScale,
            StrategyKind::PredefinedThreshold,
            StrategyKind::LearnableThreshold,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::OnOff => "on-off",
            StrategyKind::IdleWait => "idle-wait",
            StrategyKind::ClockScale => "clock-scale",
            StrategyKind::PredefinedThreshold => "predefined-threshold",
            StrategyKind::LearnableThreshold => "learnable-threshold",
        }
    }

    /// Inverse of [`StrategyKind::name`] — the wire encoding used by the
    /// distributed DSE shard protocol (`generator::dist`).
    pub fn parse(name: &str) -> Option<StrategyKind> {
        StrategyKind::all().iter().copied().find(|k| k.name() == name)
    }

    /// Instantiate the runtime strategy this kind deploys with (one
    /// factory shared by every DES validation path: the calibration
    /// replays, E7's winner validation, `elastic-gen simulate`).
    pub fn instantiate(&self) -> Box<dyn crate::strategy::Strategy> {
        use crate::strategy::{ClockScale, IdleWait, OnOff, PredefinedThreshold};
        match self {
            StrategyKind::OnOff => Box::new(OnOff),
            StrategyKind::IdleWait => Box::new(IdleWait),
            StrategyKind::ClockScale => Box::new(ClockScale),
            StrategyKind::PredefinedThreshold => Box::new(PredefinedThreshold::breakeven()),
            StrategyKind::LearnableThreshold => {
                Box::new(crate::strategy::learnable::LearnableThreshold::default_grid())
            }
        }
    }
}

/// One point in the design space.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub device: &'static FpgaDevice,
    pub fmt: QFormat,
    pub sigmoid: ActVariant,
    pub tanh: ActVariant,
    pub alus: u32,
    pub pipelined: bool,
    pub clock_mhz: f64,
    pub strategy: StrategyKind,
}

impl Candidate {
    pub fn build_opts(&self) -> BuildOpts {
        BuildOpts {
            fmt: self.fmt,
            sigmoid: self.sigmoid,
            tanh: self.tanh,
            alus: self.alus,
            pipelined: self.pipelined,
        }
    }

    pub fn describe(&self) -> String {
        format!(
            "{}/{}/{:?}-{:?}/alus{}{}/{}MHz/{}",
            self.device.name,
            self.fmt.name(),
            self.sigmoid.imp,
            self.tanh.imp,
            self.alus,
            if self.pipelined { "/pipe" } else { "/seq" },
            self.clock_mhz,
            self.strategy.name()
        )
    }
}

/// Axis definitions (pruned to the values the template library supports).
pub fn sigmoid_variants() -> Vec<ActVariant> {
    vec![
        ActVariant::new(ActKind::Sigmoid, ActImpl::Exact),
        ActVariant::new(ActKind::Sigmoid, ActImpl::Pla),
        ActVariant::new(ActKind::Sigmoid, ActImpl::Lut),
        ActVariant::new(ActKind::HardSigmoid, ActImpl::Hard),
    ]
}

pub fn tanh_variants() -> Vec<ActVariant> {
    vec![
        ActVariant::new(ActKind::Tanh, ActImpl::Exact),
        ActVariant::new(ActKind::Tanh, ActImpl::Pla),
        ActVariant::new(ActKind::Tanh, ActImpl::Lut),
        ActVariant::new(ActKind::HardTanh, ActImpl::Hard),
    ]
}

pub const FORMATS: [QFormat; 3] = [Q16_8, Q12_6, Q8_4];
pub const ALUS: [u32; 4] = [1, 2, 4, 8];
pub const CLOCKS_MHZ: [f64; 4] = [25.0, 50.0, 100.0, 150.0];

/// Full enumeration filtered by a device allowlist.  Activation pairs are
/// tied (same implementation family for sigmoid and tanh) — mixing
/// families is allowed by the templates but adds nothing the evaluation
/// needs, and it keeps the space at a size the exhaustive search can
/// sweep in milliseconds.
pub fn enumerate(device_allowlist: &[&str]) -> Vec<Candidate> {
    let mut out = Vec::new();
    for device in DEVICES {
        if !device_allowlist.is_empty() && !device_allowlist.contains(&device.name) {
            continue;
        }
        for fmt in FORMATS {
            for (sig, tan) in sigmoid_variants().into_iter().zip(tanh_variants()) {
                // LUT variants need frac_bits >= 4
                if sig.imp == ActImpl::Lut && fmt.frac_bits < 4 {
                    continue;
                }
                for alus in ALUS {
                    for pipelined in [false, true] {
                        for clock_mhz in CLOCKS_MHZ {
                            for strategy in StrategyKind::all() {
                                out.push(Candidate {
                                    device,
                                    fmt,
                                    sigmoid: sig,
                                    tanh: tan,
                                    alus,
                                    pipelined,
                                    clock_mhz,
                                    strategy: *strategy,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Coordinate view of the design space for the heuristic searchers: each
/// candidate is a 7-vector of axis indices.
#[derive(Debug, Clone)]
pub struct Axes {
    pub devices: Vec<&'static FpgaDevice>,
    pub formats: Vec<QFormat>,
    pub act_pairs: Vec<(ActVariant, ActVariant)>,
    pub alus: Vec<u32>,
    pub pipelined: Vec<bool>,
    pub clocks_mhz: Vec<f64>,
    pub strategies: Vec<StrategyKind>,
}

/// Number of search axes in [`Axes`] / genome length.
pub const N_AXES: usize = 7;

impl Axes {
    pub fn new(device_allowlist: &[&str]) -> Axes {
        Axes {
            devices: DEVICES
                .iter()
                .filter(|d| device_allowlist.is_empty() || device_allowlist.contains(&d.name))
                .collect(),
            formats: FORMATS.to_vec(),
            act_pairs: sigmoid_variants().into_iter().zip(tanh_variants()).collect(),
            alus: ALUS.to_vec(),
            pipelined: vec![false, true],
            clocks_mhz: CLOCKS_MHZ.to_vec(),
            strategies: StrategyKind::all().to_vec(),
        }
    }

    /// Axis cardinalities, in genome order.
    pub fn dims(&self) -> [usize; N_AXES] {
        [
            self.devices.len(),
            self.formats.len(),
            self.act_pairs.len(),
            self.alus.len(),
            self.pipelined.len(),
            self.clocks_mhz.len(),
            self.strategies.len(),
        ]
    }

    /// Materialise a candidate from axis indices (indices are clamped).
    pub fn candidate(&self, idx: &[usize; N_AXES]) -> Candidate {
        let clamp = |i: usize, n: usize| i.min(n - 1);
        let (sig, tan) = self.act_pairs[clamp(idx[2], self.act_pairs.len())];
        Candidate {
            device: self.devices[clamp(idx[0], self.devices.len())],
            fmt: self.formats[clamp(idx[1], self.formats.len())],
            sigmoid: sig,
            tanh: tan,
            alus: self.alus[clamp(idx[3], self.alus.len())],
            pipelined: self.pipelined[clamp(idx[4], self.pipelined.len())],
            clock_mhz: self.clocks_mhz[clamp(idx[5], self.clocks_mhz.len())],
            strategy: self.strategies[clamp(idx[6], self.strategies.len())],
        }
    }

    /// Uniformly random genome.
    pub fn random(&self, rng: &mut crate::util::rng::Rng) -> [usize; N_AXES] {
        let dims = self.dims();
        let mut g = [0usize; N_AXES];
        for (gi, d) in g.iter_mut().zip(dims) {
            *gi = rng.below(d as u64) as usize;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_space_size() {
        let all = enumerate(&[]);
        // 5 devices x (3 fmts x 4 act pairs - LUT@q8_4 exclusions) x 4 alus
        // x 2 sched x 4 clocks x 5 strategies
        assert!(all.len() > 5_000, "{}", all.len());
        // every candidate is well-formed
        assert!(all.iter().all(|c| c.alus >= 1 && c.clock_mhz > 0.0));
    }

    #[test]
    fn allowlist_filters() {
        let only = enumerate(&["xc7s6"]);
        assert!(only.iter().all(|c| c.device.name == "xc7s6"));
        assert!(!only.is_empty());
    }

    #[test]
    fn strategy_names_roundtrip() {
        for k in StrategyKind::all() {
            assert_eq!(StrategyKind::parse(k.name()), Some(*k));
        }
        assert_eq!(StrategyKind::parse("warp-drive"), None);
    }

    #[test]
    fn describe_is_informative() {
        let c = &enumerate(&["xc7s15"])[0];
        let d = c.describe();
        assert!(d.contains("xc7s15"));
        assert!(d.contains("MHz"));
    }

    #[test]
    fn axes_candidate_roundtrip() {
        let axes = Axes::new(&[]);
        let dims = axes.dims();
        assert_eq!(dims[0], DEVICES.len());
        let c = axes.candidate(&[0, 0, 0, 0, 1, 2, 3]);
        assert!(c.pipelined);
        assert_eq!(c.clock_mhz, CLOCKS_MHZ[2]);
    }

    #[test]
    fn axes_clamp_out_of_range() {
        let axes = Axes::new(&["xc7s6"]);
        let c = axes.candidate(&[99, 99, 99, 99, 99, 99, 99]);
        assert_eq!(c.device.name, "xc7s6");
        assert_eq!(c.strategy, *StrategyKind::all().last().unwrap());
    }

    #[test]
    fn axes_random_in_bounds() {
        let axes = Axes::new(&[]);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let g = axes.random(&mut rng);
            for (gi, d) in g.iter().zip(axes.dims()) {
                assert!(*gi < d);
            }
        }
    }
}
