//! Greedy coordinate ascent: from a random feasible seed, repeatedly sweep
//! the axes, moving each coordinate to the best value with the others
//! held fixed, until a full pass yields no improvement.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, N_AXES};
use crate::generator::estimator::{estimate, Estimate};
use crate::util::rng::Rng;

pub struct Greedy {
    pub seed: u64,
    pub restarts: usize,
}

impl Default for Greedy {
    fn default() -> Greedy {
        Greedy { seed: 7, restarts: 8 }
    }
}

/// Graded score so the ascent can climb out of the infeasible region
/// instead of facing a -inf cliff on every axis.
fn soft_score(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

impl Searcher for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn search(&mut self, spec: &AppSpec, _space: &[Candidate]) -> SearchResult {
        let axes = Axes::new(&[]);
        let dims = axes.dims();
        let mut rng = Rng::new(self.seed);
        let mut evals = 0usize;
        let mut best: Option<(f64, Estimate)> = None;

        // warm starts: per device, at both a fast (100 MHz, threshold
        // strategy) and a slow (lowest clock, idle-wait) operating point —
        // the slow start is what lets the ascent keep low-fmax devices
        // (iCE40) instead of being ridge-trapped by the clock axis.
        // Remaining restarts are random.
        let mut warm: Vec<[usize; N_AXES]> = Vec::new();
        for dev in 0..dims[0] {
            warm.push([dev, 0, dims[2] - 1, dims[3] - 1, 1, 2, 3]);
            // slow start keeps ALUs modest so it is feasible on the
            // DSP-poorest devices (the ascent can still grow them)
            warm.push([dev, 0, dims[2] - 1, 1, 1, 0, 1]);
        }

        for restart in 0..(warm.len() + self.restarts) {
            let mut g = if restart < warm.len() {
                warm[restart]
            } else {
                axes.random(&mut rng)
            };
            let mut cur = estimate(spec, &axes.candidate(&g));
            evals += 1;
            let mut cur_score = soft_score(&cur, spec);

            loop {
                let mut improved = false;
                for axis in 0..N_AXES {
                    let mut best_v = g[axis];
                    let mut best_s = cur_score;
                    let mut best_e: Option<Estimate> = None;
                    for v in 0..dims[axis] {
                        if v == g[axis] {
                            continue;
                        }
                        let mut probe = g;
                        probe[axis] = v;
                        let e = estimate(spec, &axes.candidate(&probe));
                        evals += 1;
                        let s = soft_score(&e, spec);
                        if s > best_s {
                            best_s = s;
                            best_v = v;
                            best_e = Some(e);
                        }
                    }
                    if let Some(e) = best_e {
                        g[axis] = best_v;
                        cur_score = best_s;
                        cur = e;
                        improved = true;
                    }
                }
                if !improved {
                    break;
                }
            }

            if cur.feasible {
                let better = best
                    .as_ref()
                    .map(|(s, _)| cur_score > *s)
                    .unwrap_or(true);
                if better {
                    best = Some((cur_score, cur));
                }
            }
        }

        SearchResult {
            best: best.map(|(_, e)| e),
            evaluations: evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;
    use crate::generator::search::exhaustive::Exhaustive;

    #[test]
    fn greedy_reaches_near_optimum() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let got = Greedy::default().search(&spec, &space).best.unwrap();
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "greedy {}x worse than optimum", ratio);
    }

    #[test]
    fn greedy_uses_fewer_evals_than_exhaustive() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&[]);
        let r = Greedy::default().search(&spec, &space);
        assert!(r.evaluations < space.len() / 2, "{}", r.evaluations);
    }
}
