//! Greedy coordinate ascent: from a warm or random seed, repeatedly sweep
//! the axes, moving each coordinate to the best value with the others
//! held fixed, until a full pass yields no improvement.  Axis probes are
//! batched through the evaluator, so a parallel pool overlaps them and
//! the memo makes re-probed values free.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, StrategyKind, N_AXES};
use crate::generator::estimator::Estimate;
use crate::generator::eval::Evaluator;
use crate::util::rng::Rng;

pub struct Greedy {
    pub seed: u64,
    pub restarts: usize,
}

impl Default for Greedy {
    fn default() -> Greedy {
        Greedy { seed: 7, restarts: 8 }
    }
}

/// Graded score so the ascent can climb out of the infeasible region
/// instead of facing a -inf cliff on every axis.
fn soft_score(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

/// Warm-start genomes derived from the axis contents — never hard-coded
/// indices, and every coordinate is clamped against the actual axis
/// sizes, so a shrunken `Axes` (device allowlists, pruned clock sets)
/// cannot push a start out of bounds.  Per device: a *fast* operating
/// point (clock nearest 100 MHz, threshold strategy, max ALUs) and a
/// *slow* one (lowest clock, idle-wait, modest ALUs so the start stays
/// feasible on DSP-poor devices) — the slow start is what lets the
/// ascent keep low-fmax devices (iCE40) instead of being ridge-trapped
/// by the clock axis.
pub fn warm_starts(axes: &Axes) -> Vec<[usize; N_AXES]> {
    let dims = axes.dims();
    let clamp = |i: usize, axis: usize| i.min(dims[axis].saturating_sub(1));
    let fast_clock = axes
        .clocks_mhz
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| (*a - 100.0).abs().total_cmp(&(*b - 100.0).abs()))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let slow_clock = axes
        .clocks_mhz
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.total_cmp(b))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let strat = |k: StrategyKind| axes.strategies.iter().position(|s| *s == k).unwrap_or(0);
    let precise_fmt = 0;
    let hard_acts = clamp(axes.act_pairs.len().saturating_sub(1), 2);
    let max_alus = clamp(axes.alus.len().saturating_sub(1), 3);
    let modest_alus = clamp(1, 3);
    let pipelined = clamp(1, 4);

    let mut warm = Vec::with_capacity(2 * dims[0]);
    for dev in 0..dims[0] {
        warm.push([
            dev,
            precise_fmt,
            hard_acts,
            max_alus,
            pipelined,
            clamp(fast_clock, 5),
            clamp(strat(StrategyKind::PredefinedThreshold), 6),
        ]);
        warm.push([
            dev,
            precise_fmt,
            hard_acts,
            modest_alus,
            pipelined,
            clamp(slow_clock, 5),
            clamp(strat(StrategyKind::IdleWait), 6),
        ]);
    }
    warm
}

impl Searcher for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn search_with(
        &mut self,
        spec: &AppSpec,
        _space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult {
        let axes = Axes::new(&spec.device_allowlist);
        let dims = axes.dims();
        let start_evals = eval.evaluations();
        let mut rng = Rng::new(self.seed);
        let mut best: Option<(f64, Estimate)> = None;
        let warm = warm_starts(&axes);

        'restarts: for restart in 0..(warm.len() + self.restarts) {
            let mut g = if restart < warm.len() {
                warm[restart]
            } else {
                axes.random(&mut rng)
            };
            let Some(mut cur) = eval.evaluate(spec, &axes.candidate(&g)) else {
                break 'restarts;
            };
            let mut cur_score = soft_score(&cur, spec);

            loop {
                let mut improved = false;
                for axis in 0..N_AXES {
                    // batch-probe every alternative value on this axis
                    let probes: Vec<(usize, Candidate)> = (0..dims[axis])
                        .filter(|v| *v != g[axis])
                        .map(|v| {
                            let mut p = g;
                            p[axis] = v;
                            (v, axes.candidate(&p))
                        })
                        .collect();
                    let cands: Vec<Candidate> =
                        probes.iter().map(|(_, c)| c.clone()).collect();
                    let results = eval.evaluate_batch(spec, &cands);

                    let mut best_v = g[axis];
                    let mut best_s = cur_score;
                    let mut best_e: Option<Estimate> = None;
                    for ((v, _), e) in probes.iter().zip(&results) {
                        let Some(e) = e else { continue };
                        let s = soft_score(e, spec);
                        if s > best_s {
                            best_s = s;
                            best_v = *v;
                            best_e = Some(e.clone());
                        }
                    }
                    if let Some(e) = best_e {
                        g[axis] = best_v;
                        cur_score = best_s;
                        cur = e;
                        improved = true;
                    }
                    if eval.budget_exhausted() {
                        break;
                    }
                }
                if !improved || eval.budget_exhausted() {
                    break;
                }
            }

            if cur.feasible {
                let better = best
                    .as_ref()
                    .map(|(s, _)| cur_score > *s)
                    .unwrap_or(true);
                if better {
                    best = Some((cur_score, cur));
                }
            }
            if eval.budget_exhausted() {
                break 'restarts;
            }
        }

        SearchResult {
            best: best.map(|(_, e)| e),
            evaluations: eval.evaluations() - start_evals,
            budget_exhausted: eval.budget_exhausted(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::DEVICES;
    use crate::generator::design_space::{enumerate, sigmoid_variants, tanh_variants};
    use crate::generator::search::exhaustive::Exhaustive;
    use crate::rtl::fixed_point::Q16_8;

    #[test]
    fn greedy_reaches_near_optimum() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let got = Greedy::default().search(&spec, &space).best.unwrap();
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "greedy {}x worse than optimum", ratio);
    }

    #[test]
    fn greedy_uses_fewer_evals_than_exhaustive() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&[]);
        let r = Greedy::default().search(&spec, &space);
        assert!(r.evaluations < space.len() / 2, "{}", r.evaluations);
    }

    #[test]
    fn warm_starts_stay_in_bounds_when_axes_shrink() {
        // a pruned axis view (single device/format/ALU/clock, no
        // threshold strategies) must still produce valid warm starts —
        // the old hard-coded index vectors went out of bounds here
        let axes = Axes {
            devices: DEVICES.iter().take(1).collect(),
            formats: vec![Q16_8],
            act_pairs: sigmoid_variants()
                .into_iter()
                .zip(tanh_variants())
                .take(2)
                .collect(),
            alus: vec![1],
            pipelined: vec![false],
            clocks_mhz: vec![25.0],
            strategies: vec![StrategyKind::OnOff, StrategyKind::IdleWait],
        };
        let dims = axes.dims();
        let warm = warm_starts(&axes);
        assert_eq!(warm.len(), 2);
        for g in warm {
            for (gi, d) in g.iter().zip(dims) {
                assert!(*gi < d, "warm start {g:?} out of bounds for dims {dims:?}");
            }
        }
    }

    #[test]
    fn warm_starts_derive_operating_points_from_axes() {
        let axes = Axes::new(&[]);
        let warm = warm_starts(&axes);
        assert_eq!(warm.len(), 2 * axes.devices.len());
        let fast = &warm[0];
        let slow = &warm[1];
        // fast: clock nearest 100 MHz, threshold strategy, max ALUs
        assert_eq!(axes.clocks_mhz[fast[5]], 100.0);
        assert_eq!(
            axes.strategies[fast[6]],
            StrategyKind::PredefinedThreshold
        );
        assert_eq!(axes.alus[fast[3]], *axes.alus.iter().max().unwrap());
        // slow: lowest clock, idle-wait
        assert_eq!(axes.clocks_mhz[slow[5]], 25.0);
        assert_eq!(axes.strategies[slow[6]], StrategyKind::IdleWait);
    }
}
