//! Exhaustive sweep: evaluate every candidate, keep the best feasible one.
//! On the pruned space (~10^4 points) this completes in well under a
//! second and serves as the optimality reference for the heuristics.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::Candidate;
use crate::generator::estimator::{estimate_cached, Estimate, EstimatorCache};

#[derive(Debug, Default)]
pub struct Exhaustive;

impl Searcher for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn search(&mut self, spec: &AppSpec, space: &[Candidate]) -> SearchResult {
        let mut best: Option<Estimate> = None;
        let mut cache = EstimatorCache::new();
        for c in space {
            let e = estimate_cached(spec, c, &mut cache);
            if !e.feasible {
                continue;
            }
            let better = match &best {
                None => true,
                Some(b) => e.score(spec.goal) > b.score(spec.goal),
            };
            if better {
                best = Some(e);
            }
        }
        SearchResult {
            best,
            evaluations: space.len(),
        }
    }
}

/// Full ranking (used by the Pareto analysis and reports).
pub fn rank(spec: &AppSpec, space: &[Candidate]) -> Vec<Estimate> {
    let mut cache = EstimatorCache::new();
    let mut es: Vec<Estimate> = space
        .iter()
        .map(|c| estimate_cached(spec, c, &mut cache))
        .filter(|e| e.feasible)
        .collect();
    es.sort_by(|a, b| {
        b.score(spec.goal)
            .partial_cmp(&a.score(spec.goal))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    es
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn finds_a_feasible_best_per_scenario() {
        let space = enumerate(&[]);
        for spec in AppSpec::scenarios() {
            let r = Exhaustive.search(&spec, &space);
            let best = r.best.expect(&spec.name);
            assert!(best.feasible);
            assert_eq!(r.evaluations, space.len());
        }
    }

    #[test]
    fn rank_is_sorted_and_feasible() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&["xc7s6", "xc7s15"]);
        let ranked = rank(&spec, &space);
        assert!(!ranked.is_empty());
        assert!(ranked.windows(2).all(|w| {
            w[0].score(spec.goal) >= w[1].score(spec.goal)
        }));
    }

    #[test]
    fn best_matches_rank_head() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&["xc7s15"]);
        let best = Exhaustive.search(&spec, &space).best.unwrap();
        let head = &rank(&spec, &space)[0];
        assert_eq!(best.score(spec.goal), head.score(spec.goal));
    }
}
