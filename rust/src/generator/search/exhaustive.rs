//! Exhaustive sweep: evaluate every candidate, keep the best feasible one.
//! On the pruned space (~10^4 points) this completes in well under a
//! second and serves as the optimality reference for the heuristics.
//! Batches are pushed through the [`Evaluator`] in shards, so a parallel
//! pool overlaps the estimates and a budget cut still reports the best
//! candidate seen so far.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::Candidate;
use crate::generator::estimator::Estimate;
use crate::generator::eval::{EvalPool, Evaluator};

/// Shard size per `evaluate_batch` call: large enough to amortise worker
/// spawn, small enough that budget cuts land promptly.
const SHARD: usize = 512;

#[derive(Debug, Default)]
pub struct Exhaustive;

impl Searcher for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn search_with(
        &mut self,
        spec: &AppSpec,
        space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult {
        let start = eval.evaluations();
        let mut best: Option<Estimate> = None;
        for shard in space.chunks(SHARD) {
            for e in eval.evaluate_batch(spec, shard).into_iter().flatten() {
                if !e.feasible {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(b) => e.score(spec.goal) > b.score(spec.goal),
                };
                if better {
                    best = Some(e);
                }
            }
            if eval.budget_exhausted() {
                break;
            }
        }
        SearchResult {
            best,
            evaluations: eval.evaluations() - start,
            budget_exhausted: eval.budget_exhausted(),
        }
    }
}

/// Full ranking (used by the Pareto analysis and reports).
pub fn rank(spec: &AppSpec, space: &[Candidate]) -> Vec<Estimate> {
    rank_with(spec, space, &mut EvalPool::new(1))
}

/// Pool-backed full ranking: parallel when the pool is, and truncated at
/// the pool's budget.
pub fn rank_with(spec: &AppSpec, space: &[Candidate], eval: &mut dyn Evaluator) -> Vec<Estimate> {
    let mut es: Vec<Estimate> = eval
        .evaluate_batch(spec, space)
        .into_iter()
        .flatten()
        .filter(|e| e.feasible)
        .collect();
    es.sort_by(|a, b| b.score(spec.goal).total_cmp(&a.score(spec.goal)));
    es
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn finds_a_feasible_best_per_scenario() {
        let space = enumerate(&[]);
        for spec in AppSpec::scenarios() {
            let r = Exhaustive.search(&spec, &space);
            let best = r.best.expect(&spec.name);
            assert!(best.feasible);
            assert!(!r.budget_exhausted);
            assert_eq!(r.evaluations, space.len());
        }
    }

    #[test]
    fn rank_is_sorted_and_feasible() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&["xc7s6", "xc7s15"]);
        let ranked = rank(&spec, &space);
        assert!(!ranked.is_empty());
        assert!(ranked
            .windows(2)
            .all(|w| { w[0].score(spec.goal) >= w[1].score(spec.goal) }));
    }

    #[test]
    fn best_matches_rank_head() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&["xc7s15"]);
        let best = Exhaustive.search(&spec, &space).best.unwrap();
        let head = &rank(&spec, &space)[0];
        assert_eq!(best.score(spec.goal), head.score(spec.goal));
    }

    #[test]
    fn budgeted_sweep_stops_early_with_partial_best() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&["xc7s6"]);
        let mut pool = EvalPool::new(2).with_budget(40);
        let r = Exhaustive.search_with(&spec, &space, &mut pool);
        assert!(r.budget_exhausted);
        assert_eq!(r.evaluations, 40);
    }
}
