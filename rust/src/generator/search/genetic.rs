//! Genetic search: tournament selection, uniform crossover, per-axis
//! mutation, elitism.  Genomes are the 7-axis index vectors of
//! `design_space::Axes`.  Each generation's offspring cohort is bred
//! first and then evaluated as one batch, so a parallel pool overlaps
//! the estimates and the memo never re-pays for duplicate children.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, N_AXES};
use crate::generator::estimator::Estimate;
use crate::generator::eval::Evaluator;
use crate::util::rng::Rng;

pub struct Genetic {
    pub seed: u64,
    pub population: usize,
    pub generations: usize,
    pub mutation_rate: f64,
    pub elite: usize,
}

impl Default for Genetic {
    fn default() -> Genetic {
        Genetic {
            seed: 13,
            population: 40,
            generations: 18,
            mutation_rate: 0.15,
            elite: 4,
        }
    }
}

type Genome = [usize; N_AXES];

fn fitness(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

impl Searcher for Genetic {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn search_with(
        &mut self,
        spec: &AppSpec,
        _space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult {
        let axes = Axes::new(&spec.device_allowlist);
        let dims = axes.dims();
        let start_evals = eval.evaluations();
        let mut rng = Rng::new(self.seed);

        // initial population: genomes first, then one batched evaluation
        let genomes: Vec<Genome> = (0..self.population).map(|_| axes.random(&mut rng)).collect();
        let cands: Vec<Candidate> = genomes.iter().map(|g| axes.candidate(g)).collect();
        let results = eval.evaluate_batch(spec, &cands);
        let mut pop: Vec<(Genome, Estimate, f64)> = genomes
            .into_iter()
            .zip(results)
            .filter_map(|(g, e)| {
                e.map(|e| {
                    let f = fitness(&e, spec);
                    (g, e, f)
                })
            })
            .collect();

        if pop.is_empty() {
            return SearchResult {
                best: None,
                evaluations: eval.evaluations() - start_evals,
                budget_exhausted: eval.budget_exhausted(),
            };
        }

        for _ in 0..self.generations {
            if eval.budget_exhausted() {
                break;
            }
            pop.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
            let elite = self.elite.min(pop.len());

            // breed the whole offspring cohort, then evaluate it as a batch
            let mut children: Vec<Genome> = Vec::with_capacity(self.population - elite);
            while children.len() + elite < self.population {
                // tournament of 3 for each parent
                let pick = |rng: &mut Rng| -> usize {
                    (0..3)
                        .map(|_| rng.below(pop.len() as u64) as usize)
                        .min_by(|&a, &b| {
                            pop[b].2.partial_cmp(&pop[a].2).unwrap_or(std::cmp::Ordering::Equal)
                        })
                        .unwrap()
                };
                let (pa, pb) = (pick(&mut rng), pick(&mut rng));
                let mut child: Genome = [0; N_AXES];
                for i in 0..N_AXES {
                    child[i] = if rng.chance(0.5) { pop[pa].0[i] } else { pop[pb].0[i] };
                    if rng.chance(self.mutation_rate) {
                        child[i] = rng.below(dims[i] as u64) as usize;
                    }
                }
                children.push(child);
            }

            let cands: Vec<Candidate> = children.iter().map(|g| axes.candidate(g)).collect();
            let results = eval.evaluate_batch(spec, &cands);
            let mut next: Vec<(Genome, Estimate, f64)> = pop[..elite].to_vec();
            for (g, e) in children.into_iter().zip(results) {
                if let Some(e) = e {
                    let f = fitness(&e, spec);
                    next.push((g, e, f));
                }
            }
            pop = next;
        }

        pop.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
        let best = pop.into_iter().map(|(_, e, _)| e).find(|e| e.feasible);
        SearchResult {
            best,
            evaluations: eval.evaluations() - start_evals,
            budget_exhausted: eval.budget_exhausted(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;
    use crate::generator::search::exhaustive::Exhaustive;

    #[test]
    fn genetic_near_optimum_with_budget() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let r = Genetic::default().search(&spec, &space);
        let got = r.best.unwrap();
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "genetic {ratio}x worse");
        assert!(r.evaluations < space.len(), "no budget saving");
    }

    #[test]
    fn elitism_preserves_best() {
        // the final best must never be worse than a pure random sample of
        // the same budget (sanity against regressions in selection)
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let g = Genetic { generations: 6, ..Default::default() }
            .search(&spec, &space)
            .best
            .unwrap();
        assert!(g.feasible);
    }
}
