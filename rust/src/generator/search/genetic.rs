//! Genetic search: tournament selection, uniform crossover, per-axis
//! mutation, elitism.  Genomes are the 7-axis index vectors of
//! `design_space::Axes`.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, N_AXES};
use crate::generator::estimator::{estimate, Estimate};
use crate::util::rng::Rng;

pub struct Genetic {
    pub seed: u64,
    pub population: usize,
    pub generations: usize,
    pub mutation_rate: f64,
    pub elite: usize,
}

impl Default for Genetic {
    fn default() -> Genetic {
        Genetic {
            seed: 13,
            population: 40,
            generations: 18,
            mutation_rate: 0.15,
            elite: 4,
        }
    }
}

type Genome = [usize; N_AXES];

fn fitness(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

impl Searcher for Genetic {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn search(&mut self, spec: &AppSpec, _space: &[Candidate]) -> SearchResult {
        let axes = Axes::new(&[]);
        let dims = axes.dims();
        let mut rng = Rng::new(self.seed);
        let mut evals = 0usize;

        let eval = |g: &Genome, evals: &mut usize| -> (Estimate, f64) {
            let e = estimate(spec, &axes.candidate(g));
            *evals += 1;
            let f = fitness(&e, spec);
            (e, f)
        };

        let mut pop: Vec<(Genome, Estimate, f64)> = (0..self.population)
            .map(|_| {
                let g = axes.random(&mut rng);
                let (e, f) = eval(&g, &mut evals);
                (g, e, f)
            })
            .collect();

        for _ in 0..self.generations {
            pop.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
            let mut next: Vec<(Genome, Estimate, f64)> = pop[..self.elite.min(pop.len())].to_vec();

            while next.len() < self.population {
                // tournament of 3 for each parent
                let pick = |rng: &mut Rng| -> usize {
                    (0..3)
                        .map(|_| rng.below(pop.len() as u64) as usize)
                        .min_by(|&a, &b| {
                            pop[b].2.partial_cmp(&pop[a].2).unwrap_or(std::cmp::Ordering::Equal)
                        })
                        .unwrap()
                };
                let (pa, pb) = (pick(&mut rng), pick(&mut rng));
                let mut child: Genome = [0; N_AXES];
                for i in 0..N_AXES {
                    child[i] = if rng.chance(0.5) { pop[pa].0[i] } else { pop[pb].0[i] };
                    if rng.chance(self.mutation_rate) {
                        child[i] = rng.below(dims[i] as u64) as usize;
                    }
                }
                let (e, f) = eval(&child, &mut evals);
                next.push((child, e, f));
            }
            pop = next;
        }

        pop.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal));
        let best = pop.into_iter().map(|(_, e, _)| e).find(|e| e.feasible);
        SearchResult { best, evaluations: evals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;
    use crate::generator::search::exhaustive::Exhaustive;

    #[test]
    fn genetic_near_optimum_with_budget() {
        let spec = AppSpec::ecg_monitor();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let r = Genetic::default().search(&spec, &space);
        let got = r.best.unwrap();
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "genetic {ratio}x worse");
        assert!(r.evaluations < space.len(), "no budget saving");
    }

    #[test]
    fn elitism_preserves_best() {
        // the final best must never be worse than a pure random sample of
        // the same budget (sanity against regressions in selection)
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let g = Genetic { generations: 6, ..Default::default() }
            .search(&spec, &space)
            .best
            .unwrap();
        assert!(g.feasible);
    }
}
