//! Search algorithms over the design space (§4 "implement search
//! algorithms ... to explore combinations of inputs").
//!
//! Four searchers with one interface, plus the Pareto front:
//!
//! * [`exhaustive`] — the ground truth on this space (~10^4 points).
//! * [`greedy`] — coordinate ascent from a feasible seed.
//! * [`annealing`] — simulated annealing with per-axis neighbour moves.
//! * [`genetic`] — a small GA (tournament selection, uniform crossover).
//!
//! All four route their estimates through an [`Evaluator`] — normally an
//! [`EvalPool`], which memoises per candidate, shards batches across
//! threads, and enforces the evaluation budget.  [`generate_portfolio`]
//! runs the heuristics concurrently and merges best-of plus a streaming
//! Pareto front.  The ablation bench (E7) reports how close each
//! heuristic gets to the exhaustive optimum at what fraction of the
//! evaluation budget.

pub mod annealing;
pub mod exhaustive;
pub mod genetic;
pub mod greedy;
pub mod pareto;

use super::constraints::AppSpec;
use super::design_space::Candidate;
use super::estimator::Estimate;
use super::eval::{EvalPool, Evaluator};
use pareto::ParetoFront;

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: Option<Estimate>,
    /// Number of estimator evaluations spent (memoised hits are free).
    pub evaluations: usize,
    /// True when the run stopped early because the evaluation budget ran
    /// out (the best seen so far is still reported).
    pub budget_exhausted: bool,
}

/// Common interface so benches can sweep searchers uniformly.
pub trait Searcher {
    fn name(&self) -> &'static str;

    /// Run against an explicit evaluation engine (shared cache/memo,
    /// optional budget, optional worker pool).
    fn search_with(
        &mut self,
        spec: &AppSpec,
        space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult;

    /// Convenience: fresh single-threaded, unbudgeted engine.  A pool
    /// with more workers returns bit-identical results, only faster.
    fn search(&mut self, spec: &AppSpec, space: &[Candidate]) -> SearchResult {
        self.search_with(spec, space, &mut EvalPool::new(1))
    }
}

/// Convenience: the generator's default pipeline — a host-parallel
/// exhaustive sweep over the (already small) pruned space, restricted to
/// the spec's device allowlist like every other entry point.
pub fn generate(spec: &AppSpec) -> SearchResult {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    exhaustive::Exhaustive.search_with(spec, &space, &mut EvalPool::with_host_threads())
}

/// Outcome of [`generate_portfolio`]: the heuristic searchers run
/// concurrently, merged.
pub struct Portfolio {
    /// Best estimate across all searchers (by the spec's goal score).
    pub best: Option<Estimate>,
    /// Per-searcher results, in a fixed deterministic order.
    pub runs: Vec<(&'static str, SearchResult)>,
    /// Merged streaming Pareto front over every feasible candidate any
    /// searcher evaluated.
    pub front: ParetoFront,
    /// Total estimator evaluations across the portfolio.
    pub evaluations: usize,
}

/// Run the heuristic searchers (greedy, annealing, genetic) concurrently,
/// one thread and one [`EvalPool`] each, and merge best-of plus the
/// streaming Pareto front.  `threads` is the overall worker target
/// (divided between the searchers' pools); `budget` caps estimator
/// evaluations per searcher.
pub fn generate_portfolio(spec: &AppSpec, threads: usize, budget: Option<usize>) -> Portfolio {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    let mut searchers: Vec<Box<dyn Searcher + Send>> = vec![
        Box::new(greedy::Greedy::default()),
        Box::new(annealing::Annealing::default()),
        Box::new(genetic::Genetic::default()),
    ];
    let per_pool = (threads.max(1) / searchers.len()).max(1);

    let results: Vec<(&'static str, SearchResult, ParetoFront)> = std::thread::scope(|s| {
        let space = &space;
        let handles: Vec<_> = searchers
            .iter_mut()
            .map(|searcher| {
                s.spawn(move || {
                    let mut pool = match budget {
                        Some(b) => EvalPool::new(per_pool).with_budget(b),
                        None => EvalPool::new(per_pool),
                    };
                    let r = searcher.search_with(spec, space, &mut pool);
                    (searcher.name(), r, pool.take_front())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("searcher thread panicked"))
            .collect()
    });

    let mut front = ParetoFront::new();
    let mut best: Option<Estimate> = None;
    let mut evaluations = 0usize;
    let mut runs = Vec::new();
    for (name, r, f) in results {
        front.merge(&f);
        evaluations += r.evaluations;
        if let Some(e) = &r.best {
            let better = match &best {
                None => true,
                Some(b) => e.score(spec.goal) > b.score(spec.goal),
            };
            if better {
                best = Some(e.clone());
            }
        }
        runs.push((name, r));
    }
    Portfolio {
        best,
        runs,
        front,
        evaluations,
    }
}
