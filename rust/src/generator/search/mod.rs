//! Search algorithms over the design space (§4 "implement search
//! algorithms ... to explore combinations of inputs").
//!
//! Four searchers with one interface, plus the Pareto front:
//!
//! * [`exhaustive`] — the ground truth on this space (~10^4 points).
//! * [`greedy`] — coordinate ascent from a feasible seed.
//! * [`annealing`] — simulated annealing with per-axis neighbour moves.
//! * [`genetic`] — a small GA (tournament selection, uniform crossover).
//!
//! All four route their estimates through an [`Evaluator`] — normally an
//! [`EvalPool`], which memoises per candidate, shards batches across
//! threads, and enforces the evaluation budget.  [`generate_portfolio`]
//! runs the heuristics concurrently and merges best-of plus a streaming
//! Pareto front; under a budget it becomes a successive-halving
//! scheduler ([`portfolio_bandit`]) that keeps moving the remaining
//! budget to whichever searcher is still improving.  The ablation bench
//! (E7) reports how close each heuristic gets to the exhaustive optimum
//! at what fraction of the evaluation budget.

pub mod annealing;
pub mod exhaustive;
pub mod genetic;
pub mod greedy;
pub mod pareto;

use super::constraints::AppSpec;
use super::design_space::Candidate;
use super::estimator::Estimate;
use super::eval::{EvalPool, Evaluator};
use pareto::ParetoFront;

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: Option<Estimate>,
    /// Number of estimator evaluations spent (memoised hits are free).
    pub evaluations: usize,
    /// True when the run stopped early because the evaluation budget ran
    /// out (the best seen so far is still reported).
    pub budget_exhausted: bool,
}

/// Common interface so benches can sweep searchers uniformly.
pub trait Searcher {
    fn name(&self) -> &'static str;

    /// Run against an explicit evaluation engine (shared cache/memo,
    /// optional budget, optional worker pool).
    fn search_with(
        &mut self,
        spec: &AppSpec,
        space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult;

    /// Convenience: fresh single-threaded, unbudgeted engine.  A pool
    /// with more workers returns bit-identical results, only faster.
    fn search(&mut self, spec: &AppSpec, space: &[Candidate]) -> SearchResult {
        self.search_with(spec, space, &mut EvalPool::new(1))
    }
}

/// Convenience: the generator's default pipeline — a host-parallel
/// exhaustive sweep over the (already small) pruned space, restricted to
/// the spec's device allowlist like every other entry point.
pub fn generate(spec: &AppSpec) -> SearchResult {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    exhaustive::Exhaustive.search_with(spec, &space, &mut EvalPool::with_host_threads())
}

/// Outcome of [`generate_portfolio`]: the heuristic searchers run
/// concurrently, merged.
pub struct Portfolio {
    /// Best estimate across all searchers (by the spec's goal score).
    pub best: Option<Estimate>,
    /// Per-searcher results, in a fixed deterministic order.  Under a
    /// budget, `evaluations` is each searcher's *cumulative* spend
    /// across every scheduler round.
    pub runs: Vec<(&'static str, SearchResult)>,
    /// Merged streaming Pareto front over every feasible candidate any
    /// searcher evaluated.
    pub front: ParetoFront,
    /// Total estimator evaluations across the portfolio.
    pub evaluations: usize,
    /// Searchers the budget scheduler retired for spending a full
    /// installment without improving (empty on unbudgeted runs).
    pub stalled: Vec<&'static str>,
}

/// A searcher constructor the portfolio scheduler can re-invoke each
/// round.  The searchers are deterministic, so a fresh instance run
/// against its previous (warm) pool replays its prior trajectory through
/// the memo for free and *resumes* where the budget cut it.
pub type SearcherFactory = fn() -> Box<dyn Searcher + Send>;

fn make_greedy() -> Box<dyn Searcher + Send> {
    Box::new(greedy::Greedy::default())
}

fn make_annealing() -> Box<dyn Searcher + Send> {
    Box::new(annealing::Annealing::default())
}

fn make_genetic() -> Box<dyn Searcher + Send> {
    Box::new(genetic::Genetic::default())
}

fn default_factories() -> Vec<SearcherFactory> {
    vec![make_greedy, make_annealing, make_genetic]
}

/// Successive-halving rounds for the budgeted portfolio scheduler.
pub const PORTFOLIO_ROUNDS: usize = 4;

/// Run the heuristic searchers (greedy, annealing, genetic) concurrently,
/// one thread and one [`EvalPool`] each, and merge best-of plus the
/// streaming Pareto front.  `threads` is the overall worker target
/// (divided between the searchers' pools).  `budget` is the *total*
/// evaluation budget for the portfolio: instead of a fixed per-searcher
/// split it is scheduled by [`portfolio_bandit`], which keeps
/// reallocating the remainder to whichever searcher is still improving.
pub fn generate_portfolio(spec: &AppSpec, threads: usize, budget: Option<usize>) -> Portfolio {
    let factories = default_factories();
    match budget {
        Some(total) => portfolio_bandit(spec, threads, total, PORTFOLIO_ROUNDS, &factories),
        None => portfolio_unbudgeted(spec, threads, &factories),
    }
}

/// Unbudgeted portfolio: every searcher runs to natural convergence,
/// concurrently, and the results merge.
fn portfolio_unbudgeted(
    spec: &AppSpec,
    threads: usize,
    factories: &[SearcherFactory],
) -> Portfolio {
    let space = super::design_space::enumerate(&spec.device_allowlist);
    let per_pool = (threads.max(1) / factories.len().max(1)).max(1);

    let results: Vec<(&'static str, SearchResult, ParetoFront)> = std::thread::scope(|s| {
        let space = &space;
        let handles: Vec<_> = factories
            .iter()
            .map(|make| {
                s.spawn(move || {
                    let mut searcher = make();
                    let mut pool = EvalPool::new(per_pool);
                    let r = searcher.search_with(spec, space, &mut pool);
                    (searcher.name(), r, pool.take_front())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("searcher thread panicked"))
            .collect()
    });
    merge_portfolio(spec, results, Vec::new())
}

/// Successive-halving portfolio scheduler (the ROADMAP's bandit item):
/// the total evaluation budget is granted in rounds, split across the
/// still-active searchers.  A searcher that spends a full installment
/// without improving its best score is **stalled** — it is retired and
/// the budget it would have drawn in later rounds flows to the searchers
/// still improving.  A searcher that converges naturally (stops before
/// exhausting its grant) refunds the unspent remainder to the pot.  Each
/// round re-instantiates the (deterministic) searcher against its own
/// warm pool: the replayed prefix of its trajectory is answered by the
/// memo for free, so a raised budget resumes the search where the last
/// cut left it instead of starting over.
pub fn portfolio_bandit(
    spec: &AppSpec,
    threads: usize,
    total_budget: usize,
    rounds: usize,
    factories: &[SearcherFactory],
) -> Portfolio {
    struct Arm {
        make: SearcherFactory,
        name: &'static str,
        pool: EvalPool,
        granted: usize,
        // best across every round: a re-run with a larger budget follows
        // a different (deterministic) trajectory and may legitimately
        // end somewhere worse, but the portfolio must never forget a
        // winner an earlier round already found
        best_score: Option<f64>,
        best_estimate: Option<Estimate>,
        last: Option<SearchResult>,
        active: bool,
        /// Granted something this round — only funded arms run and are
        /// assessed (an arm the drained pot skipped must not be re-run
        /// against its exhausted pool or counted as stalled).
        funded: bool,
    }

    let space = super::design_space::enumerate(&spec.device_allowlist);
    let per_pool = (threads.max(1) / factories.len().max(1)).max(1);
    let mut arms: Vec<Arm> = factories
        .iter()
        .map(|make| Arm {
            make: *make,
            name: make().name(),
            pool: EvalPool::new(per_pool).with_budget(0),
            granted: 0,
            best_score: None,
            best_estimate: None,
            last: None,
            active: true,
            funded: false,
        })
        .collect();

    let mut pot = total_budget;
    let mut stalled: Vec<&'static str> = Vec::new();
    let rounds = rounds.max(1);
    for round in 0..rounds {
        let active = arms.iter().filter(|a| a.active).count();
        if active == 0 || pot == 0 {
            break;
        }
        // spread the pot over the remaining rounds; the last round (or a
        // last surviving arm) drains whatever reallocation freed up
        let installment = if round + 1 == rounds {
            pot
        } else {
            (pot / (rounds - round)).max(1)
        };
        let share = (installment / active).max(1);
        for arm in arms.iter_mut() {
            arm.funded = false;
        }
        for arm in arms.iter_mut().filter(|a| a.active) {
            let g = share.min(pot);
            if g == 0 {
                break;
            }
            pot -= g;
            arm.granted += g;
            arm.pool.grant(g);
            arm.funded = true;
        }

        // run every funded arm concurrently against its warm pool (the
        // scope joins them all before returning)
        std::thread::scope(|s| {
            let space = &space;
            for arm in arms.iter_mut().filter(|a| a.active && a.funded) {
                let _ = s.spawn(move || {
                    let mut searcher = (arm.make)();
                    let r = searcher.search_with(spec, space, &mut arm.pool);
                    arm.last = Some(r);
                });
            }
        });

        // assess: refund converged arms, retire stalled ones
        for arm in arms.iter_mut().filter(|a| a.active && a.funded) {
            let r = arm.last.as_ref().expect("arm ran this round");
            let score = r.best.as_ref().map(|e| e.score(spec.goal));
            let improved = match (score, arm.best_score) {
                (Some(s), Some(prev)) => s > prev,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if improved {
                arm.best_score = score;
                arm.best_estimate = r.best.clone();
            }
            if !r.budget_exhausted {
                // natural convergence: a deterministic re-run with more
                // budget would retrace the same steps, so retire the arm
                // and hand the unspent remainder back to the pot
                pot += arm.granted.saturating_sub(arm.pool.evaluations());
                arm.active = false;
            } else if !improved && round > 0 {
                arm.active = false;
                stalled.push(arm.name);
            }
        }
    }

    let results: Vec<(&'static str, SearchResult, ParetoFront)> = arms
        .into_iter()
        .map(|mut arm| {
            let mut r = arm.last.unwrap_or_else(|| SearchResult {
                best: None,
                evaluations: 0,
                budget_exhausted: false,
            });
            // report the cumulative spend and the cross-round best, not
            // the last round's delta/outcome
            r.evaluations = arm.pool.evaluations();
            r.best = arm.best_estimate;
            (arm.name, r, arm.pool.take_front())
        })
        .collect();
    merge_portfolio(spec, results, stalled)
}

fn merge_portfolio(
    spec: &AppSpec,
    results: Vec<(&'static str, SearchResult, ParetoFront)>,
    stalled: Vec<&'static str>,
) -> Portfolio {
    let mut front = ParetoFront::new();
    let mut best: Option<Estimate> = None;
    let mut evaluations = 0usize;
    let mut runs = Vec::new();
    for (name, r, f) in results {
        front.merge(&f);
        evaluations += r.evaluations;
        if let Some(e) = &r.best {
            let better = match &best {
                None => true,
                Some(b) => e.score(spec.goal) > b.score(spec.goal),
            };
            if better {
                best = Some(e.clone());
            }
        }
        runs.push((name, r));
    }
    Portfolio {
        best,
        runs,
        front,
        evaluations,
        stalled,
    }
}
