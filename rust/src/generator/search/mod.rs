//! Search algorithms over the design space (§4 "implement search
//! algorithms ... to explore combinations of inputs").
//!
//! Four searchers with one interface, plus the Pareto front:
//!
//! * [`exhaustive`] — the ground truth on this space (~10^4 points).
//! * [`greedy`] — coordinate ascent from a feasible seed.
//! * [`annealing`] — simulated annealing with per-axis neighbour moves.
//! * [`genetic`] — a small GA (tournament selection, uniform crossover).
//!
//! The ablation bench (E7) reports how close each heuristic gets to the
//! exhaustive optimum at what fraction of the evaluation budget.

pub mod annealing;
pub mod exhaustive;
pub mod genetic;
pub mod greedy;
pub mod pareto;

use super::constraints::AppSpec;
use super::design_space::Candidate;
use super::estimator::Estimate;

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: Option<Estimate>,
    /// Number of estimator evaluations spent.
    pub evaluations: usize,
}

/// Common interface so benches can sweep searchers uniformly.
pub trait Searcher {
    fn name(&self) -> &'static str;
    fn search(&mut self, spec: &AppSpec, space: &[Candidate]) -> SearchResult;
}

/// Convenience: the generator's default pipeline — exhaustive search over
/// the (already small) pruned space.
pub fn generate(spec: &AppSpec) -> SearchResult {
    let space = super::design_space::enumerate(&[]);
    exhaustive::Exhaustive.search(spec, &space)
}
