//! Pareto-front extraction over the multi-objective view of the design
//! space: (energy per item, response latency, worst-dimension
//! utilisation).  The Generator's single-goal searches optimise a scalar;
//! the front is what the evaluation reports show a designer.

use crate::generator::estimator::Estimate;

/// Objective vector (all minimised).
pub fn objectives(e: &Estimate) -> [f64; 3] {
    [
        e.energy_per_item.value(),
        e.response_latency.value(),
        e.utilization,
    ]
}

/// `a` dominates `b` iff a <= b on all objectives and < on at least one.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    let mut strictly = false;
    for i in 0..3 {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// Streaming Pareto front: incremental dominance filtering with an
/// O(|front|) insert, so a DSE sweep maintains the front as candidates
/// are estimated instead of re-scanning the whole result set (the old
/// O(n^2) batch pass).  Membership is identical to the batch scan:
/// infeasible and dominated offers are rejected, and members newly
/// dominated by an insert are evicted.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    members: Vec<(Estimate, [f64; 3])>,
}

impl ParetoFront {
    pub fn new() -> ParetoFront {
        ParetoFront::default()
    }

    /// Offer an estimate; returns true if it joined the front.
    pub fn insert(&mut self, e: &Estimate) -> bool {
        if !e.feasible {
            return false;
        }
        let o = objectives(e);
        if self.members.iter().any(|(_, m)| dominates(m, &o)) {
            return false;
        }
        self.members.retain(|(_, m)| !dominates(&o, m));
        self.members.push((e.clone(), o));
        true
    }

    /// Fold another front in (used to merge per-searcher fronts).
    pub fn merge(&mut self, other: &ParetoFront) {
        for (e, _) in &other.members {
            self.insert(e);
        }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Estimate> {
        self.members.iter().map(|(e, _)| e)
    }

    pub fn into_members(self) -> Vec<Estimate> {
        self.members.into_iter().map(|(e, _)| e).collect()
    }
}

/// Non-dominated subset of a batch (delegates to the streaming front;
/// output preserves the input order of surviving members).
pub fn front(estimates: &[Estimate]) -> Vec<Estimate> {
    let mut f = ParetoFront::new();
    for e in estimates {
        f.insert(e);
    }
    f.into_members()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::constraints::AppSpec;
    use crate::generator::design_space::enumerate;
    use crate::generator::eval::{EvalPool, Evaluator};

    fn estimates(spec: &AppSpec, devices: &[&str]) -> Vec<Estimate> {
        let mut pool = EvalPool::new(2);
        pool.evaluate_batch(spec, &enumerate(devices))
            .into_iter()
            .flatten()
            .collect()
    }

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[2.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 2.0, 0.0], &[2.0, 1.0, 0.0]));
    }

    #[test]
    fn front_is_nondominated_and_nonempty() {
        let spec = AppSpec::soft_sensor();
        let es = estimates(&spec, &["xc7s6", "xc7s15"]);
        let f = front(&es);
        assert!(!f.is_empty());
        assert!(f.len() < es.iter().filter(|e| e.feasible).count());
        // no member dominates another
        for (i, a) in f.iter().enumerate() {
            for (j, b) in f.iter().enumerate() {
                if i != j {
                    assert!(!dominates(&objectives(a), &objectives(b)));
                }
            }
        }
    }

    #[test]
    fn front_members_feasible() {
        let spec = AppSpec::ecg_monitor();
        let es = estimates(&spec, &["xc7s15"]);
        assert!(front(&es).iter().all(|e| e.feasible));
    }

    #[test]
    fn streaming_front_matches_batch_membership() {
        let spec = AppSpec::soft_sensor();
        let es = estimates(&spec, &["xc7s6"]);
        let batch = front(&es);
        // insert in reverse order: membership must not depend on order
        let mut reversed = ParetoFront::new();
        for e in es.iter().rev() {
            reversed.insert(e);
        }
        let key = |e: &Estimate| e.candidate.describe();
        let mut a: Vec<String> = batch.iter().map(key).collect();
        let mut b: Vec<String> = reversed.iter().map(key).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn insert_evicts_dominated_members() {
        let spec = AppSpec::soft_sensor();
        let es = estimates(&spec, &["xc7s6", "xc7s15"]);
        let full = front(&es);
        // a front seeded with every feasible estimate (dominated ones
        // included, one by one) must converge to the same membership
        let mut f = ParetoFront::new();
        let mut offered = 0usize;
        for e in es.iter().filter(|e| e.feasible) {
            f.insert(e);
            offered += 1;
        }
        assert!(offered > f.len(), "nothing was ever evicted/rejected");
        assert_eq!(f.len(), full.len());
    }
}
