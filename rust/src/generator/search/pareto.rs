//! Pareto-front extraction over the multi-objective view of the design
//! space: (energy per item, response latency, worst-dimension
//! utilisation).  The Generator's single-goal searches optimise a scalar;
//! the front is what the evaluation reports show a designer.

use crate::generator::estimator::Estimate;

/// Objective vector (all minimised).
pub fn objectives(e: &Estimate) -> [f64; 3] {
    [
        e.energy_per_item.value(),
        e.response_latency.value(),
        e.utilization,
    ]
}

/// `a` dominates `b` iff a <= b on all objectives and < on at least one.
pub fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    let mut strictly = false;
    for i in 0..3 {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// Non-dominated subset (simple O(n^2), fine at this scale).
pub fn front(estimates: &[Estimate]) -> Vec<Estimate> {
    let objs: Vec<[f64; 3]> = estimates.iter().map(objectives).collect();
    estimates
        .iter()
        .enumerate()
        .filter(|(i, e)| {
            e.feasible
                && !objs
                    .iter()
                    .enumerate()
                    .any(|(j, o)| j != *i && estimates[j].feasible && dominates(o, &objs[*i]))
        })
        .map(|(_, e)| e.clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::constraints::AppSpec;
    use crate::generator::design_space::enumerate;
    use crate::generator::estimator::estimate;

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[2.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]));
        assert!(!dominates(&[1.0, 2.0, 0.0], &[2.0, 1.0, 0.0]));
    }

    #[test]
    fn front_is_nondominated_and_nonempty() {
        let spec = AppSpec::soft_sensor();
        let es: Vec<Estimate> = enumerate(&["xc7s6", "xc7s15"])
            .iter()
            .map(|c| estimate(&spec, c))
            .collect();
        let f = front(&es);
        assert!(!f.is_empty());
        assert!(f.len() < es.iter().filter(|e| e.feasible).count());
        // no member dominates another
        for a in &f {
            for b in &f {
                let (oa, ob) = (objectives(a), objectives(b));
                if oa != ob {
                    assert!(!dominates(&oa, &ob) || !dominates(&ob, &oa));
                }
            }
        }
    }

    #[test]
    fn front_members_feasible() {
        let spec = AppSpec::ecg_monitor();
        let es: Vec<Estimate> = enumerate(&["xc7s15"])
            .iter()
            .map(|c| estimate(&spec, c))
            .collect();
        assert!(front(&es).iter().all(|e| e.feasible));
    }
}
