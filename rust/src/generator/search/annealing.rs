//! Simulated annealing: random single-axis neighbour moves with a
//! geometric temperature schedule.  Infeasible states are admitted early
//! (scored by a large penalty instead of -inf) so the walk can cross
//! infeasible ridges, and frozen out as the temperature drops.  Several
//! independent chains (restarts) run back to back; the best feasible
//! state across all of them wins.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, N_AXES};
use crate::generator::estimator::Estimate;
use crate::generator::eval::Evaluator;
use crate::util::rng::Rng;

pub struct Annealing {
    pub seed: u64,
    pub steps: usize,
    pub t0: f64,
    pub cooling: f64,
    /// Independent chains run back to back (best-of across chains).
    pub restarts: usize,
}

impl Default for Annealing {
    fn default() -> Annealing {
        Annealing {
            seed: 11,
            steps: 800,
            t0: 1.0,
            cooling: 0.995,
            restarts: 2,
        }
    }
}

/// Soft score: feasible candidates keep their goal score; infeasible ones
/// are pushed far below any feasible value but remain comparable.
fn soft_score(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

impl Searcher for Annealing {
    fn name(&self) -> &'static str {
        "annealing"
    }

    fn search_with(
        &mut self,
        spec: &AppSpec,
        _space: &[Candidate],
        eval: &mut dyn Evaluator,
    ) -> SearchResult {
        let axes = Axes::new(&spec.device_allowlist);
        let dims = axes.dims();
        let start_evals = eval.evaluations();
        let mut rng = Rng::new(self.seed);
        let mut best: Option<Estimate> = None;
        let mut best_s = f64::NEG_INFINITY;

        'chains: for _ in 0..self.restarts.max(1) {
            let mut g = axes.random(&mut rng);
            let Some(mut cur) = eval.evaluate(spec, &axes.candidate(&g)) else {
                break 'chains;
            };
            let mut cur_s = soft_score(&cur, spec);
            if cur.feasible && cur_s > best_s {
                best_s = cur_s;
                best = Some(cur.clone());
            }

            // Acceptance scale, normalised to typical *feasible* score
            // magnitudes.  Freezing it from an infeasible start (penalty
            // scores, |score| ~ 1e12) made `(d / scale)` collapse to ~0
            // for every feasible-region move — exp(..) ~ 1, every
            // downhill move accepted, and the annealer degenerated into a
            // random walk.  The scale is therefore re-anchored to the
            // first feasible score the chain sees.
            let mut scale = cur_s.abs().max(1e-6);
            let mut scale_anchored = cur.feasible;
            let mut temp = self.t0;

            for _ in 0..self.steps {
                let axis = rng.below(N_AXES as u64) as usize;
                let old = g[axis];
                let mut new = rng.below(dims[axis] as u64) as usize;
                if new == old {
                    new = (new + 1) % dims[axis];
                }
                g[axis] = new;
                let Some(e) = eval.evaluate(spec, &axes.candidate(&g)) else {
                    break 'chains;
                };
                let s = soft_score(&e, spec);
                if e.feasible && !scale_anchored {
                    scale = s.abs().max(1e-6);
                    scale_anchored = true;
                }
                let accept = s >= cur_s || {
                    let d = (s - cur_s) / scale;
                    rng.chance((d / temp).exp())
                };
                if accept {
                    cur_s = s;
                    cur = e;
                    if cur.feasible && cur_s > best_s {
                        best_s = cur_s;
                        best = Some(cur.clone());
                    }
                } else {
                    g[axis] = old;
                }
                temp *= self.cooling;
            }
        }

        SearchResult {
            best,
            evaluations: eval.evaluations() - start_evals,
            budget_exhausted: eval.budget_exhausted(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;
    use crate::generator::eval::EvalPool;
    use crate::generator::search::exhaustive::Exhaustive;

    #[test]
    fn annealing_finds_feasible_near_optimum() {
        let spec = AppSpec::har_wearable();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let got = Annealing::default().search(&spec, &space).best.unwrap();
        assert!(got.feasible);
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "annealing {ratio}x worse than optimum");
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let a = Annealing::default().search(&spec, &space).best.unwrap();
        let b = Annealing::default().search(&spec, &space).best.unwrap();
        assert_eq!(a.candidate.describe(), b.candidate.describe());
    }

    #[test]
    fn recovers_from_infeasible_start() {
        // Regression for the acceptance-scale bug: chains seeded at an
        // infeasible state must still anneal to a good feasible optimum
        // instead of degenerating into a random walk.
        let spec = AppSpec::har_wearable();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let axes = Axes::new(&spec.device_allowlist);
        let mut probe = EvalPool::new(1);

        let mut tried = 0usize;
        for seed in 0..500u64 {
            // replicate the searcher's own seeding to find infeasible starts
            let mut rng = Rng::new(seed);
            let g = axes.random(&mut rng);
            let e = probe.evaluate(&spec, &axes.candidate(&g)).unwrap();
            if e.feasible {
                continue;
            }
            tried += 1;
            // restarts: 1 isolates the chain that provably starts
            // infeasible — a lucky feasible second chain must not be able
            // to mask a reintroduced scale-freezing bug
            let r = Annealing { seed, restarts: 1, ..Default::default() }.search(&spec, &space);
            let got = r
                .best
                .unwrap_or_else(|| panic!("seed {seed}: nothing feasible from infeasible start"));
            let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
            assert!(
                ratio < 3.0,
                "seed {seed}: {ratio:.2}x off optimum from infeasible start"
            );
            if tried >= 3 {
                break;
            }
        }
        assert!(tried >= 1, "no infeasible start found in the seed range");
    }
}
