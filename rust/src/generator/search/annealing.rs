//! Simulated annealing: random single-axis neighbour moves with a
//! geometric temperature schedule.  Infeasible states are admitted early
//! (scored by a large penalty instead of -inf) so the walk can cross
//! infeasible ridges, and frozen out as the temperature drops.

use super::{SearchResult, Searcher};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{Axes, Candidate, N_AXES};
use crate::generator::estimator::{estimate, Estimate};
use crate::util::rng::Rng;

pub struct Annealing {
    pub seed: u64,
    pub steps: usize,
    pub t0: f64,
    pub cooling: f64,
}

impl Default for Annealing {
    fn default() -> Annealing {
        Annealing {
            seed: 11,
            steps: 800,
            t0: 1.0,
            cooling: 0.995,
        }
    }
}

/// Soft score: feasible candidates keep their goal score; infeasible ones
/// are pushed far below any feasible value but remain comparable.
fn soft_score(e: &Estimate, spec: &AppSpec) -> f64 {
    if e.feasible {
        e.score(spec.goal)
    } else {
        -1e12 * (1.0 + e.utilization)
    }
}

impl Searcher for Annealing {
    fn name(&self) -> &'static str {
        "annealing"
    }

    fn search(&mut self, spec: &AppSpec, _space: &[Candidate]) -> SearchResult {
        let axes = Axes::new(&[]);
        let dims = axes.dims();
        let mut rng = Rng::new(self.seed);
        let mut evals = 0usize;

        let mut g = axes.random(&mut rng);
        let mut cur = estimate(spec, &axes.candidate(&g));
        evals += 1;
        let mut cur_s = soft_score(&cur, spec);
        let mut best: Option<Estimate> = cur.feasible.then(|| cur.clone());
        let mut best_s = if cur.feasible { cur_s } else { f64::NEG_INFINITY };

        // normalise the acceptance scale to typical score magnitudes
        let scale = cur_s.abs().max(1e-6);
        let mut temp = self.t0;

        for _ in 0..self.steps {
            let axis = rng.below(N_AXES as u64) as usize;
            let old = g[axis];
            let mut new = rng.below(dims[axis] as u64) as usize;
            if new == old {
                new = (new + 1) % dims[axis];
            }
            g[axis] = new;
            let e = estimate(spec, &axes.candidate(&g));
            evals += 1;
            let s = soft_score(&e, spec);
            let accept = s >= cur_s || {
                let d = (s - cur_s) / scale;
                rng.chance((d / temp).exp())
            };
            if accept {
                cur_s = s;
                cur = e;
                if cur.feasible && cur_s > best_s {
                    best_s = cur_s;
                    best = Some(cur.clone());
                }
            } else {
                g[axis] = old;
            }
            temp *= self.cooling;
        }

        SearchResult {
            best,
            evaluations: evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;
    use crate::generator::search::exhaustive::Exhaustive;

    #[test]
    fn annealing_finds_feasible_near_optimum() {
        let spec = AppSpec::har_wearable();
        let space = enumerate(&[]);
        let opt = Exhaustive.search(&spec, &space).best.unwrap();
        let got = Annealing::default().search(&spec, &space).best.unwrap();
        assert!(got.feasible);
        let ratio = got.energy_per_item.value() / opt.energy_per_item.value();
        assert!(ratio < 2.0, "annealing {ratio}x worse than optimum");
    }

    #[test]
    fn deterministic_for_seed() {
        let spec = AppSpec::soft_sensor();
        let space = enumerate(&[]);
        let a = Annealing::default().search(&spec, &space).best.unwrap();
        let b = Annealing::default().search(&spec, &space).best.unwrap();
        assert_eq!(a.candidate.describe(), b.candidate.describe());
    }
}
