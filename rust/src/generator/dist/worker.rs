//! One shard's work, shared by the `elastic-gen dse-worker` subprocess
//! and the driver's hermetic in-process mode.  Two phases share the
//! protocol, selected by `ShardSpec::scales`:
//!
//! * **sweep** (`scales: None`) — sweep the shard's stripe through an
//!   [`EvalPool`], fit shard-local `ModelScales` on the stripe's Pareto
//!   finalists via DES replay.
//! * **refinement** (`scales: Some`) — re-rank the stripe through a
//!   [`CalibratedEstimator`] carrying the driver's corrected constants,
//!   ship the corrected-coordinate Pareto finalists, and report the
//!   corrected model's DES rank agreement on them (the driver's guard
//!   signal; no new fit — the shipped scales echo the correction in
//!   force).
//!
//! Either way the result is a self-contained, host-portable
//! [`ShardResult`].

use std::io::Read;

use anyhow::Context;

use crate::generator::calibrate::{
    calibrate_finalists, rank_agreement, refine_with, replay_all, CalibrateOpts,
    CalibratedEstimator, ModelScales, RankAgreement,
};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{enumerate, Candidate};
use crate::generator::estimator::Estimate;
use crate::generator::eval::{EvalPool, Evaluator};
use crate::generator::search::exhaustive::Exhaustive;
use crate::generator::search::Searcher;
use crate::util::rng::Rng;

use super::plan::stripe;
use super::wire::ShardSpec;

/// Everything one shard contributes to a distributed sweep.  Candidates
/// only — estimates are re-derived deterministically on the driver from
/// the decoded candidates, so the wire stays small and host-portable.
#[derive(Debug, Clone)]
pub struct ShardResult {
    pub app: String,
    pub shard: usize,
    pub of: usize,
    /// Estimator evaluations the shard paid (memo hits are free).
    pub evaluations: usize,
    /// Total evaluation requests including memo hits.
    pub eval_requests: usize,
    pub budget_exhausted: bool,
    /// The shard's Pareto finalists, describe-sorted (canonical order).
    pub front: Vec<Candidate>,
    /// The shard's best candidate by the scenario goal, if any stripe
    /// member was feasible.
    pub best: Option<Candidate>,
    /// Global enumeration index of `best` — the driver breaks exact
    /// score ties by this, matching the single-process sweep's
    /// first-in-enumeration-order winner.
    pub best_index: Option<usize>,
    /// Per-component `ModelScales` fitted on this shard's finalists
    /// (identity when the fit fell back).
    pub scales: ModelScales,
    pub fell_back: bool,
    /// Estimator↔DES rank agreement before the fit.
    pub pre: RankAgreement, // lint: wire(tau_pre)
    /// Agreement under the shipped scales (== `pre` when fell back).
    pub post: RankAgreement, // lint: wire(tau_post)
}

pub(crate) fn scenario(name: &str) -> anyhow::Result<AppSpec> {
    AppSpec::scenarios()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario '{name}' in shard spec"))
}

/// Execute one shard: stripe sweep (shard-local calibration fit) or, when
/// the spec carries corrected constants, the calibrated refinement
/// re-rank of the stripe.
pub fn run_shard(spec: &ShardSpec) -> anyhow::Result<ShardResult> {
    anyhow::ensure!(spec.of >= 1, "shard count must be >= 1");
    anyhow::ensure!(
        spec.shard < spec.of,
        "shard index {} out of range for {} shards",
        spec.shard,
        spec.of
    );
    let app = scenario(&spec.app)?;
    let space = enumerate(&app.device_allowlist);
    let mine = stripe(&space, spec.shard, spec.of);

    let mut pool = EvalPool::new(spec.threads.max(1));
    if let Some(b) = spec.budget {
        pool = pool.with_budget(b);
    }
    if let Some(scales) = spec.scales {
        return run_refine_shard(spec, &app, &mine, pool, scales);
    }
    let sweep = Exhaustive.search_with(&app, &mine, &mut pool);
    let evaluations = pool.evaluations();
    let eval_requests = pool.requests();
    let budget_exhausted = pool.budget_exhausted();
    let finalists = pool.take_front().into_members();

    // shard-local calibration: DES replay of this stripe's finalists on
    // the driver-issued trace, least-squares fit, tau agreement — the
    // scales and agreement travel with the front so the driver can
    // guard the merge without replaying every shard itself
    let opts = CalibrateOpts {
        threads: spec.threads.max(1),
        requests: spec.requests,
        seed: spec.seed,
        budget: None,
    };
    let cal = calibrate_finalists(&app, finalists, &opts);
    let front: Vec<Candidate> = cal
        .replays
        .iter()
        .map(|r| r.estimate.candidate.clone())
        .collect();

    let (best, best_index) = best_with_index(spec, &mine, &sweep.best)?;

    Ok(ShardResult {
        app: app.name.clone(),
        shard: spec.shard,
        of: spec.of,
        evaluations,
        eval_requests,
        budget_exhausted,
        front,
        best,
        best_index,
        scales: cal.scales,
        fell_back: cal.fell_back,
        pre: cal.before,
        post: cal.after,
    })
}

/// Map a stripe-local best back to (candidate, global enumeration index)
/// — the driver breaks exact score ties by this index, matching the
/// single-process first-in-enumeration-order winner.
fn best_with_index(
    spec: &ShardSpec,
    mine: &[Candidate],
    best: &Option<Estimate>,
) -> anyhow::Result<(Option<Candidate>, Option<usize>)> {
    match best {
        Some(b) => {
            let key = b.candidate.describe();
            let local = mine
                .iter()
                .position(|c| c.describe() == key)
                .context("best missing from its own stripe")?;
            let global = spec.shard + local * spec.of;
            Ok((Some(b.candidate.clone()), Some(global)))
        }
        None => Ok((None, None)),
    }
}

/// The refinement phase of one shard: re-rank the stripe through a
/// [`CalibratedEstimator`] carrying the driver's corrected constants and
/// ship the corrected-coordinate Pareto finalists.  No new fit happens
/// here — the shipped scales echo the correction in force, and the
/// pre/post agreement is the corrected model's DES rank agreement on
/// this stripe's finalists (what the driver's tau-floor guard reads).
fn run_refine_shard(
    spec: &ShardSpec,
    app: &AppSpec,
    mine: &[Candidate],
    pool: EvalPool,
    scales: ModelScales,
) -> anyhow::Result<ShardResult> {
    let refined = refine_with(app, mine, CalibratedEstimator::new(pool, scales));
    // corrected-coordinate finalists, describe-sorted (canonical order)
    let mut finalists: Vec<Estimate> = refined.front.into_members();
    finalists.sort_by(|a, b| a.candidate.describe().cmp(&b.candidate.describe()));
    let arrivals = app.workload.arrivals(spec.requests, &mut Rng::new(spec.seed));
    let replays = replay_all(&finalists, &arrivals, spec.threads.max(1));
    let est: Vec<f64> = finalists.iter().map(|e| e.energy_per_item.value()).collect();
    let sim: Vec<f64> = replays.iter().map(|r| r.sim_energy_per_item.value()).collect();
    let agreement = rank_agreement(&est, &sim);
    let (best, best_index) = best_with_index(spec, mine, &refined.best)?;
    Ok(ShardResult {
        app: app.name.clone(),
        shard: spec.shard,
        of: spec.of,
        evaluations: refined.evaluations,
        eval_requests: refined.requests,
        budget_exhausted: refined.budget_exhausted,
        front: finalists.iter().map(|e| e.candidate.clone()).collect(),
        best,
        best_index,
        scales,
        fell_back: false,
        pre: agreement,
        post: agreement,
    })
}

/// The `elastic-gen dse-worker` body: shard spec JSON on stdin, shard
/// result JSON on stdout (nothing else is written there).
pub fn worker_stdio() -> anyhow::Result<()> {
    let mut buf = String::new();
    std::io::stdin()
        .read_to_string(&mut buf)
        .context("reading shard spec from stdin")?;
    let spec = ShardSpec::from_json_str(&buf)?;
    let result = run_shard(&spec)?;
    // lint: allow(obs-print) — stdout IS the wire protocol here: the driver reads
    // exactly this one JSON line as the shard result; diagnostics still belong in
    // the journal, not here
    println!("{}", result.to_json().dump());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(shard: usize, of: usize) -> ShardSpec {
        ShardSpec {
            app: "har-wearable".into(),
            shard,
            of,
            budget: None,
            seed: 11,
            requests: 60,
            threads: 1,
            scales: None,
        }
    }

    #[test]
    fn shard_result_is_self_consistent() {
        let r = run_shard(&quick_spec(0, 2)).unwrap();
        assert_eq!(r.app, "har-wearable");
        assert_eq!((r.shard, r.of), (0, 2));
        assert!(r.evaluations > 0);
        assert!(!r.front.is_empty());
        // canonical describe-sorted order
        let keys: Vec<String> = r.front.iter().map(|c| c.describe()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // best index points back at the best candidate in the stripe
        let (best, idx) = (r.best.unwrap(), r.best_index.unwrap());
        assert_eq!(idx % 2, 0, "index {idx} not in stripe 0 of 2");
        let app = scenario("har-wearable").unwrap();
        let space = enumerate(&app.device_allowlist);
        assert_eq!(space[idx].describe(), best.describe());
    }

    #[test]
    fn wire_roundtrip_preserves_the_result() {
        let r = run_shard(&quick_spec(1, 3)).unwrap();
        let back = ShardResult::from_json_str(&r.to_json().dump()).unwrap();
        assert_eq!(back.app, r.app);
        assert_eq!((back.shard, back.of), (r.shard, r.of));
        assert_eq!(back.evaluations, r.evaluations);
        assert_eq!(back.eval_requests, r.eval_requests);
        assert_eq!(back.budget_exhausted, r.budget_exhausted);
        assert_eq!(back.front.len(), r.front.len());
        for (a, b) in back.front.iter().zip(&r.front) {
            assert_eq!(a.describe(), b.describe());
        }
        assert_eq!(
            back.best.map(|c| c.describe()),
            r.best.as_ref().map(|c| c.describe())
        );
        assert_eq!(back.best_index, r.best_index);
        assert_eq!(back.scales, r.scales);
        assert_eq!(back.pre, r.pre);
        assert_eq!(back.post, r.post);
    }

    #[test]
    fn rejects_bad_shard_indices_and_apps() {
        assert!(run_shard(&quick_spec(2, 2)).is_err());
        let mut bad = quick_spec(0, 1);
        bad.app = "no-such-app".into();
        assert!(run_shard(&bad).is_err());
    }

    #[test]
    fn refinement_shard_reranks_under_the_shipped_scales() {
        let scales = ModelScales { busy: 1.3, idle: 0.8, off: 1.0, cold: 0.6 };
        let mut spec = quick_spec(0, 2);
        spec.scales = Some(scales);
        let r = run_shard(&spec).unwrap();
        // the shipped scales echo the correction in force; nothing fits
        // (or falls back) during refinement
        assert_eq!(r.scales, scales);
        assert!(!r.fell_back);
        assert_eq!(r.pre, r.post);
        assert!(!r.front.is_empty());
        // a full-space refinement shard (of=1) reproduces the
        // single-process refine() front and best exactly
        let mut full = quick_spec(0, 1);
        full.scales = Some(scales);
        let dist = run_shard(&full).unwrap();
        let app = scenario("har-wearable").unwrap();
        let local = crate::generator::calibrate::refine(&app, scales, 1);
        let mut keys: Vec<String> = local.front.iter().map(|e| e.candidate.describe()).collect();
        keys.sort();
        let dist_keys: Vec<String> = dist.front.iter().map(|c| c.describe()).collect();
        assert_eq!(dist_keys, keys);
        assert_eq!(
            dist.best.map(|c| c.describe()),
            local.best.map(|e| e.candidate.describe())
        );
    }
}
