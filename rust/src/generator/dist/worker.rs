//! One shard's work, shared by the `elastic-gen dse-worker` subprocess
//! and the driver's hermetic in-process mode: sweep the shard's stripe
//! through an [`EvalPool`], fit shard-local `ModelScales` on the
//! stripe's Pareto finalists via DES replay, and package everything as a
//! self-contained, host-portable [`ShardResult`].

use std::io::Read;

use anyhow::Context;

use crate::generator::calibrate::{calibrate_finalists, CalibrateOpts, ModelScales, RankAgreement};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::{enumerate, Candidate};
use crate::generator::eval::{EvalPool, Evaluator};
use crate::generator::search::exhaustive::Exhaustive;
use crate::generator::search::Searcher;

use super::plan::stripe;
use super::wire::ShardSpec;

/// Everything one shard contributes to a distributed sweep.  Candidates
/// only — estimates are re-derived deterministically on the driver from
/// the decoded candidates, so the wire stays small and host-portable.
#[derive(Debug, Clone)]
pub struct ShardResult {
    pub app: String,
    pub shard: usize,
    pub of: usize,
    /// Estimator evaluations the shard paid (memo hits are free).
    pub evaluations: usize,
    /// Total evaluation requests including memo hits.
    pub eval_requests: usize,
    pub budget_exhausted: bool,
    /// The shard's Pareto finalists, describe-sorted (canonical order).
    pub front: Vec<Candidate>,
    /// The shard's best candidate by the scenario goal, if any stripe
    /// member was feasible.
    pub best: Option<Candidate>,
    /// Global enumeration index of `best` — the driver breaks exact
    /// score ties by this, matching the single-process sweep's
    /// first-in-enumeration-order winner.
    pub best_index: Option<usize>,
    /// Per-component `ModelScales` fitted on this shard's finalists
    /// (identity when the fit fell back).
    pub scales: ModelScales,
    pub fell_back: bool,
    /// Estimator↔DES rank agreement before the fit.
    pub pre: RankAgreement,
    /// Agreement under the shipped scales (== `pre` when fell back).
    pub post: RankAgreement,
}

pub(crate) fn scenario(name: &str) -> anyhow::Result<AppSpec> {
    AppSpec::scenarios()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario '{name}' in shard spec"))
}

/// Execute one shard: stripe sweep, shard-local calibration fit, result.
pub fn run_shard(spec: &ShardSpec) -> anyhow::Result<ShardResult> {
    anyhow::ensure!(spec.of >= 1, "shard count must be >= 1");
    anyhow::ensure!(
        spec.shard < spec.of,
        "shard index {} out of range for {} shards",
        spec.shard,
        spec.of
    );
    let app = scenario(&spec.app)?;
    let space = enumerate(&app.device_allowlist);
    let mine = stripe(&space, spec.shard, spec.of);

    let mut pool = EvalPool::new(spec.threads.max(1));
    if let Some(b) = spec.budget {
        pool = pool.with_budget(b);
    }
    let sweep = Exhaustive.search_with(&app, &mine, &mut pool);
    let evaluations = pool.evaluations();
    let eval_requests = pool.requests();
    let budget_exhausted = pool.budget_exhausted();
    let finalists = pool.take_front().into_members();

    // shard-local calibration: DES replay of this stripe's finalists on
    // the driver-issued trace, least-squares fit, tau agreement — the
    // scales and agreement travel with the front so the driver can
    // guard the merge without replaying every shard itself
    let opts = CalibrateOpts {
        threads: spec.threads.max(1),
        requests: spec.requests,
        seed: spec.seed,
        budget: None,
    };
    let cal = calibrate_finalists(&app, finalists, &opts);
    let front: Vec<Candidate> = cal
        .replays
        .iter()
        .map(|r| r.estimate.candidate.clone())
        .collect();

    let (best, best_index) = match &sweep.best {
        Some(b) => {
            let key = b.candidate.describe();
            let local = mine
                .iter()
                .position(|c| c.describe() == key)
                .context("sweep best missing from its own stripe")?;
            (
                Some(b.candidate.clone()),
                Some(spec.shard + local * spec.of),
            )
        }
        None => (None, None),
    };

    Ok(ShardResult {
        app: app.name.clone(),
        shard: spec.shard,
        of: spec.of,
        evaluations,
        eval_requests,
        budget_exhausted,
        front,
        best,
        best_index,
        scales: cal.scales,
        fell_back: cal.fell_back,
        pre: cal.before,
        post: cal.after,
    })
}

/// The `elastic-gen dse-worker` body: shard spec JSON on stdin, shard
/// result JSON on stdout (nothing else is written there).
pub fn worker_stdio() -> anyhow::Result<()> {
    let mut buf = String::new();
    std::io::stdin()
        .read_to_string(&mut buf)
        .context("reading shard spec from stdin")?;
    let spec = ShardSpec::from_json_str(&buf)?;
    let result = run_shard(&spec)?;
    println!("{}", result.to_json().dump());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(shard: usize, of: usize) -> ShardSpec {
        ShardSpec {
            app: "har-wearable".into(),
            shard,
            of,
            budget: None,
            seed: 11,
            requests: 60,
            threads: 1,
        }
    }

    #[test]
    fn shard_result_is_self_consistent() {
        let r = run_shard(&quick_spec(0, 2)).unwrap();
        assert_eq!(r.app, "har-wearable");
        assert_eq!((r.shard, r.of), (0, 2));
        assert!(r.evaluations > 0);
        assert!(!r.front.is_empty());
        // canonical describe-sorted order
        let keys: Vec<String> = r.front.iter().map(|c| c.describe()).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // best index points back at the best candidate in the stripe
        let (best, idx) = (r.best.unwrap(), r.best_index.unwrap());
        assert_eq!(idx % 2, 0, "index {idx} not in stripe 0 of 2");
        let app = scenario("har-wearable").unwrap();
        let space = enumerate(&app.device_allowlist);
        assert_eq!(space[idx].describe(), best.describe());
    }

    #[test]
    fn wire_roundtrip_preserves_the_result() {
        let r = run_shard(&quick_spec(1, 3)).unwrap();
        let back = ShardResult::from_json_str(&r.to_json().dump()).unwrap();
        assert_eq!(back.app, r.app);
        assert_eq!((back.shard, back.of), (r.shard, r.of));
        assert_eq!(back.evaluations, r.evaluations);
        assert_eq!(back.eval_requests, r.eval_requests);
        assert_eq!(back.budget_exhausted, r.budget_exhausted);
        assert_eq!(back.front.len(), r.front.len());
        for (a, b) in back.front.iter().zip(&r.front) {
            assert_eq!(a.describe(), b.describe());
        }
        assert_eq!(
            back.best.map(|c| c.describe()),
            r.best.as_ref().map(|c| c.describe())
        );
        assert_eq!(back.best_index, r.best_index);
        assert_eq!(back.scales, r.scales);
        assert_eq!(back.pre, r.pre);
        assert_eq!(back.post, r.post);
    }

    #[test]
    fn rejects_bad_shard_indices_and_apps() {
        assert!(run_shard(&quick_spec(2, 2)).is_err());
        let mut bad = quick_spec(0, 1);
        bad.app = "no-such-app".into();
        assert!(run_shard(&bad).is_err());
    }
}
