//! Distributed DSE: process-sharded sweeps with calibration-guarded
//! Pareto-front merging, plus the distributed calibrated-refinement
//! phase — the subsystem that turns the single-machine generator into a
//! distributable exploration service running the full
//! estimator↔simulator loop.
//!
//! Pipeline (see DESIGN.md "Distributed DSE"):
//!
//! * [`plan`] — the shard planner: partitions a scenario's design space
//!   into disjoint candidate stripes over the enumeration order (shard
//!   `s` of `N` owns global indices `s, s+N, s+2N, …`), so shards carry
//!   comparable estimator cost, and splits an evaluation budget so the
//!   union of per-shard prefixes is exactly the single-process budget
//!   prefix — on the sweep *and* on the refinement re-shard.
//! * [`wire`] — the host-portable JSON protocol (`util::json`): shard
//!   specs in, self-contained shard results out, candidates encoded by
//!   their axis fields and keyed by `Candidate::describe()` (decode
//!   re-derives the key and rejects mismatches, so a corrupt or
//!   cross-version payload cannot silently fold into a front).  A spec
//!   optionally carries `ModelScales`, which turns the shard into a
//!   refinement shard.
//! * [`worker`] — one shard's work: stripe sweep through an `EvalPool`
//!   with a shard-local `ModelScales` fit (sweep phase), or a
//!   re-ranking of the stripe through a `CalibratedEstimator` under the
//!   driver's corrected constants (refinement phase) — the payload
//!   behind the `elastic-gen dse-worker` subcommand.
//! * [`driver`] — [`DistSweep`]: spawns N workers (subprocesses or
//!   in-process for hermetic tests), reassigns crashed/timed-out shards,
//!   and merges into one streaming `ParetoFront`.  `run` is the sweep,
//!   `run_refine` the refinement, and `run_calibrated` chains them with
//!   a driver-side fit on the merged front into the full distributed
//!   estimator↔simulator loop.
//!
//! Determinism contract: sweep dominance is evaluated in the
//! *uncorrected* closed form's coordinates and refinement dominance in
//! the *corrected* ones — in both cases a coordinate frame every host
//! shares, with exact best-score ties broken by global enumeration
//! index — so each phase's merged front/best is bit-identical to the
//! corresponding single-process pass for any worker count (including
//! one), and independent of which shards crashed and were reassigned.
//! The calibration guard decides trust, not membership: a shard whose
//! shipped tau sits at or below the floor has its finalists re-ranked
//! through a DES replay before folding (and, on the sweep, its fit
//! quarantined from the consensus).

pub mod driver;
pub mod plan;
pub mod wire;
pub mod worker;

pub use driver::{
    assert_front_parity, single_process_reference, DistCalOutcome, DistOutcome, DistOpts,
    DistSweep, RefineOutcome, ShardRun, WorkerMode,
};
pub use plan::{plan_shards, stripe, stripe_budget};
pub use wire::ShardSpec;
pub use worker::{run_shard, worker_stdio, ShardResult};
