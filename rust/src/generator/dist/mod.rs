//! Distributed DSE: process-sharded sweeps with calibration-guarded
//! Pareto-front merging — the subsystem that turns the single-machine
//! generator into a distributable exploration service.
//!
//! Pipeline (see DESIGN.md "Distributed DSE"):
//!
//! * [`plan`] — the shard planner: partitions a scenario's design space
//!   into disjoint candidate stripes over the enumeration order (shard
//!   `s` of `N` owns global indices `s, s+N, s+2N, …`), so shards carry
//!   comparable estimator cost, and splits an evaluation budget so the
//!   union of per-shard prefixes is exactly the single-process budget
//!   prefix.
//! * [`wire`] — the host-portable JSON protocol (`util::json`): shard
//!   specs in, self-contained shard results out, candidates encoded by
//!   their axis fields and keyed by `Candidate::describe()` (decode
//!   re-derives the key and rejects mismatches, so a corrupt or
//!   cross-version payload cannot silently fold into a front).
//! * [`worker`] — one shard's work: stripe sweep through an `EvalPool`,
//!   shard-local Pareto front, per-component `ModelScales` fitted on the
//!   shard's finalists via DES replay, and Kendall-tau agreement — the
//!   payload behind the `elastic-gen dse-worker` subcommand.
//! * [`driver`] — [`DistSweep`]: spawns N workers (subprocesses or
//!   in-process for hermetic tests), reassigns crashed/timed-out shards,
//!   and performs the calibration-guarded merge into one streaming
//!   `ParetoFront`.
//!
//! Determinism contract: dominance is always evaluated in the
//! *uncorrected* closed form's coordinates — the common reference frame
//! every host shares — so the merged front is bit-identical to the
//! single-process sweep for any worker count (including one), and
//! independent of which shards crashed and were reassigned.  Per-shard
//! `ModelScales` travel with each front; shards whose fitted tau clears
//! the floor contribute to the consensus correction, while a disagreeing
//! shard's finalists are re-ranked through a DES replay
//! (ground-truth-first fold order, surfaced per shard) and its fit is
//! quarantined from the consensus.

pub mod driver;
pub mod plan;
pub mod wire;
pub mod worker;

pub use driver::{
    assert_front_parity, single_process_reference, DistOutcome, DistOpts, DistSweep, ShardRun,
    WorkerMode,
};
pub use plan::{plan_shards, stripe, stripe_budget};
pub use wire::ShardSpec;
pub use worker::{run_shard, worker_stdio, ShardResult};
