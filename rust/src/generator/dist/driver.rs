//! [`DistSweep`]: the distributed-DSE driver.  Plans shards, runs them
//! on N workers (subprocesses speaking the stdin/stdout JSON protocol,
//! or in-process for hermetic tests and benches), reassigns
//! crashed/timed-out shards, and performs the calibration-guarded merge
//! into one streaming [`ParetoFront`].
//!
//! Determinism: dominance is always evaluated in the *uncorrected*
//! closed form's coordinates — the common reference frame every host
//! shares — and the driver re-derives each wire candidate's estimate
//! with the same pure estimator the workers used, so the merged front is
//! bit-identical to the single-process sweep for any worker count and
//! any crash/reassignment history.  The calibration guard decides
//! *trust*, not membership: a shard whose fitted tau clears the floor
//! contributes its `ModelScales` to the consensus correction, while a
//! disagreeing shard's finalists are re-ranked through a DES replay
//! (ground-truth-first fold order) and its fit is quarantined.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::generator::calibrate::{replay_all, ModelScales};
use crate::generator::constraints::AppSpec;
use crate::generator::estimator::{estimate_cached, Estimate, EstimatorCache};
use crate::generator::eval::{EvalPool, Evaluator};
use crate::generator::search::exhaustive::Exhaustive;
use crate::generator::search::pareto::ParetoFront;
use crate::generator::search::Searcher;
use crate::util::rng::Rng;

use super::plan::plan_shards;
use super::wire::ShardSpec;
use super::worker::{run_shard, ShardResult};

/// How shards are executed.
#[derive(Debug, Clone)]
pub enum WorkerMode {
    /// Run shards inside this process (hermetic: tier-1 tests, benches).
    InProcess,
    /// Spawn `<exe> dse-worker` per shard — the production path.  Use
    /// `std::env::current_exe()` to shard across copies of the running
    /// binary.
    Subprocess(PathBuf),
}

/// Knobs for a distributed sweep.
#[derive(Debug, Clone)]
pub struct DistOpts {
    /// Shard / worker count.
    pub workers: usize,
    pub mode: WorkerMode,
    /// Global evaluation budget (the planner splits it per stripe so the
    /// union of shard prefixes equals the single-process prefix).
    pub budget: Option<usize>,
    /// Workload-trace seed shared by every shard's calibration replay.
    pub seed: u64,
    /// Replay trace length per finalist.
    pub requests: usize,
    /// Worker-local `EvalPool` width (keep at 1 when `workers` already
    /// saturates the host — shards are the parallelism axis here).
    pub threads: usize,
    /// Kendall-tau floor a shard's shipped agreement must clear for its
    /// fit to join the consensus; at or below it the shard counts as
    /// disagreeing and its finalists are DES-replayed before folding.
    pub tau_floor: f64,
    /// Wall-clock cap per subprocess attempt before the worker is
    /// killed and the shard retried/reassigned.
    pub timeout: Duration,
    /// Subprocess attempts per shard before in-process reassignment.
    pub attempts: usize,
}

impl Default for DistOpts {
    fn default() -> DistOpts {
        DistOpts {
            workers: 2,
            mode: WorkerMode::InProcess,
            budget: None,
            seed: 11,
            requests: 200,
            threads: 1,
            tau_floor: 0.0,
            timeout: Duration::from_secs(300),
            attempts: 2,
        }
    }
}

/// One shard's execution record inside a [`DistOutcome`].
#[derive(Debug)]
pub struct ShardRun {
    pub result: ShardResult,
    /// Worker attempts consumed (1 = first try succeeded; includes the
    /// in-process reassignment when every subprocess attempt failed).
    pub attempts: usize,
    /// True when the shard was reassigned to an in-process worker after
    /// its subprocess attempts failed or timed out.
    pub reassigned: bool,
    /// The last subprocess failure that forced the reassignment (spawn
    /// error, timeout, bad exit, undecodable output) — `None` unless
    /// `reassigned`.
    pub failure: Option<String>,
    /// True when the calibration guard tripped: the shard's finalists
    /// were re-ranked through a DES replay before folding and its fit
    /// was kept out of the consensus scales.
    pub reranked: bool,
}

/// Outcome of a distributed sweep.
#[derive(Debug)]
pub struct DistOutcome {
    pub spec: AppSpec,
    /// Merged streaming front, in the uncorrected closed form's
    /// coordinates — bit-identical to the single-process sweep.
    pub front: ParetoFront,
    /// Global best by the spec's goal (exact score ties broken by
    /// global enumeration index, matching the local sweep).
    pub best: Option<Estimate>,
    pub shards: Vec<ShardRun>,
    /// Estimator evaluations summed over all shards.
    pub evaluations: usize,
    /// Finalist-weighted mean of the trusted shards' fitted scales —
    /// the correction a downstream refinement sweep should use.
    pub consensus: ModelScales,
    /// Shards that needed in-process reassignment.
    pub reassigned: usize,
    /// Shards whose calibration guard tripped.
    pub reranked: usize,
    /// True when any shard hit its budget slice.
    pub budget_exhausted: bool,
}

/// The distributed sweep driver (see module docs).
pub struct DistSweep {
    opts: DistOpts,
}

impl DistSweep {
    pub fn new(opts: DistOpts) -> DistSweep {
        DistSweep { opts }
    }

    pub fn opts(&self) -> &DistOpts {
        &self.opts
    }

    /// Plan, execute (workers in parallel), merge.
    pub fn run(&self, spec: &AppSpec) -> anyhow::Result<DistOutcome> {
        let o = &self.opts;
        let plans = plan_shards(spec, o.workers, o.budget, o.seed, o.requests, o.threads);

        let executed: Vec<anyhow::Result<Executed>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = plans
                    .iter()
                    .map(|p| s.spawn(move || self.execute(p)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked"))
                    .collect()
            });

        // merge in shard order (membership is order-independent; the
        // order only fixes which duplicate-free sequence the streaming
        // front saw, for reproducible logs)
        let mut front = ParetoFront::new();
        let mut cache = EstimatorCache::new();
        let mut fits: Vec<(ModelScales, f64)> = Vec::new();
        let mut best: Option<(Estimate, usize)> = None;
        let mut shards: Vec<ShardRun> = Vec::with_capacity(plans.len());
        let mut evaluations = 0usize;
        let mut budget_exhausted = false;
        // the same shared trace the workers fitted against, for the
        // guard's own replays
        let arrivals = spec.workload.arrivals(o.requests, &mut Rng::new(o.seed));

        for (p, outcome) in plans.iter().zip(executed) {
            let (result, attempts, failure) =
                outcome.with_context(|| format!("shard {}/{}", p.shard, p.of))?;
            let reassigned = failure.is_some();
            anyhow::ensure!(
                result.app == spec.name && result.shard == p.shard && result.of == p.of,
                "worker answered for the wrong shard: {}/{} of '{}'",
                result.shard,
                result.of,
                result.app
            );

            // decode + deterministic re-estimation: the estimator is a
            // pure function of (spec, candidate), so re-deriving each
            // finalist locally reproduces the worker's exact numbers —
            // the wire carries candidates, not floats to trust
            let members: Vec<Estimate> = result
                .front
                .iter()
                .map(|c| estimate_cached(spec, c, &mut cache))
                .collect();

            let trusted = result.post.pairs < 2 || result.post.tau > o.tau_floor;
            if trusted {
                if !result.fell_back && !result.front.is_empty() {
                    fits.push((result.scales, result.front.len() as f64));
                }
                for e in &members {
                    front.insert(e);
                }
            } else {
                // calibration guard: this shard's estimator ranking
                // disagrees with the DES, so validate before folding —
                // replay its finalists (map_ordered under the hood) and
                // fold them ground-truth-first; its fit stays out of
                // the consensus
                let replays = replay_all(&members, &arrivals, o.threads.max(1));
                let mut order: Vec<usize> = (0..members.len()).collect();
                order.sort_by(|&a, &b| {
                    replays[a]
                        .sim_energy_per_item
                        .value()
                        .total_cmp(&replays[b].sim_energy_per_item.value())
                });
                for i in order {
                    front.insert(&members[i]);
                }
            }

            if let (Some(c), Some(idx)) = (&result.best, result.best_index) {
                let e = estimate_cached(spec, c, &mut cache);
                let better = match &best {
                    None => true,
                    Some((b, bi)) => {
                        let (sa, sb) = (e.score(spec.goal), b.score(spec.goal));
                        sa > sb || (sa == sb && idx < *bi)
                    }
                };
                if better {
                    best = Some((e, idx));
                }
            }

            evaluations += result.evaluations;
            budget_exhausted |= result.budget_exhausted;
            shards.push(ShardRun {
                reranked: !trusted,
                result,
                attempts,
                reassigned,
                failure,
            });
        }

        let consensus = ModelScales::weighted_mean(&fits);
        Ok(DistOutcome {
            spec: spec.clone(),
            front,
            best: best.map(|(e, _)| e),
            evaluations,
            consensus,
            reassigned: shards.iter().filter(|s| s.reassigned).count(),
            reranked: shards.iter().filter(|s| s.reranked).count(),
            budget_exhausted,
            shards,
        })
    }

    /// Run one shard under the configured mode, with retry + in-process
    /// reassignment for failed subprocess workers.  Returns
    /// `(result, attempts, last_failure)` — `last_failure` is `Some`
    /// exactly when the shard was reassigned in-process.
    fn execute(&self, plan: &ShardSpec) -> anyhow::Result<Executed> {
        match &self.opts.mode {
            WorkerMode::InProcess => run_shard(plan).map(|r| (r, 1, None)),
            WorkerMode::Subprocess(exe) => {
                let payload = plan.to_json().dump();
                let mut attempts = 0usize;
                let mut last_err = String::new();
                while attempts < self.opts.attempts.max(1) {
                    attempts += 1;
                    let decoded = spawn_worker(exe, &payload, self.opts.timeout)
                        .and_then(|out| ShardResult::from_json_str(&out));
                    match decoded {
                        Ok(r) => return Ok((r, attempts, None)),
                        Err(e) => last_err = format!("{e:#}"),
                    }
                }
                // every subprocess attempt crashed, hung or spoke
                // garbage: reassign the shard to an in-process worker so
                // the sweep completes with an unchanged merged front,
                // keeping the last failure as the reassignment cause
                run_shard(plan).map(|r| (r, attempts + 1, Some(last_err)))
            }
        }
    }
}

/// `execute`'s outcome: result, attempts, and — when the shard had to be
/// reassigned in-process — the last subprocess failure.
type Executed = (ShardResult, usize, Option<String>);

/// Spawn `<exe> dse-worker`, feed it the shard spec, enforce the wall
/// cap, and return its stdout.
fn spawn_worker(exe: &Path, payload: &str, timeout: Duration) -> anyhow::Result<String> {
    let mut child = Command::new(exe)
        .arg("dse-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning worker {}", exe.display()))?;

    // hand over the spec and close stdin so the worker sees EOF; a
    // worker that already died yields a broken pipe here, which the
    // exit-status check below reports as the real failure
    if let Some(mut sin) = child.stdin.take() {
        let _ = sin.write_all(payload.as_bytes());
    }

    // drain stdout on a helper thread so a large result cannot dead-lock
    // against a full pipe while we poll for exit
    let mut sout = child.stdout.take().expect("stdout was piped");
    let reader = std::thread::spawn(move || {
        let mut buf = String::new();
        let _ = sout.read_to_string(&mut buf);
        buf
    });

    let deadline = Instant::now() + timeout;
    let status = loop {
        match child.try_wait().context("polling worker")? {
            Some(status) => break status,
            None if Instant::now() >= deadline => {
                let _ = child.kill();
                let _ = child.wait();
                let _ = reader.join();
                anyhow::bail!("worker timed out after {timeout:?}");
            }
            None => std::thread::sleep(Duration::from_millis(5)),
        }
    };
    let out = reader
        .join()
        .map_err(|_| anyhow!("worker stdout reader panicked"))?;
    anyhow::ensure!(status.success(), "worker exited with {status}");
    Ok(out)
}

/// The single-process reference sweep with identical budget semantics —
/// what `generate` produces locally.  Returns the streaming front, the
/// best estimate, and the evaluation count.
pub fn single_process_reference(
    spec: &AppSpec,
    budget: Option<usize>,
    threads: usize,
) -> (ParetoFront, Option<Estimate>, usize) {
    let space = crate::generator::design_space::enumerate(&spec.device_allowlist);
    let mut pool = EvalPool::new(threads.max(1));
    if let Some(b) = budget {
        pool = pool.with_budget(b);
    }
    let r = Exhaustive.search_with(spec, &space, &mut pool);
    let evaluations = pool.evaluations();
    (pool.take_front(), r.best, evaluations)
}

/// Bit-identity check between a reference front and a merged one: same
/// membership by describe key, bit-equal objective vectors per member.
pub fn assert_front_parity(reference: &ParetoFront, merged: &ParetoFront) -> anyhow::Result<()> {
    let key = |e: &Estimate| {
        (
            e.candidate.describe(),
            e.energy_per_item.value().to_bits(),
            e.response_latency.value().to_bits(),
            e.utilization.to_bits(),
        )
    };
    let mut a: Vec<_> = reference.iter().map(key).collect();
    let mut b: Vec<_> = merged.iter().map(key).collect();
    a.sort();
    b.sort();
    anyhow::ensure!(
        a.len() == b.len(),
        "front size differs: reference {} vs merged {}",
        a.len(),
        b.len()
    );
    for (x, y) in a.iter().zip(&b) {
        anyhow::ensure!(
            x == y,
            "front member differs: reference '{}' vs merged '{}'",
            x.0,
            y.0
        );
    }
    Ok(())
}
