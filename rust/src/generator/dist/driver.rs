//! [`DistSweep`]: the distributed-DSE driver.  Plans shards, runs them
//! on N workers (subprocesses speaking the stdin/stdout JSON protocol,
//! or in-process for hermetic tests and benches), reassigns
//! crashed/timed-out shards, and performs the calibration-guarded merge
//! into one streaming [`ParetoFront`].
//!
//! Two phases share the worker fleet:
//!
//! * **sweep** ([`DistSweep::run`]) — the exploration pass.  Dominance is
//!   evaluated in the *uncorrected* closed form's coordinates — the
//!   common reference frame every host shares — and the driver
//!   re-derives each wire candidate's estimate with the same pure
//!   estimator the workers used, so the merged front is bit-identical to
//!   the single-process sweep for any worker count and any
//!   crash/reassignment history.  The calibration guard decides *trust*,
//!   not membership: a shard whose fitted tau clears the floor
//!   contributes its `ModelScales` to the consensus correction, while a
//!   disagreeing shard's finalists are re-ranked through a DES replay
//!   (ground-truth-first fold order) and its fit is quarantined.
//! * **refinement** ([`DistSweep::run_refine`]) — the correction pass.
//!   The space is re-sharded with the corrected constants riding on each
//!   [`ShardSpec`]; workers re-rank their stripes through a
//!   `CalibratedEstimator`, and the driver merges in the *corrected*
//!   closed form's coordinates (exact score ties broken by global
//!   enumeration index), so the refined front/best are bit-identical to
//!   the single-process `refine_with` under the same scales — again for
//!   any worker count, crashes included.
//!
//! [`DistSweep::run_calibrated`] chains them into the full distributed
//! estimator↔simulator loop: sweep → driver-side fit on the *merged*
//! front (the same finalist set the single-process `calibrate_finalists`
//! sees, so the fitted scales are bit-identical to the local loop's) →
//! distributed refinement under those scales.  The per-shard consensus
//! (`DistOutcome::consensus`) remains the cheap cross-host trust signal;
//! the merged-front fit is the canonical correction, because bit-parity
//! with `calibrate_and_refine` demands the exact least-squares system
//! the single process solves.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::generator::calibrate::{
    calibrate_finalists, replay_all, CalibrateOpts, Calibration, ModelScales,
};
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::Candidate;
use crate::generator::estimator::{estimate_cached, Estimate, EstimatorCache};
use crate::generator::eval::{EvalPool, Evaluator};
use crate::generator::search::exhaustive::Exhaustive;
use crate::generator::search::pareto::ParetoFront;
use crate::generator::search::Searcher;
use crate::obs::{Event, Journal, WorkerEvent};
use crate::util::rng::Rng;

use super::plan::plan_shards;
use super::wire::ShardSpec;
use super::worker::{run_shard, ShardResult};

/// How shards are executed.
#[derive(Debug, Clone)]
pub enum WorkerMode {
    /// Run shards inside this process (hermetic: tier-1 tests, benches).
    InProcess,
    /// Spawn `<exe> dse-worker` per shard — the production path.  Use
    /// `std::env::current_exe()` to shard across copies of the running
    /// binary.
    Subprocess(PathBuf),
}

/// Knobs for a distributed sweep.
#[derive(Debug, Clone)]
pub struct DistOpts {
    /// Shard / worker count.
    pub workers: usize,
    pub mode: WorkerMode,
    /// Global evaluation budget (the planner splits it per stripe so the
    /// union of shard prefixes equals the single-process prefix).
    pub budget: Option<usize>,
    /// Workload-trace seed shared by every shard's calibration replay.
    pub seed: u64,
    /// Replay trace length per finalist.
    pub requests: usize,
    /// Worker-local `EvalPool` width (keep at 1 when `workers` already
    /// saturates the host — shards are the parallelism axis here).
    pub threads: usize,
    /// Kendall-tau floor a shard's shipped agreement must clear for its
    /// fit to join the consensus; at or below it the shard counts as
    /// disagreeing and its finalists are DES-replayed before folding.
    /// The same floor guards both phases — a refinement shard sitting at
    /// or below it is folded ground-truth-first too.
    pub tau_floor: f64,
    /// Wall-clock cap per subprocess attempt before the worker is
    /// killed and the shard retried/reassigned.
    pub timeout: Duration,
    /// Subprocess attempts per shard before in-process reassignment.
    pub attempts: usize,
    /// Event journal worker-lifecycle events are emitted into
    /// (spawn/exit/timeout/reassign/quarantine).  Timestamps are stamped
    /// by the journal itself, so this parity-scoped driver never reads a
    /// wall clock for observability.
    pub journal: Option<Arc<Journal>>,
}

impl Default for DistOpts {
    fn default() -> DistOpts {
        DistOpts {
            workers: 2,
            mode: WorkerMode::InProcess,
            budget: None,
            seed: 11,
            requests: 200,
            threads: 1,
            tau_floor: 0.0,
            timeout: Duration::from_secs(300),
            attempts: 2,
            journal: None,
        }
    }
}

/// One shard's execution record inside a [`DistOutcome`].
#[derive(Debug)]
pub struct ShardRun {
    pub result: ShardResult,
    /// Worker attempts consumed (1 = first try succeeded; includes the
    /// in-process reassignment when every subprocess attempt failed).
    pub attempts: usize,
    /// True when the shard was reassigned to an in-process worker after
    /// its subprocess attempts failed or timed out.
    pub reassigned: bool,
    /// The last subprocess failure that forced the reassignment (spawn
    /// error, timeout, bad exit, undecodable output) — `None` unless
    /// `reassigned`.
    pub failure: Option<String>,
    /// True when the calibration guard tripped: the shard's finalists
    /// were re-ranked through a DES replay before folding and its fit
    /// was kept out of the consensus scales.
    pub reranked: bool,
}

/// Outcome of a distributed sweep.
#[derive(Debug)]
pub struct DistOutcome {
    pub spec: AppSpec,
    /// Merged streaming front, in the uncorrected closed form's
    /// coordinates — bit-identical to the single-process sweep.
    pub front: ParetoFront,
    /// Global best by the spec's goal (exact score ties broken by
    /// global enumeration index, matching the local sweep).
    pub best: Option<Estimate>,
    pub shards: Vec<ShardRun>,
    /// Estimator evaluations summed over all shards.
    pub evaluations: usize,
    /// Finalist-weighted mean of the trusted shards' fitted scales — the
    /// cross-host trust signal.  The canonical correction a refinement
    /// uses is the driver-side fit on the merged front
    /// ([`DistSweep::run_calibrated`]), which is bit-identical to the
    /// single-process fit; this consensus is what the merge guard
    /// produced from per-shard fits alone.
    pub consensus: ModelScales,
    /// Shards that needed in-process reassignment.
    pub reassigned: usize,
    /// Shards whose calibration guard tripped.
    pub reranked: usize,
    /// True when any shard hit its budget slice.
    pub budget_exhausted: bool,
}

/// Outcome of the distributed refinement phase: the merged re-ranking of
/// the space in the *corrected* closed form's coordinates.
#[derive(Debug)]
pub struct RefineOutcome {
    /// The corrected constants every worker (and the driver's local
    /// re-estimation) applied.
    pub scales: ModelScales,
    /// Merged refinement front in corrected coordinates — bit-identical
    /// to the single-process `refine_with` front under the same scales.
    pub front: ParetoFront,
    /// Best corrected estimate by the spec's goal (exact score ties
    /// broken by global enumeration index).
    pub best: Option<Estimate>,
    pub shards: Vec<ShardRun>,
    /// Estimator evaluations the refinement paid across all shards
    /// (fresh worker pools cannot reuse the sweep memo across process
    /// boundaries, so a distributed refinement re-pays the stripe
    /// estimates the single-process pipeline served from its memo).
    pub evaluations: usize,
    pub reassigned: usize,
    /// Shards whose corrected-model agreement sat at or below the tau
    /// floor and were folded ground-truth-first.
    pub reranked: usize,
    pub budget_exhausted: bool,
}

/// The full distributed estimator↔simulator loop:
/// sweep → driver-side fit on the merged front → distributed refinement.
#[derive(Debug)]
pub struct DistCalOutcome {
    pub sweep: DistOutcome,
    /// Fitted on the merged front's finalists — the same least-squares
    /// system the single-process `calibrate_finalists` solves, so
    /// scales/agreement/fallback are bit-identical to the local loop.
    pub calibration: Calibration,
    pub refined: RefineOutcome,
}

/// What a shared merge pass produces before phase-specific packaging.
struct Merged {
    front: ParetoFront,
    best: Option<(Estimate, usize)>,
    shards: Vec<ShardRun>,
    evaluations: usize,
    budget_exhausted: bool,
    /// Trusted shards' (scales, finalist-count) fits — empty on the
    /// refinement phase, which never folds a consensus.
    fits: Vec<(ModelScales, f64)>,
}

/// The distributed sweep driver (see module docs).
pub struct DistSweep {
    opts: DistOpts,
}

impl DistSweep {
    pub fn new(opts: DistOpts) -> DistSweep {
        DistSweep { opts }
    }

    pub fn opts(&self) -> &DistOpts {
        &self.opts
    }

    /// Emit one worker-lifecycle event when a journal is attached.
    fn note(&self, kind: &str, shard: usize, attempt: Option<usize>, detail: Option<String>) {
        if let Some(j) = &self.opts.journal {
            let mut e = WorkerEvent::new(kind, shard);
            e.attempt = attempt;
            e.detail = detail;
            j.record(Event::Worker(e));
        }
    }

    /// Plan, execute (workers in parallel), merge — the sweep phase.
    pub fn run(&self, spec: &AppSpec) -> anyhow::Result<DistOutcome> {
        let o = &self.opts;
        let plans = plan_shards(spec, o.workers, o.budget, o.seed, o.requests, o.threads, None);
        let executed = self.execute_all(&plans);
        let m = self.merge_shards(spec, &plans, executed, None)?;
        let consensus = ModelScales::weighted_mean(&m.fits);
        Ok(DistOutcome {
            spec: spec.clone(),
            front: m.front,
            best: m.best.map(|(e, _)| e),
            evaluations: m.evaluations,
            consensus,
            reassigned: m.shards.iter().filter(|s| s.reassigned).count(),
            reranked: m.shards.iter().filter(|s| s.reranked).count(),
            budget_exhausted: m.budget_exhausted,
            shards: m.shards,
        })
    }

    /// The refinement phase: re-shard the space with `scales` riding on
    /// each spec, re-rank every stripe through a calibrated estimator on
    /// the same worker fleet (same crash/timeout reassignment), and
    /// merge in the corrected closed form's coordinates.
    pub fn run_refine(&self, spec: &AppSpec, scales: ModelScales) -> anyhow::Result<RefineOutcome> {
        let o = &self.opts;
        let plans = plan_shards(
            spec,
            o.workers,
            o.budget,
            o.seed,
            o.requests,
            o.threads,
            Some(scales),
        );
        let executed = self.execute_all(&plans);
        let m = self.merge_shards(spec, &plans, executed, Some(scales))?;
        Ok(RefineOutcome {
            scales,
            front: m.front,
            best: m.best.map(|(e, _)| e),
            evaluations: m.evaluations,
            reassigned: m.shards.iter().filter(|s| s.reassigned).count(),
            reranked: m.shards.iter().filter(|s| s.reranked).count(),
            budget_exhausted: m.budget_exhausted,
            shards: m.shards,
        })
    }

    /// The full distributed estimator↔simulator loop.  The calibration
    /// is fitted by the driver on the *merged* front — the identical
    /// finalist set the single-process `calibrate_finalists` sees — so
    /// the scales, agreement and fallback decision are bit-identical to
    /// `calibrate_and_refine` with the same seed/requests/budget, and
    /// the refinement that follows inherits that parity.
    pub fn run_calibrated(&self, spec: &AppSpec) -> anyhow::Result<DistCalOutcome> {
        let o = &self.opts;
        let sweep = self.run(spec)?;
        let opts = CalibrateOpts {
            threads: o.threads.max(1),
            requests: o.requests,
            seed: o.seed,
            budget: None,
        };
        let finalists: Vec<Estimate> = sweep.front.iter().cloned().collect();
        let mut cal = calibrate_finalists(spec, finalists, &opts);
        cal.sweep_best = sweep.best.clone();
        let refined = self.run_refine(spec, cal.scales)?;
        Ok(DistCalOutcome { sweep, calibration: cal, refined })
    }

    /// Execute every planned shard on its own thread (subprocess workers
    /// run concurrently; in-process workers use the thread directly).
    fn execute_all(&self, plans: &[ShardSpec]) -> Vec<anyhow::Result<Executed>> {
        std::thread::scope(|s| {
            let handles: Vec<_> = plans
                .iter()
                .map(|p| s.spawn(move || self.execute(p)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("shard thread panicked")))
                })
                .collect()
        })
    }

    /// The shared merge pass.  `correction: None` merges in the
    /// uncorrected coordinates (sweep phase, consensus fits collected);
    /// `Some(scales)` re-derives every wire candidate in the corrected
    /// coordinates (refinement phase).  Either way membership is
    /// fold-order independent and exact best-score ties break by global
    /// enumeration index, which is what makes the merge bit-identical to
    /// the corresponding single-process pass.
    fn merge_shards(
        &self,
        spec: &AppSpec,
        plans: &[ShardSpec],
        executed: Vec<anyhow::Result<Executed>>,
        correction: Option<ModelScales>,
    ) -> anyhow::Result<Merged> {
        let o = &self.opts;
        let mut front = ParetoFront::new();
        let mut cache = EstimatorCache::new();
        let mut fits: Vec<(ModelScales, f64)> = Vec::new();
        let mut best: Option<(Estimate, usize)> = None;
        let mut shards: Vec<ShardRun> = Vec::with_capacity(plans.len());
        let mut evaluations = 0usize;
        let mut budget_exhausted = false;
        // the same shared trace the workers fitted against, for the
        // guard's own replays
        let arrivals = spec.workload.arrivals(o.requests, &mut Rng::new(o.seed));
        let derive = |c: &Candidate, cache: &mut EstimatorCache| {
            let e = estimate_cached(spec, c, cache);
            match &correction {
                Some(s) => s.correct_estimate(spec, e),
                None => e,
            }
        };

        for (p, outcome) in plans.iter().zip(executed) {
            let (result, attempts, failure) =
                outcome.with_context(|| format!("shard {}/{}", p.shard, p.of))?;
            let reassigned = failure.is_some();
            anyhow::ensure!(
                result.app == spec.name && result.shard == p.shard && result.of == p.of,
                "worker answered for the wrong shard: {}/{} of '{}'",
                result.shard,
                result.of,
                result.app
            );
            // a refinement worker echoes the correction it applied; a
            // worker that ignored the shipped scales (version skew — an
            // old binary decodes the spec but drops the unknown field
            // and runs the sweep phase) must not fold sweep-phase
            // results into the refined front
            if let Some(s) = &correction {
                anyhow::ensure!(
                    result.scales.to_bits() == s.to_bits(),
                    "refinement shard {}/{} did not apply the shipped correction \
                     (echoed {:?}, want {:?}) — version-skewed worker?",
                    result.shard,
                    result.of,
                    result.scales,
                    s
                );
            }

            // decode + deterministic re-estimation: the estimator is a
            // pure function of (spec, candidate) — and the correction a
            // pure function of (scales, estimate) — so re-deriving each
            // finalist locally reproduces the worker's exact numbers;
            // the wire carries candidates, not floats to trust
            let members: Vec<Estimate> = result
                .front
                .iter()
                .map(|c| derive(c, &mut cache))
                .collect();

            let trusted = result.post.pairs < 2 || result.post.tau > o.tau_floor;
            if trusted {
                if correction.is_none() && !result.fell_back && !result.front.is_empty() {
                    fits.push((result.scales, result.front.len() as f64));
                }
                for e in &members {
                    front.insert(e);
                }
            } else {
                self.note(
                    "quarantine",
                    p.shard,
                    None,
                    Some(format!(
                        "tau {:.3} <= floor {:.3} over {} pairs",
                        result.post.tau, o.tau_floor, result.post.pairs
                    )),
                );
                // calibration guard: this shard's ranking (uncorrected
                // model on the sweep, corrected model on the refinement)
                // disagrees with the DES, so validate before folding —
                // replay its finalists (map_ordered under the hood) and
                // fold them ground-truth-first; a sweep shard's fit
                // stays out of the consensus
                let replays = replay_all(&members, &arrivals, o.threads.max(1));
                let mut ranked: Vec<(&Estimate, f64)> = members
                    .iter()
                    .zip(&replays)
                    .map(|(e, r)| (e, r.sim_energy_per_item.value()))
                    .collect();
                ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
                for (e, _) in ranked {
                    front.insert(e);
                }
            }

            if let (Some(c), Some(idx)) = (&result.best, result.best_index) {
                let e = derive(c, &mut cache);
                let better = match &best {
                    None => true,
                    Some((b, bi)) => {
                        let (sa, sb) = (e.score(spec.goal), b.score(spec.goal));
                        sa > sb || (sa == sb && idx < *bi)
                    }
                };
                if better {
                    best = Some((e, idx));
                }
            }

            evaluations += result.evaluations;
            budget_exhausted |= result.budget_exhausted;
            shards.push(ShardRun {
                reranked: !trusted,
                result,
                attempts,
                reassigned,
                failure,
            });
        }

        Ok(Merged {
            front,
            best,
            shards,
            evaluations,
            budget_exhausted,
            fits,
        })
    }

    /// Run one shard under the configured mode, with retry + in-process
    /// reassignment for failed subprocess workers.  Returns
    /// `(result, attempts, last_failure)` — `last_failure` is `Some`
    /// exactly when the shard was reassigned in-process.
    fn execute(&self, plan: &ShardSpec) -> anyhow::Result<Executed> {
        match &self.opts.mode {
            WorkerMode::InProcess => {
                self.note("spawn", plan.shard, Some(1), None);
                let r = run_shard(plan).map(|r| (r, 1, None));
                self.note("exit", plan.shard, Some(1), r.as_ref().err().map(|e| format!("{e:#}")));
                r
            }
            WorkerMode::Subprocess(exe) => {
                let payload = plan.to_json().dump();
                let mut attempts = 0usize;
                let mut last_err = String::new();
                while attempts < self.opts.attempts.max(1) {
                    attempts += 1;
                    self.note("spawn", plan.shard, Some(attempts), None);
                    let decoded = spawn_worker(exe, &payload, self.opts.timeout)
                        .and_then(|out| ShardResult::from_json_str(&out))
                        .and_then(|r| {
                            // a refinement worker echoes the correction it
                            // applied; an old binary that dropped the
                            // unknown scales field ran the sweep phase
                            // instead — treat it like any other bad
                            // worker so the shard is retried/reassigned
                            if let Some(s) = &plan.scales {
                                anyhow::ensure!(
                                    r.scales.to_bits() == s.to_bits(),
                                    "worker did not apply the shipped correction (version skew?)"
                                );
                            }
                            Ok(r)
                        });
                    match decoded {
                        Ok(r) => {
                            self.note("exit", plan.shard, Some(attempts), None);
                            return Ok((r, attempts, None));
                        }
                        Err(e) => {
                            last_err = format!("{e:#}");
                            let kind = if last_err.contains("timed out") { "timeout" } else { "exit" };
                            self.note(kind, plan.shard, Some(attempts), Some(last_err.clone()));
                        }
                    }
                }
                // every subprocess attempt crashed, hung or spoke
                // garbage: reassign the shard to an in-process worker so
                // the sweep completes with an unchanged merged front,
                // keeping the last failure as the reassignment cause
                self.note("reassign", plan.shard, Some(attempts + 1), Some(last_err.clone()));
                run_shard(plan).map(|r| (r, attempts + 1, Some(last_err)))
            }
        }
    }
}

/// `execute`'s outcome: result, attempts, and — when the shard had to be
/// reassigned in-process — the last subprocess failure.
type Executed = (ShardResult, usize, Option<String>);

/// Spawn `<exe> dse-worker`, feed it the shard spec, enforce the wall
/// cap, and return its stdout.
fn spawn_worker(exe: &Path, payload: &str, timeout: Duration) -> anyhow::Result<String> {
    let mut child = Command::new(exe)
        .arg("dse-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning worker {}", exe.display()))?;

    // hand over the spec on a helper thread so the deadline below covers
    // the write too: a worker that never reads stdin plus a payload
    // larger than the OS pipe buffer would otherwise block write_all on
    // this thread forever, before the timeout loop ever started.  The
    // thread closes stdin on drop (EOF for the worker); a worker that
    // already died yields a broken pipe, which the exit-status check
    // below reports as the real failure.
    let writer = child.stdin.take().map(|mut sin| {
        let payload = payload.to_owned();
        std::thread::spawn(move || {
            let _ = sin.write_all(payload.as_bytes());
        })
    });

    // drain stdout on a helper thread so a large result cannot dead-lock
    // against a full pipe while we poll for exit
    let mut sout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow!("worker stdout pipe missing"))?;
    let reader = std::thread::spawn(move || {
        let mut buf = String::new();
        let _ = sout.read_to_string(&mut buf);
        buf
    });

    // lint: allow(det-wall-clock) — subprocess liveness deadline only; a timed-out shard is retried/reassigned, its clock never reaches merged results
    let deadline = Instant::now() + timeout;
    // exit-poll backoff: short shards (the common case at small budgets)
    // return within a millisecond, so start near-instant and double up
    // to the old fixed 5 ms cap for the long tail
    let mut poll = Duration::from_micros(200);
    const POLL_CAP: Duration = Duration::from_millis(5);
    let status = loop {
        match child.try_wait().context("polling worker")? {
            Some(status) => break status,
            // lint: allow(det-wall-clock) — polls the same liveness deadline; merge output is independent of when the timeout fires
            None if Instant::now() >= deadline => {
                // killing the child closes its pipe ends, unblocking
                // both helper threads
                let _ = child.kill();
                let _ = child.wait();
                if let Some(w) = writer {
                    let _ = w.join();
                }
                let _ = reader.join();
                anyhow::bail!("worker timed out after {timeout:?}");
            }
            None => {
                std::thread::sleep(poll);
                poll = (poll * 2).min(POLL_CAP);
            }
        }
    };
    if let Some(w) = writer {
        let _ = w.join();
    }
    let out = reader
        .join()
        .map_err(|_| anyhow!("worker stdout reader panicked"))?;
    anyhow::ensure!(status.success(), "worker exited with {status}");
    Ok(out)
}

/// The single-process reference sweep with identical budget semantics —
/// what `generate` produces locally.  Returns the streaming front, the
/// best estimate, and the evaluation count.
pub fn single_process_reference(
    spec: &AppSpec,
    budget: Option<usize>,
    threads: usize,
) -> (ParetoFront, Option<Estimate>, usize) {
    let space = crate::generator::design_space::enumerate(&spec.device_allowlist);
    let mut pool = EvalPool::new(threads.max(1));
    if let Some(b) = budget {
        pool = pool.with_budget(b);
    }
    let r = Exhaustive.search_with(spec, &space, &mut pool);
    let evaluations = pool.evaluations();
    (pool.take_front(), r.best, evaluations)
}

/// Bit-identity check between a reference front and a merged one: same
/// membership by describe key, bit-equal objective vectors per member.
/// Works for both phases — corrected fronts compare against corrected
/// references.
pub fn assert_front_parity(reference: &ParetoFront, merged: &ParetoFront) -> anyhow::Result<()> {
    let key = |e: &Estimate| {
        (
            e.candidate.describe(),
            e.energy_per_item.value().to_bits(),
            e.response_latency.value().to_bits(),
            e.utilization.to_bits(),
        )
    };
    let mut a: Vec<_> = reference.iter().map(key).collect();
    let mut b: Vec<_> = merged.iter().map(key).collect();
    a.sort();
    b.sort();
    anyhow::ensure!(
        a.len() == b.len(),
        "front size differs: reference {} vs merged {}",
        a.len(),
        b.len()
    );
    for (x, y) in a.iter().zip(&b) {
        anyhow::ensure!(
            x == y,
            "front member differs: reference '{}' vs merged '{}'",
            x.0,
            y.0
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression: a worker that never reads stdin combined with a
    /// payload larger than the OS pipe buffer used to block the driver
    /// thread inside `write_all` *before* the timeout poll loop started,
    /// hanging the sweep forever.  The stdin hand-over now runs on a
    /// helper thread covered by the same deadline.
    #[test]
    #[cfg(unix)]
    fn oversized_payload_to_a_stuck_worker_times_out() {
        use std::os::unix::fs::PermissionsExt;
        let script = std::env::temp_dir()
            .join(format!("elastic-gen-stuck-worker-{}.sh", std::process::id()));
        {
            let mut f = std::fs::File::create(&script).unwrap();
            // sleeps without ever reading stdin
            f.write_all(b"#!/bin/sh\nsleep 30\n").unwrap();
        }
        std::fs::set_permissions(&script, std::fs::Permissions::from_mode(0o755)).unwrap();
        // far larger than any OS pipe buffer (Linux default is 64 KiB)
        let payload = "x".repeat(1 << 20);
        let t0 = Instant::now();
        let err = spawn_worker(&script, &payload, Duration::from_millis(400))
            .expect_err("a stuck worker must time out, not hang the driver");
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "driver blocked on the stdin write for {:?}",
            t0.elapsed()
        );
        let _ = std::fs::remove_file(&script);
    }
}
