//! The distributed-DSE wire format: JSON shard specs and shard results
//! over stdin/stdout (`util::json` — serde is not in the vendored crate
//! set).
//!
//! Candidates cross the wire as their axis fields plus a `key` field
//! holding `Candidate::describe()`.  The decoder re-materialises the
//! candidate from the fields and re-derives the key; a mismatch (corrupt
//! payload, schema drift, a worker built from different axis tables)
//! rejects the candidate instead of silently folding a wrong design
//! point into a Pareto front.  Everything else on the wire is scalars,
//! so a result decoded on any host is bit-identical to the worker's —
//! the JSON writer prints f64s in shortest-round-trip form.

use anyhow::{anyhow, Context};

use crate::fpga::device;
use crate::generator::calibrate::{ModelScales, RankAgreement};
use crate::generator::design_space::{sigmoid_variants, tanh_variants, Candidate, StrategyKind};
use crate::rtl::activation::{ActImpl, ActKind, ActVariant};
use crate::rtl::fixed_point::QFormat;
use crate::util::json::{parse, Json};

use super::worker::ShardResult;

/// Schema tags so a driver can reject a worker speaking another version.
pub const SPEC_SCHEMA: &str = "elastic-gen/dse-shard-spec/v1";
pub const RESULT_SCHEMA: &str = "elastic-gen/dse-shard-result/v1";

/// One shard's work order: which stripe of which scenario's enumeration,
/// under what budget, and how the shard-local calibration replay is
/// parameterised.  This is what `elastic-gen dse-worker` reads on stdin.
///
/// `scales` selects the phase: `None` is a calibration-sweep shard
/// (stripe sweep + shard-local fit), `Some` is a *refinement* shard —
/// the worker re-ranks its stripe through a `CalibratedEstimator`
/// carrying exactly these corrected constants, so every worker (and the
/// driver's local re-estimation) shares one corrected coordinate frame.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    /// Scenario name (`AppSpec::scenarios()` entry).
    pub app: String,
    /// Stripe index in `0..of`.
    pub shard: usize,
    /// Total shard count.
    pub of: usize,
    /// Shard-local evaluation budget (already split by the planner).
    pub budget: Option<usize>,
    /// Workload-trace seed for the shard-local calibration replay (the
    /// driver hands every shard the same seed).
    pub seed: u64,
    /// Replay trace length per finalist.
    pub requests: usize,
    /// Worker-local `EvalPool` width.
    pub threads: usize,
    /// Corrected constants for a refinement shard; `None` on the plain
    /// calibration sweep.  Absent on the wire when `None`, so v1 specs
    /// round-trip unchanged.
    pub scales: Option<ModelScales>,
}

// -- field accessors ---------------------------------------------------------

fn num(j: &Json, k: &str) -> anyhow::Result<f64> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{k}'"))
}

fn uint(j: &Json, k: &str) -> anyhow::Result<usize> {
    let x = num(j, k)?;
    anyhow::ensure!(x >= 0.0 && x.fract() == 0.0, "field '{k}' is not a whole number: {x}");
    Ok(x as usize)
}

fn string<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a str> {
    j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing or non-string field '{k}'"))
}

fn boolean(j: &Json, k: &str) -> anyhow::Result<bool> {
    j.get(k)
        .and_then(|v| v.as_bool())
        .ok_or_else(|| anyhow!("missing or non-bool field '{k}'"))
}

fn check_schema(j: &Json, want: &str) -> anyhow::Result<()> {
    let got = string(j, "schema")?;
    anyhow::ensure!(got == want, "schema mismatch: got '{got}', want '{want}'");
    Ok(())
}

// -- candidate codec ---------------------------------------------------------

fn act_kind_name(k: ActKind) -> &'static str {
    match k {
        ActKind::Sigmoid => "sigmoid",
        ActKind::Tanh => "tanh",
        ActKind::HardSigmoid => "hardsigmoid",
        ActKind::HardTanh => "hardtanh",
    }
}

fn act_impl_name(i: ActImpl) -> &'static str {
    match i {
        ActImpl::Exact => "exact",
        ActImpl::Pla => "pla",
        ActImpl::Lut => "lut",
        ActImpl::Hard => "hard",
    }
}

fn encode_act(v: ActVariant) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(act_kind_name(v.kind).to_string())),
        ("impl", Json::Str(act_impl_name(v.imp).to_string())),
    ])
}

fn decode_act(j: &Json, field: &str) -> anyhow::Result<ActVariant> {
    let obj = j.get(field).ok_or_else(|| anyhow!("missing field '{field}'"))?;
    let kind = string(obj, "kind")?;
    let imp = string(obj, "impl")?;
    ActVariant::parse(kind, imp)
        .ok_or_else(|| anyhow!("unknown activation variant {kind}/{imp} in '{field}'"))
}

/// Encode a candidate host-portably: axis fields plus the describe key.
pub fn encode_candidate(c: &Candidate) -> Json {
    Json::obj(vec![
        ("key", Json::Str(c.describe())),
        ("device", Json::Str(c.device.name.to_string())),
        ("fmt", Json::Str(c.fmt.name())),
        ("sigmoid", encode_act(c.sigmoid)),
        ("tanh", encode_act(c.tanh)),
        ("alus", Json::Num(c.alus as f64)),
        ("pipelined", Json::Bool(c.pipelined)),
        ("clock_mhz", Json::Num(c.clock_mhz)),
        ("strategy", Json::Str(c.strategy.name().to_string())),
    ])
}

/// Decode a candidate and verify its describe key round-trips.
pub fn decode_candidate(j: &Json) -> anyhow::Result<Candidate> {
    let key = string(j, "key")?;
    let dev_name = string(j, "device")?;
    let dev = device(dev_name).ok_or_else(|| anyhow!("unknown device '{dev_name}'"))?;
    let fmt_name = string(j, "fmt")?;
    let fmt = QFormat::parse(fmt_name).ok_or_else(|| anyhow!("bad format '{fmt_name}'"))?;
    let strat_name = string(j, "strategy")?;
    let strategy = StrategyKind::parse(strat_name)
        .ok_or_else(|| anyhow!("unknown strategy '{strat_name}'"))?;
    let c = Candidate {
        device: dev,
        fmt,
        sigmoid: decode_act(j, "sigmoid")?,
        tanh: decode_act(j, "tanh")?,
        alus: uint(j, "alus")? as u32,
        pipelined: boolean(j, "pipelined")?,
        clock_mhz: num(j, "clock_mhz")?,
        strategy,
    };
    anyhow::ensure!(
        c.describe() == key,
        "candidate key mismatch: wire '{key}' decodes to '{}'",
        c.describe()
    );
    // the describe key covers every axis except the activation *kinds*
    // (it prints only the impls), so pin the pair against the tied
    // activation axis — a tampered kind with a valid impl must not fold
    // an out-of-design-space candidate into a front
    let pair_in_axes = sigmoid_variants()
        .into_iter()
        .zip(tanh_variants())
        .any(|(s, t)| s == c.sigmoid && t == c.tanh);
    anyhow::ensure!(
        pair_in_axes,
        "activation pair {:?}/{:?} + {:?}/{:?} is not a design-axis pair",
        c.sigmoid.kind,
        c.sigmoid.imp,
        c.tanh.kind,
        c.tanh.imp
    );
    Ok(c)
}

// -- scales / agreement codec ------------------------------------------------

pub fn encode_scales(s: &ModelScales) -> Json {
    Json::obj(vec![
        ("busy", Json::Num(s.busy)),
        ("idle", Json::Num(s.idle)),
        ("off", Json::Num(s.off)),
        ("cold", Json::Num(s.cold)),
    ])
}

/// Decode fitted scales.  A component that arrives null/absent/non-finite
/// degrades to the identity multiplier — the same fallback the
/// calibration guard uses — so a worker whose fit produced a non-finite
/// theta (serialized as null by the JSON writer) cannot poison a merge.
pub fn decode_scales(j: &Json) -> ModelScales {
    let get = |k: &str| {
        j.get(k)
            .and_then(|v| v.as_f64())
            .filter(|x| x.is_finite())
            .unwrap_or(1.0)
    };
    ModelScales {
        busy: get("busy"),
        idle: get("idle"),
        off: get("off"),
        cold: get("cold"),
    }
}

pub fn encode_agreement(a: &RankAgreement) -> Json {
    Json::obj(vec![
        ("tau", Json::Num(a.tau)),
        ("crossovers", Json::Num(a.crossovers as f64)),
        ("pairs", Json::Num(a.pairs as f64)),
    ])
}

pub fn decode_agreement(j: &Json, field: &str) -> anyhow::Result<RankAgreement> {
    let obj = j.get(field).ok_or_else(|| anyhow!("missing field '{field}'"))?;
    Ok(RankAgreement {
        tau: num(obj, "tau")?,
        crossovers: uint(obj, "crossovers")?,
        pairs: uint(obj, "pairs")?,
    })
}

// -- shard spec --------------------------------------------------------------

impl ShardSpec {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema", Json::Str(SPEC_SCHEMA.to_string())),
            ("app", Json::Str(self.app.clone())),
            ("shard", Json::Num(self.shard as f64)),
            ("of", Json::Num(self.of as f64)),
            (
                "budget",
                match self.budget {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            // strings, not f64: every u64 seed must cross exactly (an
            // f64 would silently round seeds at or above 2^53)
            ("seed", Json::Str(self.seed.to_string())),
            ("requests", Json::Num(self.requests as f64)),
            ("threads", Json::Num(self.threads as f64)),
        ];
        if let Some(s) = &self.scales {
            fields.push(("scales", encode_scales(s)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ShardSpec> {
        check_schema(j, SPEC_SCHEMA)?;
        let budget = match j.get("budget") {
            None | Some(Json::Null) => None,
            Some(_) => Some(uint(j, "budget")?),
        };
        let seed_text = string(j, "seed")?;
        let seed = seed_text
            .parse::<u64>()
            .map_err(|_| anyhow!("bad seed '{seed_text}'"))?;
        let scales = match j.get("scales") {
            None | Some(Json::Null) => None,
            Some(s) => Some(decode_scales(s)),
        };
        Ok(ShardSpec {
            app: string(j, "app")?.to_string(),
            shard: uint(j, "shard")?,
            of: uint(j, "of")?,
            budget,
            seed,
            requests: uint(j, "requests")?,
            threads: uint(j, "threads")?,
            scales,
        })
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<ShardSpec> {
        // lint: allow(panic-reach) — the json parser's indexing is bounds-guarded (every
        // b[i] sits behind an i < len check); malformed input returns JsonError, never panics
        let j = parse(text).map_err(|e| anyhow!("{e}")).context("parsing shard spec")?;
        ShardSpec::from_json(&j)
    }
}

// -- shard result ------------------------------------------------------------

impl ShardResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(RESULT_SCHEMA.to_string())),
            ("app", Json::Str(self.app.clone())),
            ("shard", Json::Num(self.shard as f64)),
            ("of", Json::Num(self.of as f64)),
            ("evaluations", Json::Num(self.evaluations as f64)),
            ("eval_requests", Json::Num(self.eval_requests as f64)),
            ("budget_exhausted", Json::Bool(self.budget_exhausted)),
            (
                "front",
                Json::Arr(self.front.iter().map(encode_candidate).collect()),
            ),
            (
                "best",
                match &self.best {
                    Some(c) => encode_candidate(c),
                    None => Json::Null,
                },
            ),
            (
                "best_index",
                match self.best_index {
                    Some(i) => Json::Num(i as f64),
                    None => Json::Null,
                },
            ),
            ("scales", encode_scales(&self.scales)),
            ("fell_back", Json::Bool(self.fell_back)),
            ("tau_pre", encode_agreement(&self.pre)),
            ("tau_post", encode_agreement(&self.post)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ShardResult> {
        check_schema(j, RESULT_SCHEMA)?;
        let front_json = j
            .get("front")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing 'front' array"))?;
        let mut front = Vec::with_capacity(front_json.len());
        for (i, c) in front_json.iter().enumerate() {
            front.push(decode_candidate(c).with_context(|| format!("front member {i}"))?);
        }
        let best = match j.get("best") {
            None | Some(Json::Null) => None,
            Some(c) => Some(decode_candidate(c).context("best candidate")?),
        };
        let best_index = match j.get("best_index") {
            None | Some(Json::Null) => None,
            Some(_) => Some(uint(j, "best_index")?),
        };
        let scales = j
            .get("scales")
            .map(decode_scales)
            .ok_or_else(|| anyhow!("missing 'scales'"))?;
        Ok(ShardResult {
            app: string(j, "app")?.to_string(),
            shard: uint(j, "shard")?,
            of: uint(j, "of")?,
            evaluations: uint(j, "evaluations")?,
            eval_requests: uint(j, "eval_requests")?,
            budget_exhausted: boolean(j, "budget_exhausted")?,
            front,
            best,
            best_index,
            scales,
            fell_back: boolean(j, "fell_back")?,
            pre: decode_agreement(j, "tau_pre")?,
            post: decode_agreement(j, "tau_post")?,
        })
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<ShardResult> {
        // lint: allow(panic-reach) — the json parser's indexing is bounds-guarded (every
        // b[i] sits behind an i < len check); malformed input returns JsonError, never panics
        let j = parse(text).map_err(|e| anyhow!("{e}")).context("parsing shard result")?;
        ShardResult::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn candidate_codec_roundtrips_every_strategy() {
        let space = enumerate(&[]);
        for kind in StrategyKind::all() {
            let c = space
                .iter()
                .find(|c| c.strategy == *kind)
                .expect("strategy present in space");
            let j = encode_candidate(c);
            let back = decode_candidate(&j).expect("decode");
            assert_eq!(back.describe(), c.describe());
            assert_eq!(back.clock_mhz.to_bits(), c.clock_mhz.to_bits());
        }
    }

    #[test]
    fn candidate_decode_rejects_key_mismatch() {
        let c = &enumerate(&["xc7s15"])[0];
        let j = encode_candidate(c);
        // tamper with one axis but keep the original key
        let tampered = match j {
            Json::Obj(mut m) => {
                m.insert("alus".into(), Json::Num(7.0));
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        assert!(decode_candidate(&tampered).is_err());
    }

    #[test]
    fn candidate_decode_rejects_off_axis_activation_kind() {
        // the describe key prints only the activation impls, so a
        // tampered *kind* with a valid impl would slip past the key
        // check — the tied-pair axis validation must catch it
        let c = enumerate(&["xc7s15"])
            .into_iter()
            .find(|c| c.sigmoid.imp == ActImpl::Pla)
            .expect("pla candidate");
        let tampered = match encode_candidate(&c) {
            Json::Obj(mut m) => {
                m.insert(
                    "sigmoid".into(),
                    Json::obj(vec![
                        ("kind", Json::Str("tanh".into())),
                        ("impl", Json::Str("pla".into())),
                    ]),
                );
                Json::Obj(m)
            }
            _ => unreachable!(),
        };
        assert!(decode_candidate(&tampered).is_err());
    }

    #[test]
    fn shard_spec_roundtrips() {
        let spec = ShardSpec {
            app: "soft-sensor".into(),
            shard: 1,
            of: 4,
            budget: Some(123),
            // above 2^53: an f64 wire encoding would silently round it
            seed: u64::MAX - 1,
            requests: 200,
            threads: 2,
            scales: None,
        };
        let text = spec.to_json().dump();
        // sweep-phase specs don't carry a scales field at all (v1 shape)
        assert!(!text.contains("scales"));
        assert_eq!(ShardSpec::from_json_str(&text).unwrap(), spec);
        let none = ShardSpec { budget: None, ..spec.clone() };
        assert_eq!(
            ShardSpec::from_json_str(&none.to_json().dump()).unwrap(),
            none
        );
        // a refinement spec round-trips its corrected constants exactly
        let refine = ShardSpec {
            scales: Some(ModelScales { busy: 1.25, idle: 0.5, off: 2.0, cold: 0.75 }),
            ..spec
        };
        assert_eq!(
            ShardSpec::from_json_str(&refine.to_json().dump()).unwrap(),
            refine
        );
    }

    #[test]
    fn non_finite_scales_degrade_to_identity_on_the_wire() {
        let bad = ModelScales {
            busy: f64::NAN,
            idle: f64::INFINITY,
            off: 0.5,
            cold: 1.25,
        };
        let text = encode_scales(&bad).dump();
        // the writer's non-finite guard keeps the document parseable
        let back = decode_scales(&crate::util::json::parse(&text).unwrap());
        assert_eq!(back.busy, 1.0);
        assert_eq!(back.idle, 1.0);
        assert_eq!(back.off, 0.5);
        assert_eq!(back.cold, 1.25);
    }
}
