//! The shard planner: disjoint, cost-balanced partitions of a scenario's
//! design space plus the budget split that keeps a distributed sweep
//! bit-identical to the single-process one.
//!
//! Shards are *striped* over the enumeration order — shard `s` of `N`
//! owns global indices `s, s+N, s+2N, …` — rather than chunked, because
//! the enumeration nests the expensive axes (device, format, activation
//! pair) outermost: a contiguous chunk would hand one worker all the
//! large-device candidates while another sweeps only cheap ones, and the
//! sweep would run at the speed of the slowest chunk.  Striping
//! interleaves every axis, so shard costs stay within one candidate of
//! each other.

use crate::generator::calibrate::ModelScales;
use crate::generator::constraints::AppSpec;
use crate::generator::design_space::Candidate;

use super::wire::ShardSpec;

/// The candidates shard `shard` of `of` owns, in enumeration order.
pub fn stripe(space: &[Candidate], shard: usize, of: usize) -> Vec<Candidate> {
    let of = of.max(1);
    space
        .iter()
        .skip(shard)
        .step_by(of)
        .cloned()
        .collect()
}

/// Portion of a global evaluation budget that lands on shard `shard` of
/// `of`: the number of global enumeration indices `< total` congruent to
/// `shard (mod of)`.  Because a budgeted `EvalPool` spends on the first
/// candidates it sees, the union of every shard's budget prefix is then
/// exactly the single-process sweep's first-`total` prefix — which is
/// what keeps budgeted distributed sweeps bit-identical to local ones.
pub fn stripe_budget(total: usize, shard: usize, of: usize) -> usize {
    let of = of.max(1);
    total / of + usize::from(shard < total % of)
}

/// Plan one shard spec per worker for a scenario.  `budget` is the
/// *global* evaluation budget (split per stripe); `seed`/`requests`
/// parameterise each worker's shard-local calibration replay; `threads`
/// is the worker-local `EvalPool` width.  A `Some(scales)` plans the
/// *refinement* phase: workers re-rank their stripes under these
/// corrected constants, and the budget split is the same stripe prefix —
/// so the union of per-shard refinement prefixes is exactly the
/// candidate prefix the single-process calibration sweep memoized, which
/// is what keeps a budgeted distributed refinement bit-identical to
/// `refine_with` on the budget-cut pool.
pub fn plan_shards(
    spec: &AppSpec,
    workers: usize,
    budget: Option<usize>,
    seed: u64,
    requests: usize,
    threads: usize,
    scales: Option<ModelScales>,
) -> Vec<ShardSpec> {
    let workers = workers.max(1);
    (0..workers)
        .map(|shard| ShardSpec {
            app: spec.name.clone(),
            shard,
            of: workers,
            budget: budget.map(|b| stripe_budget(b, shard, workers)),
            seed,
            requests,
            threads,
            scales,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn stripes_partition_the_space() {
        let space = enumerate(&["xc7s6", "xc7s15"]);
        for of in [1usize, 2, 3, 4, 7] {
            let mut seen = vec![false; space.len()];
            let mut total = 0usize;
            for shard in 0..of {
                for (j, c) in stripe(&space, shard, of).iter().enumerate() {
                    let global = shard + j * of;
                    assert_eq!(c.describe(), space[global].describe());
                    assert!(!seen[global], "index {global} assigned twice");
                    seen[global] = true;
                    total += 1;
                }
            }
            assert_eq!(total, space.len(), "stripes at of={of} do not cover");
        }
    }

    #[test]
    fn stripe_sizes_balanced_within_one() {
        let space = enumerate(&["xc7s15"]);
        for of in [2usize, 3, 5] {
            let sizes: Vec<usize> = (0..of).map(|s| stripe(&space, s, of).len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "{sizes:?}");
        }
    }

    #[test]
    fn budget_split_sums_and_matches_prefix_counts() {
        for (total, of) in [(0usize, 3usize), (1, 3), (7, 3), (100, 4), (101, 4), (5, 8)] {
            let parts: Vec<usize> = (0..of).map(|s| stripe_budget(total, s, of)).collect();
            assert_eq!(parts.iter().sum::<usize>(), total, "{total}/{of}");
            // each part equals the count of indices < total in that stripe
            for (s, p) in parts.iter().enumerate() {
                let count = (0..total).filter(|j| j % of == s).count();
                assert_eq!(*p, count, "total={total} of={of} shard={s}");
            }
        }
    }

    #[test]
    fn plan_covers_workers_and_splits_budget() {
        let spec = AppSpec::soft_sensor();
        let plans = plan_shards(&spec, 4, Some(10), 7, 100, 1, None);
        assert_eq!(plans.len(), 4);
        assert!(plans.iter().all(|p| p.app == spec.name && p.of == 4));
        assert!(plans.iter().all(|p| p.scales.is_none()));
        let granted: usize = plans.iter().map(|p| p.budget.unwrap()).sum();
        assert_eq!(granted, 10);
        let unbudgeted = plan_shards(&spec, 2, None, 7, 100, 1, None);
        assert!(unbudgeted.iter().all(|p| p.budget.is_none()));
    }

    #[test]
    fn refinement_plan_carries_scales_and_the_same_budget_split() {
        let spec = AppSpec::soft_sensor();
        let scales = ModelScales { busy: 1.5, idle: 1.0, off: 1.0, cold: 0.5 };
        let sweep = plan_shards(&spec, 3, Some(11), 7, 100, 1, None);
        let refine = plan_shards(&spec, 3, Some(11), 7, 100, 1, Some(scales));
        assert!(refine.iter().all(|p| p.scales == Some(scales)));
        // budget-prefix parity: the refinement stripes spend on exactly
        // the same global enumeration prefix as the sweep stripes
        for (a, b) in sweep.iter().zip(&refine) {
            assert_eq!(a.budget, b.budget);
            assert_eq!((a.shard, a.of), (b.shard, b.of));
        }
    }
}
