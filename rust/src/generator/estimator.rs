//! Analytical candidate estimation and pruning (§2.2 "Exploration and
//! Estimation").
//!
//! For every candidate the estimator runs the full analytical chain —
//! template instantiation → technology mapping → timing → power → a
//! closed-form workload-energy model — and checks the application's
//! constraints.  The closed-form model is deliberately cheap (the
//! Generator sweeps thousands of candidates); E7 validates its ranking
//! against the discrete-event simulator on the finalists.

use super::constraints::{AppSpec, Goal};
use super::design_space::{Candidate, StrategyKind};
use crate::eda;
use crate::elastic_node::Platform;
use crate::fpga::ConfigController;
use crate::power;
use crate::rtl::composition::{build, Accelerator};
use crate::sim;
use crate::strategy::CostModel;
use crate::util::units::{Hertz, Joules, Secs};

/// Estimated performance of one candidate under one application.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub candidate: Candidate,
    pub feasible: bool,
    pub reject_reason: Option<&'static str>,
    /// Pure inference latency.
    pub latency: Secs,
    /// Worst-case response latency under the chosen strategy (includes
    /// reconfiguration when the strategy may power off).
    pub response_latency: Secs,
    pub gops_per_watt: f64,
    pub energy_per_item: Joules,
    pub act_error_lsb: f64,
    pub utilization: f64,
    /// The strategy-facing cost model the closed-form numbers were derived
    /// from.  Carried so the calibration loop can replay the candidate
    /// through the DES and re-derive corrected energies without rebuilding
    /// the accelerator (`generator::calibrate`).
    pub cost: CostModel,
}

impl Estimate {
    /// Scalar score, higher is better (used by all search algorithms).
    pub fn score(&self, goal: Goal) -> f64 {
        if !self.feasible {
            return f64::NEG_INFINITY;
        }
        match goal {
            Goal::EnergyEfficiency => self.gops_per_watt,
            Goal::EnergyPerItem => -self.energy_per_item.value(),
            Goal::Latency => -self.response_latency.value(),
        }
    }
}

/// Build the cost model a candidate's strategy would see.
pub fn candidate_cost_model(acc: &Accelerator, c: &Candidate) -> CostModel {
    let platform = Platform::default();
    let config = ConfigController::raw(c.device);
    sim::cost_model(acc, c.device, Hertz::from_mhz(c.clock_mhz), &platform, &config)
}

/// Per-item energy split of the closed-form workload model, in the DES
/// ledger's coordinates (busy / idle / off / cold≡config).  The split is
/// what the calibration loop fits per-component against simulated
/// ledgers (`generator::calibrate`).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyComponents {
    /// Inference energy (busy power × busy time).
    pub busy: Joules,
    /// Configured-and-waiting energy across the gap.
    pub idle: Joules,
    /// Powered-down energy across the gap.
    pub off: Joules,
    /// Cold-start (power-up + configuration) energy.
    pub cold: Joules,
}

impl EnergyComponents {
    pub fn total(&self) -> Joules {
        self.busy + self.idle + self.off + self.cold
    }
}

/// Closed-form per-item energy components for a strategy at mean gap `g`
/// (see [`EnergyComponents`]); [`strategy_energy_per_item`] is their sum.
pub fn strategy_energy_components(
    cost: &CostModel,
    kind: StrategyKind,
    g: Secs,
) -> EnergyComponents {
    let zero = Joules(0.0);
    let busy = cost.busy_power * cost.busy_time;
    let idle_gap = Secs((g.value() - cost.busy_time.value()).max(0.0));
    let idle = cost.idle_power * idle_gap;
    let off = cost.off_power * idle_gap;
    match kind {
        StrategyKind::OnOff => EnergyComponents {
            busy,
            idle: zero,
            off,
            cold: cost.cold_energy,
        },
        StrategyKind::IdleWait => EnergyComponents {
            busy,
            idle,
            off: zero,
            cold: zero,
        },
        StrategyKind::ClockScale => {
            // stretch the inference across ~the whole gap; dynamic energy is
            // f-invariant to first order, static burns for the full gap.
            // The dynamic share is clamped at zero like the DES's
            // `scaled_busy`: under calibration corrections busy power can
            // be scaled below idle power, and an unclamped negative term
            // would let a refinement sweep crown a bogus winner.
            let t = g.value().max(cost.busy_time.value());
            let dyn_e = (cost.busy_power.value() - cost.idle_power.value()).max(0.0)
                * cost.busy_time.value();
            EnergyComponents {
                busy: Joules(dyn_e),
                idle: Joules(cost.idle_power.value() * t),
                off: zero,
                cold: zero,
            }
        }
        // threshold switches: the oracle bound (they approach the better
        // side of the crossover; the learnable variant tracks it under
        // drift — E4 quantifies the gap to this bound)
        StrategyKind::PredefinedThreshold | StrategyKind::LearnableThreshold => {
            let onoff = cost.cold_energy + off;
            if idle.value() <= onoff.value() {
                EnergyComponents { busy, idle, off: zero, cold: zero }
            } else {
                EnergyComponents { busy, idle: zero, off, cold: cost.cold_energy }
            }
        }
    }
}

/// Closed-form mean energy per served item for a strategy at mean gap `g`.
pub fn strategy_energy_per_item(cost: &CostModel, kind: StrategyKind, g: Secs) -> Joules {
    strategy_energy_components(cost, kind, g).total()
}

/// Template-level cache key: candidates differing only in clock/strategy
/// share one built accelerator (20 reuses per template point on the full
/// axes — the §Perf memoisation, ~3x on exhaustive sweeps).
type AccKey = (crate::models::Topology, &'static str, (u32, u32), u8, u8, u32, bool);

fn acc_key(spec: &AppSpec, c: &Candidate) -> AccKey {
    (
        spec.topology,
        c.device.name,
        (c.fmt.total_bits, c.fmt.frac_bits),
        c.sigmoid.imp as u8,
        c.tanh.imp as u8,
        c.alus,
        c.pipelined,
    )
}

/// Accelerator-build cache for DSE sweeps.
#[derive(Default)]
pub struct EstimatorCache {
    built: std::collections::HashMap<AccKey, Accelerator>,
}

impl EstimatorCache {
    pub fn new() -> EstimatorCache {
        EstimatorCache::default()
    }

    fn get(&mut self, spec: &AppSpec, c: &Candidate) -> &Accelerator {
        self.built
            .entry(acc_key(spec, c))
            .or_insert_with(|| build(spec.topology, &c.build_opts()))
    }
}

/// Evaluate one candidate against an application spec.
pub fn estimate(spec: &AppSpec, c: &Candidate) -> Estimate {
    let acc = build(spec.topology, &c.build_opts());
    estimate_with_acc(spec, c, &acc)
}

/// Cached variant for sweeps (see [`EstimatorCache`]).
pub fn estimate_cached(spec: &AppSpec, c: &Candidate, cache: &mut EstimatorCache) -> Estimate {
    let acc = cache.get(spec, c);
    estimate_with_acc(spec, c, acc)
}

fn estimate_with_acc(spec: &AppSpec, c: &Candidate, acc: &Accelerator) -> Estimate {
    let clock = Hertz::from_mhz(c.clock_mhz);
    let synth = eda::synthesize(acc, c.device);
    let latency = acc.latency(clock);
    let act_error_lsb = c
        .sigmoid
        .max_error_lsb(c.fmt)
        .max(c.tanh.max_error_lsb(c.fmt));

    let cost = candidate_cost_model(acc, c);
    let g = spec.workload.mean_gap();
    let energy_per_item = strategy_energy_per_item(&cost, c.strategy, g);
    let may_power_off = matches!(
        c.strategy,
        StrategyKind::OnOff | StrategyKind::PredefinedThreshold | StrategyKind::LearnableThreshold
    );
    let response_latency = if may_power_off {
        latency + cost.cold_time
    } else if c.strategy == StrategyKind::ClockScale {
        // stretched inference fills the period
        Secs(latency.value().max(g.value() * 0.9))
    } else {
        latency
    };

    let mut reject: Option<&'static str> = None;
    if !spec.allows_device(c.device.name) {
        reject = Some("device not allowed");
    } else if !synth.fits {
        reject = Some("over capacity");
    } else if !eda::meets_timing(&synth, c.device, clock) {
        reject = Some("timing violated");
    } else if latency.value() >= g.value() {
        reject = Some("cannot sustain workload rate");
    } else if let Some(maxl) = spec.max_latency {
        if response_latency.value() > maxl.value() {
            reject = Some("latency bound violated");
        }
    }
    if reject.is_none() {
        if let Some(max_err) = spec.max_act_error_lsb {
            if act_error_lsb > max_err {
                reject = Some("activation error budget exceeded");
            }
        }
    }

    Estimate {
        candidate: c.clone(),
        feasible: reject.is_none(),
        reject_reason: reject,
        latency,
        response_latency,
        gops_per_watt: power::gops_per_watt(acc, c.device, clock),
        energy_per_item,
        act_error_lsb,
        utilization: synth.utilization,
        cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generator::design_space::enumerate;

    #[test]
    fn some_candidates_feasible_for_each_scenario() {
        for spec in AppSpec::scenarios() {
            let feasible = enumerate(&[])
                .iter()
                .map(|c| estimate(&spec, c))
                .filter(|e| e.feasible)
                .count();
            assert!(feasible > 10, "{}: {feasible} feasible", spec.name);
        }
    }

    #[test]
    fn cached_estimate_identical_to_uncached() {
        let spec = AppSpec::soft_sensor();
        let mut cache = EstimatorCache::new();
        for c in enumerate(&["xc7s15"]).iter().take(300) {
            let a = estimate(&spec, c);
            let b = estimate_cached(&spec, c, &mut cache);
            assert_eq!(a.feasible, b.feasible);
            assert_eq!(a.energy_per_item.value(), b.energy_per_item.value());
            assert_eq!(a.gops_per_watt, b.gops_per_watt);
        }
    }

    #[test]
    fn infeasible_scores_neg_infinity() {
        let spec = AppSpec::har_wearable();
        let bad = enumerate(&["ice40up5k"]); // not in the allowlist
        let e = estimate(&spec, &bad[0]);
        assert!(!e.feasible);
        assert_eq!(e.score(Goal::EnergyPerItem), f64::NEG_INFINITY);
    }

    #[test]
    fn idle_beats_onoff_at_short_gap_in_closed_form() {
        let spec = AppSpec::soft_sensor(); // 50ms period
        let cands = enumerate(&["xc7s15"]);
        let idle = cands
            .iter()
            .find(|c| c.strategy == StrategyKind::IdleWait && c.pipelined && c.clock_mhz == 100.0)
            .unwrap();
        let mut onoff = idle.clone();
        onoff.strategy = StrategyKind::OnOff;
        let e_idle = estimate(&spec, idle);
        let e_onoff = estimate(&spec, &onoff);
        assert!(e_idle.energy_per_item.value() < e_onoff.energy_per_item.value());
    }

    #[test]
    fn threshold_oracle_never_worse_than_either_side() {
        let spec = AppSpec::ecg_monitor();
        for c in enumerate(&["xc7s6"]).iter().take(200) {
            let acc = build(spec.topology, &c.build_opts());
            let cost = candidate_cost_model(&acc, c);
            let g = spec.workload.mean_gap();
            let th = strategy_energy_per_item(&cost, StrategyKind::PredefinedThreshold, g);
            let idle = strategy_energy_per_item(&cost, StrategyKind::IdleWait, g);
            let onoff = strategy_energy_per_item(&cost, StrategyKind::OnOff, g);
            assert!(th.value() <= idle.value() + 1e-15);
            assert!(th.value() <= onoff.value() + 1e-15);
        }
    }
}
