//! The *Generator* (§2.2, RQ3): application-specific knowledge + RTL
//! templates + workload-aware strategies → energy-optimal accelerator
//! configurations.
//!
//! * [`constraints`] — application scenario specs (goal + constraints).
//! * [`design_space`] — the candidate cross-product and its axis view.
//! * [`estimator`] — analytical evaluation + constraint pruning.
//! * [`eval`] — the parallel, budget-aware evaluation engine (EvalPool).
//! * [`search`] — exhaustive / greedy / annealing / genetic + Pareto,
//!   plus the successive-halving heuristic portfolio driver.
//! * [`calibrate`] — the estimator↔simulator loop: DES replay of Pareto
//!   finalists, least-squares constant fitting, rank-agreement checks,
//!   and the calibrated refinement sweep.
//! * [`dist`] — distributed DSE: process-sharded sweeps (shard planner,
//!   JSON worker protocol, `DistSweep` driver) merged under a
//!   calibration guard into one bit-identical Pareto front.

pub mod calibrate;
pub mod constraints;
pub mod design_space;
pub mod dist;
pub mod estimator;
pub mod eval;
pub mod search;

pub use calibrate::{
    calibrate, calibrate_and_refine, calibrate_and_refine_dist, calibrate_finalists, refine,
    refine_with, CalibrateOpts, CalibratedEstimator, Calibration, ModelScales, RankAgreement,
    Refinement,
};
pub use constraints::{AppSpec, Goal};
pub use design_space::{Candidate, StrategyKind};
pub use dist::{DistCalOutcome, DistOpts, DistOutcome, DistSweep, RefineOutcome, WorkerMode};
pub use estimator::{estimate, Estimate};
pub use eval::{default_threads, map_ordered, EvalPool, Evaluator};
pub use search::{generate, generate_portfolio, Portfolio, SearchResult, Searcher};
