//! The *Generator* (§2.2, RQ3): application-specific knowledge + RTL
//! templates + workload-aware strategies → energy-optimal accelerator
//! configurations.
//!
//! * [`constraints`] — application scenario specs (goal + constraints).
//! * [`design_space`] — the candidate cross-product and its axis view.
//! * [`estimator`] — analytical evaluation + constraint pruning.
//! * [`search`] — exhaustive / greedy / annealing / genetic + Pareto.

pub mod constraints;
pub mod design_space;
pub mod estimator;
pub mod search;

pub use constraints::{AppSpec, Goal};
pub use design_space::{Candidate, StrategyKind};
pub use estimator::{estimate, Estimate};
pub use search::{generate, SearchResult, Searcher};
